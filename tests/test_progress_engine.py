"""ONE progress engine across the functional core and the DES (ISSUE 4).

The engine-parity suite: both layers drive the same
:class:`repro.core.comm.progress.ProgressEngine`, so — given the same
variant config and workload — they must make IDENTICAL protocol-path and
completion-dispatch decisions.  The engine records a normalized decision
trace (``('send', path, nfollowups)``, ``('header', path)``,
``('chunk',)``, ``('deliver', n)``); we compare the ordered traces.

Plus: policy/router units, the dedicated-progress-worker family
(``lci_prg{n}``), the completion-router scope (``cq_scope`` /
``lci_shared_cq``), the ``rnr_storm`` model, and the
``sim_config_for_variant`` family-resolution regression."""
import dataclasses

import pytest

from repro.amtsim.parcelport_sim import (
    SHARED_CONFIG_FIELDS,
    SimConfig,
    SimWorld,
    Task,
    sim_config_for_variant,
)
from repro.amtsim.workloads import flood
from repro.core.comm.progress import (
    LOCK_BLOCK,
    LOCK_TRY,
    ROLE_PROGRESS,
    CompletionRouter,
    CompletionSource,
    ProgressEngine,
    ProgressPolicy,
    run_step,
)
from repro.core.comm.resources import ResourceLimits
from repro.core.lci_parcelport import LCIPPConfig, LCIParcelport
from repro.core.mpi_parcelport import MPIParcelport
from repro.core.parcelport import World
from repro.core.variants import VARIANTS, make_parcelport_factory, max_devices

# Sizes chosen away from every threshold so both layers' size accounting
# (the functional layer counts serialized bytes, the DES raw payload
# bytes) lands on the same protocol path: 64 B (eager / piggyback),
# 12 KiB (straddles nothing: rdv for 8 KiB thresholds, eager for 16 KiB),
# 40 KiB (rendezvous with exactly one follow-up everywhere).
PARITY_SIZES = (64, 12_000, 40_000)
PARITY_VARIANTS = ("lci", "lci_agg_eager", "mpi", "lci_prg2",
                   "shmem", "shmem_put", "shmem_putq", "shmem_prg2")


def functional_trace(variant: str, sizes=PARITY_SIZES) -> list:
    """Run the functional core sequentially (drain between sends) and
    return the engine's ordered decision trace."""
    world = World(2, make_parcelport_factory(variant), devices_per_rank=max_devices(variant))
    tr: list = []
    for loc in world.localities:
        loc.parcelport.engine.trace = tr
    got: list = []
    world.localities[1].register_action("sink", lambda *a: got.append(a))
    for s in sizes:
        world.localities[0].async_action(
            1, "sink", bytes([s % 251]) * s, zero_copy_threshold=1 << 30
        )
        world.drain()
    assert len(got) == len(sizes)
    for loc in world.localities:
        close = getattr(loc.parcelport, "close", None)
        if close:
            close()
    return tr


def des_trace(variant: str, sizes=PARITY_SIZES) -> list:
    """Run the DES with the same config, chained sequentially (each send
    spawned by the previous delivery), and return the engine's trace."""
    world = SimWorld(2, 4, sim_config_for_variant(variant))
    tr: list = []
    world.engine.trace = tr
    state = {"i": 0}

    def send_next() -> None:
        if state["i"] >= len(sizes):
            world.stop()
            return
        size = sizes[state["i"]]
        state["i"] += 1
        op = world.make_parcel(0, 1, size, on_delivered=send_next)
        world.spawn(0, Task(action=lambda w, _op=op: world.send_parcel(w, _op)))

    send_next()
    world.run(until=5.0)
    assert world.stopped and state["i"] == len(sizes)
    return tr


# ------------------------------------------------------- engine parity
@pytest.mark.parametrize("variant", PARITY_VARIANTS)
def test_engine_parity_functional_vs_des(variant):
    """The acceptance gate: same variant, same workload → the functional
    core and the DES replay identical ordered decision traces through the
    one shared engine (protocol path per send, header kind, follow-up
    chunk sequence, delivery counts)."""
    ft = functional_trace(variant)
    dt = des_trace(variant)
    assert ft == dt, f"{variant}: functional {ft} != DES {dt}"


def test_parity_trace_shape():
    """The trace itself encodes the protocol engine: eager sends have zero
    follow-ups and eager headers; 40 KiB rides rendezvous with exactly one
    chunk; every parcel delivers exactly once."""
    tr = functional_trace("lci")
    sends = [e for e in tr if e[0] == "send"]
    assert sends[0] == ("send", "eager", 0)  # 64 B
    assert sends[1] == ("send", "rdv", 1)  # 12 KiB > 8 KiB piggyback
    assert sends[2] == ("send", "rdv", 1)  # 40 KiB
    assert tr.count(("deliver", 1)) == len(PARITY_SIZES)
    assert tr.count(("chunk",)) == 2
    # agg_eager's 16 KiB threshold flips the 12 KiB parcel onto eager
    tr_agg = functional_trace("lci_agg_eager")
    assert [e for e in tr_agg if e[0] == "send"][1] == ("send", "eager", 0)
    # MPI never takes the eager path
    assert all(e[1] == "rdv" for e in functional_trace("mpi") if e[0] == "send")


# ------------------------------------ cross-backend engine parity (ISSUE 5)
def test_collective_engine_parity_vs_lci_backend():
    """Same engine, same config, DIFFERENT CommInterface backend: the
    collective transport replays the LCI backend's decision trace bit for
    bit (protocol path per send, header kind, chunk sequence, deliveries)
    — the abstraction carries the protocol, the backend only moves bytes."""
    assert functional_trace("collective") == functional_trace("sendrecv_queue")


def test_shmem_ladder_engine_parity_vs_lci_backend():
    """ISSUE 6, cross-backend: the shared-memory transport replays the LCI
    backend's decision traces bit for bit at every capability rung — the
    two-sided rung matches the two-sided LCI config, and BOTH put rungs
    match the put-capable LCI default (the rungs differ only in how a
    completed put is discovered, which is below the engine's trace)."""
    assert functional_trace("shmem") == functional_trace("sendrecv_queue")
    assert functional_trace("shmem_put") == functional_trace("lci")
    assert functional_trace("shmem_putq") == functional_trace("lci")


def test_collective_prg_family_delivers():
    """Dedicated progress workers drive the collective backend too (the
    collective_prg{n} family): real threads, full delivery."""
    cfg = VARIANTS["collective_prg2"]
    assert cfg.progress_workers == 2 and cfg.progress_mode == "implicit"
    tr = functional_trace("collective_prg2")
    assert tr.count(("deliver", 1)) == len(PARITY_SIZES)


# ------------------------------------------------- policy / router units
def test_policy_for_config_parity_across_layers():
    """ONE policy builder serves both layers: the functional LCIPPConfig
    and the DES SimConfig for the same variant yield the same policy."""
    for name in ("lci", "try_progress", "block", "lci_prg2"):
        functional = ProgressPolicy.for_config(VARIANTS[name])
        des = ProgressPolicy.for_config(sim_config_for_variant(name))
        assert functional == des, name
    assert ProgressPolicy.for_config(sim_config_for_variant("mpi")) == ProgressPolicy.mpi_request_pool()


def test_named_policies_match_paper_ladder():
    assert ProgressPolicy.blocking().lock_mode == LOCK_BLOCK
    assert ProgressPolicy.blocking().progress_mode == "explicit"  # §5.3 catastrophe
    assert ProgressPolicy.explicit_trylock().lock_mode == LOCK_TRY
    assert ProgressPolicy.worker_polling().progress_mode == "implicit"
    assert ProgressPolicy.dedicated(3).dedicated_workers == 3
    mpi = ProgressPolicy.mpi_request_pool()
    assert mpi.step_lock and mpi.big_lock


def test_router_device_rotation_and_roles():
    src_own = CompletionSource("dev_cq", per_device=True, sweep="own", progress_side=True)
    src_all = CompletionSource("cq", per_device=True, sweep="all")
    client = CompletionSource("client_poll")
    router = CompletionRouter([client, src_own, src_all], ndevices=4)
    # task role: own-device sources stay on the static mapping; 'all'
    # sources rotate starting at the worker's own device
    assert router.devices_for(src_own, wid=6, role="task") == (2,)
    assert router.devices_for(src_all, wid=6, role="task") == (2, 3, 0, 1)
    assert router.devices_for(client, wid=6, role="task") == (-1,)
    # progress role: only progress-side sources, every device
    assert router.sources(ROLE_PROGRESS) == (src_own,)
    assert router.devices_for(src_own, wid=1, role=ROLE_PROGRESS) == (1, 2, 3, 0)


class _OpLog:
    """Fake op executor: records the engine's decision sequence."""

    def __init__(self, results=None):
        self.ops = []
        self.results = dict(results or {})

    def execute(self, op):
        self.ops.append(op[0])
        return self.results.get(op[0])  # None = empty reap / falsy op result


def test_engine_step_canonical_order():
    eng = ProgressEngine(
        ProgressPolicy(),  # explicit, lock-free
        CompletionRouter([CompletionSource("cq", batch=4)], ndevices=1),
    )
    log = _OpLog()
    run_step(eng, log, wid=0)
    # drain retries → progress → reap (empty) → flush
    assert log.ops == ["drain_retries", "progress", "reap_begin", "reap", "reap_end", "flush"]


def test_engine_step_mpi_discipline_aborts_on_contended_pool():
    eng = ProgressEngine(
        ProgressPolicy.mpi_request_pool(),
        CompletionRouter([CompletionSource("mpi_header", batch=1)]),
    )
    log = _OpLog(results={"step_trylock": False})
    assert run_step(eng, log, wid=0) is False
    assert log.ops == ["step_trylock"]  # nothing runs without the pool lock


def test_engine_implicit_polls_only_on_empty_reap():
    eng = ProgressEngine(
        ProgressPolicy.worker_polling(),
        CompletionRouter([CompletionSource("cq", batch=2)]),
    )
    idle = _OpLog()
    run_step(eng, idle, wid=0)
    assert "poll" in idle.ops and "implicit_tax" in idle.ops and "progress" not in idle.ops
    busy = _OpLog(results={"reap": object()})
    run_step(eng, busy, wid=0)
    assert "poll" not in busy.ops  # something was reaped: no fallback poll


# --------------------------------------- dedicated progress workers (prg)
def test_lci_prg_family_resolves_and_delivers():
    cfg = VARIANTS["lci_prg2"]
    assert cfg.progress_workers == 2 and cfg.progress_mode == "implicit"
    assert VARIANTS["lci_prg0"].progress_workers == 0
    assert VARIANTS["lci_prg0"].progress_mode == "explicit"  # all-workers-poll
    tr = functional_trace("lci_prg2")  # real dedicated threads + delivery
    assert tr.count(("deliver", 1)) == len(PARITY_SIZES)


def test_des_dedicated_progress_workers_deliver():
    r = flood("lci_prg2", msg_size=64, nthreads=8, nmsgs=300)
    assert r.messages == 300


def test_prg_threads_join_on_close():
    """Regression (ISSUE 5): the dedicated progress workers used to rely
    on weakref finalization alone, leaking live daemon threads for as long
    as the parcelport object survived.  close() must stop AND join them —
    thread count stays flat over 50 create/destroy cycles."""
    import threading

    base = threading.active_count()
    for _ in range(50):
        world = World(2, make_parcelport_factory("lci_prg2"), devices_per_rank=2)
        world.close()
    assert threading.active_count() <= base + 1
    # idempotent, and usable as a context manager
    world = World(2, make_parcelport_factory("lci_prg2"), devices_per_rank=2)
    pp = world.localities[0].parcelport
    assert pp._pw_pool is not None and pp._pw_pool.size() == 2
    with pp:
        pass
    assert pp._pw_pool.size() == 0
    pp.close()
    world.close()
    assert threading.active_count() <= base + 1


def test_des_rejects_all_workers_dedicated():
    """Reserving every core for the engine leaves nobody to run tasks —
    fail fast instead of silently spinning to the time cap."""
    with pytest.raises(ValueError, match="progress_workers"):
        SimWorld(2, 2, sim_config_for_variant("lci_prg2"))


# --------------------------------------------- completion-router scope
def test_cq_scope_device_functional_delivery():
    cfg = VARIANTS["lci_shared_cq"].variant(name="lci_devcq", cq_scope="device")
    world = World(2, lambda loc, fab: LCIParcelport(loc, fab, cfg), devices_per_rank=cfg.ndevices)
    got: list = []
    for loc in world.localities:
        loc.register_action("sink", lambda *a, _g=got: _g.append(a))
    for i, s in enumerate((8, 600, 12_000, 40_000)):
        world.localities[i % 2].async_action((i + 1) % 2, "sink", b"x" * s)
    world.drain()
    assert sorted(len(a[0]) for a in got) == [8, 600, 12_000, 40_000]
    # the shared-scope variant is the documented default
    assert VARIANTS["lci_shared_cq"].cq_scope == "shared"
    assert VARIANTS["lci"].cq_scope == "shared"


def test_cq_scope_device_des_deterministic():
    cfg = dataclasses.replace(sim_config_for_variant("lci"), name="lci_devcq", cq_scope="device")
    r1 = flood(cfg, msg_size=8, nthreads=8, nmsgs=300)
    r2 = flood(cfg, msg_size=8, nthreads=8, nmsgs=300)
    assert r1.messages == 300 and (r1.elapsed, r1.messages) == (r2.elapsed, r2.messages)


# ------------------------------------------------------------ rnr_storm
def _rnr_cfg(storm: bool) -> SimConfig:
    return dataclasses.replace(
        sim_config_for_variant("lci"),
        name="lci_rnr_storm" if storm else "lci_rnr",
        rnr_storm=storm,
        limits=ResourceLimits(recv_slots=1),
    )


def test_rnr_storm_charges_retries_and_loses_nothing():
    """ROADMAP follow-up (§3.1): storm mode retransmits RNR'd arrivals
    under exponential backoff on t_rnr_retry — counted per retry, slower
    than free redelivery-on-reap, and still lossless."""
    free = flood(_rnr_cfg(False), msg_size=64, nthreads=8, nmsgs=300, max_seconds=4.0)
    storm = flood(_rnr_cfg(True), msg_size=64, nthreads=8, nmsgs=300, max_seconds=4.0)
    assert free.rnr_events > 0 and free.rnr_retries == 0  # default: free redelivery
    assert storm.rnr_retries > 0  # every retransmission counted
    assert storm.messages == 300  # retried, never lost
    assert storm.elapsed > free.elapsed  # retries burn wire time
    assert storm.rnr_events >= free.rnr_events  # refused retries re-count


def test_rnr_storm_flag_is_inert_without_recv_slots():
    """Unbounded model bit-identical: the storm flag takes no code path
    unless limits.recv_slots bounds the receive side."""
    base = sim_config_for_variant("lci")
    r0 = flood(base, msg_size=64, nthreads=8, nmsgs=300)
    r1 = flood(dataclasses.replace(base, rnr_storm=True), msg_size=64, nthreads=8, nmsgs=300)
    assert (r0.elapsed, r0.messages, r0.rnr_events, r0.rnr_retries) == (
        r1.elapsed, r1.messages, r1.rnr_events, r1.rnr_retries,
    )


def test_rnr_storm_deterministic():
    cfg = _rnr_cfg(True)
    r1 = flood(cfg, msg_size=64, nthreads=8, nmsgs=300, max_seconds=4.0)
    r2 = flood(cfg, msg_size=64, nthreads=8, nmsgs=300, max_seconds=4.0)
    assert (r1.elapsed, r1.rnr_retries) == (r2.elapsed, r2.rnr_retries)


def test_rnr_retries_in_injection_stats():
    cfg = _rnr_cfg(True)
    world = SimWorld(2, 4, cfg)
    assert "rnr_retries" in world.injection_stats()


# ---------------------------------- sim_config_for_variant (regression)
def test_sim_config_resolves_family_members_via_registry():
    """The fix: parameterized family names resolve through the registry,
    and every shared axis is carried over — not just the fixed names."""
    prg = sim_config_for_variant("lci_prg2")
    assert prg.progress_workers == 2 and prg.progress_mode == "implicit"
    b8 = sim_config_for_variant("lci_b8")
    assert b8.limits is VARIANTS["lci_b8"].limits  # SAME object, never a copy
    eager = sim_config_for_variant("lci_eager_32k")
    assert eager.eager_threshold == 32 * 1024
    with pytest.raises(KeyError):
        sim_config_for_variant("lci_prgx")


def test_shared_config_fields_exhaustive():
    """Drift guard: every LCIPPConfig axis except the name must be mapped
    into SimConfig (a new functional knob that the DES silently ignores is
    exactly the bug the one-engine refactor exists to prevent)."""
    lci_fields = {f.name for f in dataclasses.fields(LCIPPConfig)} - {"name"}
    assert lci_fields == set(SHARED_CONFIG_FIELDS)
    sim_fields = {f.name for f in dataclasses.fields(SimConfig)}
    assert set(SHARED_CONFIG_FIELDS) <= sim_fields


# ------------------------------------------------- the check_api gate
def test_background_work_is_engine_thin():
    """Both functional parcelports' background_work must be thin run_step
    calls (the tools/check_api.py CI gate, asserted here as a test)."""
    for cls in (LCIParcelport, MPIParcelport):
        assert "run_step" in cls.background_work.__code__.co_names


def test_check_api_engine_gate_green():
    import importlib.util
    from pathlib import Path

    spec = importlib.util.spec_from_file_location(
        "check_api", Path(__file__).resolve().parent.parent / "tools" / "check_api.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    failures: list = []
    mod.check_progress_engine(failures)
    assert failures == []
