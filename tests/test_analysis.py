"""The concurrency static-analysis subsystem (ISSUE 10).

Per-pass fixture modules: each known-bad fixture is caught with a
witness, each clean twin stays quiet; the ported gates catch the aliased
imports and multi-line calls the old line-greps provably missed; the
whole repo runs clean against the reviewed baseline; and the runtime
lockset sanitizer reports seeded races while blessing the shipped lock
discipline.
"""
from __future__ import annotations

import json
import subprocess
import sys
import threading
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

from repro.analysis.registry import (
    AnalysisContext,
    all_passes,
    load_baseline,
    run_passes,
    split_findings,
)
from repro.analysis import sanitizer


def ctx_of(**sources):
    """Fixture context: keyword name → source (dots in names via __)."""
    return AnalysisContext.from_sources(
        {k.replace("__", "/") + ".py": textwrap.dedent(v) for k, v in sources.items()}
    )


def findings_of(ctx, pass_id):
    return run_passes(ctx, [pass_id])


# ===================================================== pass 1: lock order
CYCLE_SRC = """
    import threading

    class Pair:
        def __init__(self):
            self.l1 = threading.Lock()
            self.l2 = threading.Lock()

        def forward(self):
            with self.l1:
                with self.l2:
                    pass

        def backward(self):
            with self.l2:
                with self.l1:
                    pass
"""

CLEAN_ORDER_SRC = """
    import threading

    class Pair:
        def __init__(self):
            self.l1 = threading.Lock()
            self.l2 = threading.Lock()

        def forward(self):
            with self.l1:
                with self.l2:
                    pass

        def also_forward(self):
            with self.l1:
                with self.l2:
                    pass
"""


def test_lock_order_cycle_caught_with_witness():
    found = findings_of(ctx_of(fx__cycle=CYCLE_SRC), "lock-order")
    cycles = [f for f in found if f.key.startswith("cycle:")]
    assert len(cycles) == 1, found
    f = cycles[0]
    assert "Pair.l1" in f.message and "Pair.l2" in f.message
    # full witness path: one edge per hop, each naming the acquiring function
    assert len(f.witness) == 2
    assert any("forward" in w for w in f.witness)
    assert any("backward" in w for w in f.witness)


def test_lock_order_clean_twin_quiet():
    assert findings_of(ctx_of(fx__clean=CLEAN_ORDER_SRC), "lock-order") == []


def test_lock_order_transitive_cycle_caught():
    """Reordering nested acquisitions ACROSS functions (caller holds A,
    callee takes B; elsewhere the nesting is B→A) still cycles."""
    src = """
        import threading

        class Pair:
            def __init__(self):
                self.l1 = threading.Lock()
                self.l2 = threading.Lock()

            def take_l2(self):
                with self.l2:
                    pass

            def forward(self):
                with self.l1:
                    self.take_l2()

            def backward(self):
                with self.l2:
                    with self.l1:
                        pass
    """
    found = findings_of(ctx_of(fx__trans=src), "lock-order")
    cycles = [f for f in found if f.key.startswith("cycle:")]
    assert len(cycles) == 1, found
    assert any("take_l2" in w for w in cycles[0].witness)


def test_lock_order_try_acquire_then_return_is_not_a_self_cycle():
    """The LCIDevice._acquire idiom: a try-acquire branch that RETURNS
    must not leak its held-set into the unconditional acquire below."""
    src = """
        import threading

        class Dev:
            def __init__(self):
                self.coarse = threading.Lock()

            def _acquire(self, try_only=False):
                if try_only:
                    ok = self.coarse.acquire(blocking=False)
                    return ok
                self.coarse.acquire()
                return True
    """
    assert findings_of(ctx_of(fx__tryacq=src), "lock-order") == []


# ============================================ pass 2: blocking under lock
BLOCKING_SRC = """
    import threading
    import time

    class Engine:
        def __init__(self):
            self.lk = threading.Lock()

        def step(self):
            with self.lk:
                time.sleep(0.01)
"""

BLOCKING_TRANSITIVE_SRC = """
    import threading
    import time

    class Engine:
        def __init__(self):
            self.lk = threading.Lock()

        def _drive(self):
            time.sleep(0.01)

        def step(self):
            with self.lk:
                self._drive()
"""

BLOCKING_CLEAN_SRC = """
    import threading
    import time

    class Engine:
        def __init__(self):
            self.lk = threading.Lock()

        def step(self):
            with self.lk:
                n = 1
            time.sleep(0.01)

        def joiner(self, t):
            with self.lk:
                t.join(timeout=0.5)
"""


def test_blocking_under_lock_caught():
    found = findings_of(ctx_of(fx__blk=BLOCKING_SRC), "blocking-under-lock")
    assert len(found) == 1 and "sleep" in found[0].message
    assert "Engine.lk" in found[0].message


def test_blocking_under_lock_transitive_with_chain():
    found = findings_of(ctx_of(fx__blkt=BLOCKING_TRANSITIVE_SRC), "blocking-under-lock")
    assert len(found) == 1, found
    # witness chain walks through the callee to the sleep site
    assert any("_drive" in w for w in found[0].witness)


def test_blocking_under_lock_clean_twin_quiet():
    """Blocking outside the lock and timeout-bounded joins are fine."""
    assert findings_of(ctx_of(fx__blkc=BLOCKING_CLEAN_SRC), "blocking-under-lock") == []


# ============================================ pass 3: unchecked PostStatus
POST_SRC = """
    def fire_and_forget(dev, data):
        dev.post_send(1, 0, 7, data, None)

    def checked(dev, data):
        st = dev.post_send(1, 0, 7, data, None)
        return st

    def parked(dev, throttle, data):
        throttle(lambda: dev.post_put_signal(1, 0, data, None))
"""


def test_unchecked_post_status_caught_and_consumers_quiet():
    found = findings_of(ctx_of(fx__post=POST_SRC), "unchecked-post-status")
    assert len(found) == 1, found
    assert "fire_and_forget" in found[0].message and "post_send" in found[0].message


# ============================================ pass 4: capability dominance
CAP_SRC = """
    class Proto:
        def __init__(self, dev):
            self._use_put = dev.capabilities.one_sided_put

        def good(self, dev, data):
            if self._use_put:
                return dev.post_put_signal(0, 0, data, None)
            return dev.post_send(0, 0, 1, data, None)

        def good_negated(self, dev, data):
            if not self._use_put:
                return dev.post_send(0, 0, 1, data, None)
            else:
                return dev.post_put_signal(0, 0, data, None)

        def bad(self, dev, data):
            return dev.post_put_signal(0, 0, data, None)
"""


def test_capability_dominance_undominated_put_caught():
    found = findings_of(ctx_of(fx__cap=CAP_SRC), "capability-dominance")
    assert len(found) == 1, found
    assert "bad" in found[0].key


def test_capability_dominance_wrong_branch_caught():
    """A put on the NEGATIVE side of the capability check is a bug, not
    a dominated site — polarity matters, mere textual proximity (the old
    gate's 'one_sided_put appears somewhere in the file') does not."""
    src = """
        class Proto:
            def __init__(self, dev):
                self._use_put = dev.capabilities.one_sided_put

            def inverted(self, dev, data):
                if not self._use_put:
                    return dev.post_put_signal(0, 0, data, None)
                return dev.post_send(0, 0, 1, data, None)
    """
    found = findings_of(ctx_of(fx__capn=src), "capability-dominance")
    assert len(found) == 1, found


# ============================================== pass 5: thread ownership
def test_thread_ownership_rogue_spawn_caught_and_nursery_quiet():
    src = """
        import threading

        def rogue(fn):
            t = threading.Thread(target=fn)
            t.start()

        def good(membership, fn):
            return membership.spawn_worker(fn)
    """
    found = findings_of(ctx_of(fx__rogue=src), "thread-ownership")
    assert len(found) == 1 and "threading.Thread" in found[0].message


def test_thread_ownership_catches_aliased_thread_old_gate_missed():
    """`from threading import Thread as T; T(target=...)` — neither of
    the old gate's needles ('threading.Thread(' / 'Thread(target=')
    appears in the source, but the call-graph resolution catches it."""
    src = """
        from threading import Thread as T

        def rogue(fn):
            worker = T(target=fn)
            worker.start()
    """
    plain = textwrap.dedent(src)
    assert "threading.Thread(" not in plain and "Thread(target=" not in plain  # old gate blind
    found = findings_of(ctx_of(fx__alias=src), "thread-ownership")
    assert len(found) == 1, found


# ===================================== ported gates: old-grep blind spots
def test_put_capability_gate_catches_aliased_isinstance():
    src = """
        from repro.core.device import LCIDevice as Dev

        def pick(dev):
            if isinstance(dev, Dev):
                return "put"
            return "send"
    """
    plain = textwrap.dedent(src)
    # the old line-grep required a backend name ON the isinstance line
    assert not any(
        "isinstance(" in ln and "LCIDevice" in ln for ln in plain.splitlines()
    )
    found = findings_of(ctx_of(fx__isal=src), "gate-put-capability")
    assert len(found) == 1 and "LCIDevice" in found[0].message


def test_put_capability_gate_catches_multiline_isinstance():
    src = (
        "def pick(dev):\n"
        "    if isinstance(\n"
        "        dev,\n"
        "        MPISim,\n"
        "    ):\n"
        "        return 'big-lock'\n"
        "    return 'other'\n"
    )
    assert not any(
        "isinstance(" in ln and "MPISim" in ln for ln in src.splitlines()
    )  # old per-line grep was blind to the wrapped call
    found = findings_of(
        AnalysisContext.from_sources({"fx/isml.py": src}), "gate-put-capability"
    )
    assert len(found) == 1 and "MPISim" in found[0].message


def test_serving_gate_catches_aliased_queue_ctor():
    src = """
        from repro.core.completion import LCRQueue as Q

        def build():
            return Q()
    """
    plain = textwrap.dedent(src)
    assert "LCRQueue(" not in plain  # the old forbidden-substring grep missed this
    found = findings_of(
        AnalysisContext.from_sources(
            {"src/repro/serve/fx_handoff.py": textwrap.dedent(src)}
        ),
        "gate-serving-comm",
    )
    assert any(f.key == "queue-ctor:LCRQueue" for f in found), found


def test_serving_gate_clean_twin_quiet():
    src = """
        def build(channel):
            return channel.request(b"x")
    """
    found = findings_of(
        AnalysisContext.from_sources(
            {"src/repro/serve/fx_clean.py": textwrap.dedent(src)}
        ),
        "gate-serving-comm",
    )
    assert found == []


# ======================================================== whole-repo runs
def repo_ctx():
    return AnalysisContext.for_repo(REPO)


def test_whole_repo_zero_nonbaselined_findings():
    """Every pass over the real tree: nothing outside the reviewed
    baseline, and no stale baseline entries either."""
    findings = run_passes(repo_ctx())
    baseline = load_baseline(REPO / "tools" / "analysis_baseline.json")
    new, accepted, stale = split_findings(findings, baseline)
    assert new == [], [f.fingerprint for f in new]
    assert stale == [], stale
    # the deliberate paper exhibits are still present (the baseline is live)
    assert len(accepted) == len(baseline)


def test_registry_has_all_thirteen_passes():
    ids = set(all_passes())
    assert ids == {
        "lock-order",
        "blocking-under-lock",
        "unchecked-post-status",
        "capability-dominance",
        "thread-ownership",
        "gate-resource-mirror",
        "gate-resource-shared",
        "gate-resource-delegates",
        "gate-progress-engine",
        "gate-serving-comm",
        "gate-put-capability",
        "gate-thread-nursery",
        "gate-no-pickle-wire",
    }


def test_fingerprints_are_line_number_free():
    """Moving a function must not invalidate its baseline entry."""
    shifted = "\n\n\n# pushed down\n" + textwrap.dedent(BLOCKING_SRC)
    a = findings_of(ctx_of(fx__blk=BLOCKING_SRC), "blocking-under-lock")
    b = findings_of(
        AnalysisContext.from_sources({"fx/blk.py": shifted}), "blocking-under-lock"
    )
    assert a[0].line != b[0].line
    assert a[0].fingerprint == b[0].fingerprint


def test_analyze_cli_strict_green_and_json():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "analyze.py"), "--strict",
         "--json", "/tmp/analysis_findings.json"],
        capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads(Path("/tmp/analysis_findings.json").read_text())
    assert data["new"] == []
    assert len(data["baselined"]) >= 8


def test_analyze_cli_unknown_pass_errors():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "analyze.py"), "-p", "no-such-pass"],
        capture_output=True, text=True,
    )
    assert out.returncode == 2 and "no-such-pass" in out.stderr


def test_check_api_shim_contract():
    """The CLI shim keeps the historical output format and exit code."""
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_api.py")],
        capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.strip().splitlines()[-1] == "check_api: 0 failure(s)"


# ================================================= true-positive regressions
def test_mpisim_isend_observes_post_status():
    """ISSUE 10 triage: isend discarded post_send's PostStatus.  The
    contract is Always-OK; a falsy status now raises instead of silently
    dropping the send."""
    from repro.core.fabric import Fabric
    from repro.core.mpi_sim import MPISim
    from repro.core.comm.interface import PostStatus

    sim = MPISim(Fabric(2), 0)
    req = sim.isend(1, 5, b"ok")  # normal path still returns the request
    assert req.kind == "send"
    sim.post_send = lambda *a, **k: PostStatus.EAGAIN_QUEUE  # type: ignore[assignment]
    with pytest.raises(RuntimeError, match="EAGAIN_QUEUE"):
        sim.isend(1, 6, b"drop?")


def test_membership_queries_hold_the_lock():
    """ISSUE 10 triage: state/guard_post/admit_completion read (and
    admit_completion mutates) the member table without Membership._lock.
    Under the sanitizer, hammering them against concurrent transitions
    must produce an empty race report."""
    from repro.core.comm.membership import Membership

    was = sanitizer.enabled()
    sanitizer.enable(True)
    sanitizer.reset()
    try:
        m = Membership()  # constructed under sanitize → tracked lock
        stop = threading.Event()

        def transitions():
            rank = 0
            while not stop.is_set():
                m.join(rank)
                m.activate(rank)
                m.begin_drain(rank)
                m.finish_leave(rank)
                rank += 1

        def queries():
            while not stop.is_set():
                m.state(0)
                m.guard_post(0)
                m.admit_completion(0, 0)
                m.view()

        ts = [threading.Thread(target=transitions), threading.Thread(target=queries)]
        for t in ts:
            t.start()
        import time

        time.sleep(0.3)
        stop.set()
        for t in ts:
            t.join()
        assert sanitizer.race_reports() == [], sanitizer.race_reports()
        assert sanitizer.exercised_structures().get("Membership._members", 0) > 0
    finally:
        sanitizer.reset()
        sanitizer.enable(was)


# ======================================================== lockset sanitizer
def test_sanitizer_reports_seeded_race():
    """Deleting a ``with lock`` is exactly what the lockset checker
    exists to catch: two threads touching one structure with no common
    lock → one actionable report naming the structure."""
    was = sanitizer.enabled()
    sanitizer.enable(True)
    sanitizer.reset()
    try:
        lk = sanitizer.make_lock("Mutant._lock")

        def locked():
            for _ in range(20):
                with lk:
                    sanitizer.note_access("Mutant.slots", 1)

        def unlocked():  # the deleted `with lock`
            for _ in range(20):
                sanitizer.note_access("Mutant.slots", 1)

        t1, t2 = threading.Thread(target=locked), threading.Thread(target=unlocked)
        t1.start(); t1.join()
        t2.start(); t2.join()
        reports = sanitizer.race_reports()
        assert len(reports) == 1, reports
        assert reports[0]["struct"] == "Mutant.slots"
        assert len(reports[0]["threads"]) == 2
    finally:
        sanitizer.reset()
        sanitizer.enable(was)


def test_sanitizer_blesses_shmem_segment_discipline():
    """The shipped ShmemSegment lock discipline survives two-threaded
    alloc/commit/announce/pop/read/free traffic with zero reports, and
    the shared structures show up as exercised."""
    was = sanitizer.enabled()
    sanitizer.enable(True)
    sanitizer.reset()
    try:
        from repro.core.comm.shmem import ShmemSegment

        seg = ShmemSegment(nslots=8, slot_size=64)
        try:
            def producer():
                for i in range(200):
                    idx = seg.alloc()
                    if idx is None:
                        continue
                    seg.write(idx, 1, 0, 0, i, b"x" * 8)
                    seg.commit(idx, 1)
                    seg.announce(idx)

            def consumer():
                for _ in range(400):
                    idx = seg.pop_announced()
                    if idx is not None:
                        seg.read(idx)
                        seg.free(idx)

            ts = [threading.Thread(target=producer), threading.Thread(target=consumer)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert sanitizer.race_reports() == [], sanitizer.race_reports()
            ex = sanitizer.exercised_structures()
            assert ex.get("ShmemSegment.slots", 0) > 0
            assert ex.get("ShmemSegment.rxq", 0) > 0
        finally:
            seg.close()
    finally:
        sanitizer.reset()
        sanitizer.enable(was)


def test_sanitizer_disabled_is_inert():
    assert not sanitizer.enabled() or True  # state restored by other tests
    was = sanitizer.enabled()
    sanitizer.enable(False)
    try:
        lk = sanitizer.make_lock("X")
        assert isinstance(lk, type(threading.Lock()))
        sanitizer.note_access("X.y", 0)
        assert sanitizer.race_reports() == []
    finally:
        sanitizer.enable(was)
