"""Training substrate: optimizer, microbatching, compression, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.optim import OptHParams, adamw_init, adamw_update, global_norm, warmup_cosine
from repro.train import TrainConfig, init_train_state, make_train_step

CFG = SMOKES["tinyllama-1.1b"]


def make_batch(rng, B=4, S=32, cfg=CFG):
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def test_warmup_cosine_schedule():
    hp = OptHParams(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10, total_steps=100)
    lrs = [float(warmup_cosine(jnp.asarray(s), hp)) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9  # linear warmup
    assert abs(lrs[2] - 1e-3) < 1e-6  # peak
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-5) < 1e-6  # floor


def test_adamw_moves_params_and_clips():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
    hp = OptHParams(grad_clip=1.0, warmup_steps=0, total_steps=10)
    new_p, new_opt, metrics = adamw_update(grads, opt, params, hp)
    assert float(metrics["grad_norm"]) > 1.0
    assert not jnp.allclose(new_p["w"], params["w"])
    assert int(new_opt["count"]) == 1


def test_loss_decreases_all_variants():
    hp = OptHParams(lr_peak=1e-2, warmup_steps=2, total_steps=20)
    for tc in (
        TrainConfig(microbatches=1, remat="none"),
        TrainConfig(microbatches=2, remat="dots"),
        TrainConfig(microbatches=1, remat="full", grad_sync="int8_ef"),
    ):
        rng = jax.random.PRNGKey(0)
        state = init_train_state(rng, CFG, tc)
        step = jax.jit(make_train_step(CFG, hp, tc))
        batch = make_batch(rng)
        first = last = None
        for _ in range(8):
            state, met = step(state, batch)
            if first is None:
                first = float(met["loss"])
            last = float(met["loss"])
        assert last < first, f"{tc}: {first} -> {last}"


def test_microbatch_equivalence():
    """mb=1 and mb=2 produce (nearly) the same update."""
    hp = OptHParams(lr_peak=1e-3, warmup_steps=0, total_steps=10)
    rng = jax.random.PRNGKey(1)
    batch = make_batch(rng, B=4)
    outs = {}
    for m in (1, 2):
        tc = TrainConfig(microbatches=m, remat="none")
        state = init_train_state(jax.random.PRNGKey(7), CFG, tc)
        step = jax.jit(make_train_step(CFG, hp, tc))
        state, met = step(state, batch)
        outs[m] = (state["params"]["embed"], float(met["grad_norm"]))
    diff = float(jnp.max(jnp.abs(outs[1][0].astype(jnp.float32) - outs[2][0].astype(jnp.float32))))
    assert diff < 2e-2  # bf16 params, tiny numerical drift allowed
    assert abs(outs[1][1] - outs[2][1]) / outs[1][1] < 0.05


def test_int8_ef_compression_unbiased():
    from repro.train.grad_sync import compress_grads_int8_ef

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    ef = {"w": jnp.zeros((64, 64), jnp.float32)}
    # repeated compression of the same gradient: EF keeps the running sum
    # of applied updates close to the true accumulated gradient
    applied = jnp.zeros((64, 64))
    for _ in range(16):
        deq, ef = compress_grads_int8_ef(g, ef)
        applied = applied + deq["w"]
    err = float(jnp.max(jnp.abs(applied / 16 - g["w"])))
    assert err < 2e-2


def test_global_norm():
    t = {"a": jnp.ones((3,)) * 2.0, "b": jnp.ones((4,)) * 1.0}
    assert abs(float(global_norm(t)) - np.sqrt(12 + 4)) < 1e-5


def test_compress_grads_tuple_leaf_containers_and_int_dtype():
    """Regression (ISSUE 5): the result split used
    ``is_leaf=lambda t: isinstance(t, tuple)``, which stopped at a pytree
    whose own leaf container is a tuple and silently mixed dequantized
    values with the error-feedback state.  The transpose-based split keeps
    any structure intact; int-dtype leaves quantize through float32."""
    from repro.train.grad_sync import compress_grads_int8_ef

    g = {
        "w": (jnp.linspace(-1.0, 1.0, 12).reshape(3, 4), jnp.arange(4, dtype=jnp.int32)),
        "b": jnp.ones((2,), jnp.float32),
    }
    ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    deq, new_ef = compress_grads_int8_ef(g, ef)
    assert jax.tree.structure(deq) == jax.tree.structure(g)
    assert jax.tree.structure(new_ef) == jax.tree.structure(g)
    # per-leaf identity: dequantized + residual == original (+0 ef)
    for d, e, orig in zip(jax.tree.leaves(deq), jax.tree.leaves(new_ef), jax.tree.leaves(g)):
        np.testing.assert_allclose(
            np.asarray(d) + np.asarray(e), np.asarray(orig, np.float32), atol=1e-6
        )
    # the int leaf's DEQUANTIZED values sit in the int leaf's slot (the
    # old split put the error tensor there), within the int8 grid
    np.testing.assert_allclose(np.asarray(deq["w"][1]), np.arange(4), atol=0.05)
    # still jit-compatible (structure-only transform)
    jdeq, _ = jax.jit(compress_grads_int8_ef)(g, ef)
    assert jax.tree.structure(jdeq) == jax.tree.structure(g)


def test_grad_sync_handoff_over_comm_interface():
    """The host-side DP gradient exchange rides CommInterface verbs: each
    rank packs its compressed grads to bytes, ships them through the
    CollectiveComm channel, and averages with the peer's — identical to
    the direct in-memory average."""
    from repro.core.comm.collective import CommChannel
    from repro.train.grad_sync import compress_grads_int8_ef, pack_grads, unpack_grads

    rng = np.random.default_rng(1)
    grads = [
        {"w": (jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
               jnp.asarray(rng.standard_normal((8,)), jnp.float32))}
        for _ in range(2)
    ]
    deq = []
    for g in grads:
        ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
        deq.append(compress_grads_int8_ef(g, ef)[0])
    channel = CommChannel()
    channel.send_request(pack_grads(deq[0]))  # rank 0 -> rank 1
    channel.send_response(pack_grads(deq[1]))  # rank 1 -> rank 0
    for _ in range(4):
        channel.progress()

    def reap_recv(source):  # skip send-completion records
        for _ in range(8):
            rec = channel.reap(source)
            if rec is not None and rec.op == "recv":
                return rec
        raise AssertionError(f"no arrived payload on {source}")

    from_peer0 = unpack_grads(reap_recv("request").data, deq[1])
    from_peer1 = unpack_grads(reap_recv("response").data, deq[0])
    avg_comm = jax.tree.map(lambda a, b: (a + b) / 2, deq[0], from_peer1)
    avg_direct = jax.tree.map(lambda a, b: (a + b) / 2, deq[0], deq[1])
    for got, want in zip(jax.tree.leaves(avg_comm), jax.tree.leaves(avg_direct)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the peer's view agrees
    avg_peer = jax.tree.map(lambda a, b: (a + b) / 2, from_peer0, deq[1])
    for got, want in zip(jax.tree.leaves(avg_peer), jax.tree.leaves(avg_direct)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
