"""Threshold-aware aggregation (ISSUE 2 tentpole): the drain packs parcels
up to ``eager_threshold`` — an aggregate exactly at the threshold ships as
ONE eager message, one byte over splits the batch, oversize parcels take
rendezvous alone, and the delivered payload set is identical with
aggregation disabled, classic, or threshold-aware."""
from collections import deque

import pytest

from repro.core.harness import deliver_payloads
from repro.core.lci_parcelport import LCIParcelport, LCIPPConfig
from repro.core.parcel import Chunk, Parcel, serialize_action
from repro.core.parcelport import (
    AGG_PER_PARCEL_BYTES,
    AGG_PREAMBLE_BYTES,
    World,
    aggregate_parcels,
    aggregate_projected_bytes,
)
from repro.core.variants import VARIANTS


def _nzc_parcel(pid: int, size: int) -> Parcel:
    return Parcel(parcel_id=pid, source=0, dest=1, nzc_chunk=Chunk(b"x" * size))


def _agg_world(cfg: LCIPPConfig):
    world = World(2, lambda loc, fab: LCIParcelport(loc, fab, cfg), devices_per_rank=cfg.ndevices)
    got: list = []
    world.localities[1].register_action("sink", lambda *a: got.append(a))
    return world, got


def _drain_burst(world, parcels):
    """Pre-load the per-destination queue (as racing senders would), then
    one send drains the whole burst through the batching logic."""
    pp = world.localities[0].parcelport
    q = pp._agg_queues.setdefault(1, deque())
    for p in parcels[:-1]:
        q.append((p, None))
    pp.send(1, parcels[-1])
    world.drain()
    return pp


# ------------------------------------------------------- projection helper
def test_projected_bytes_matches_real_aggregate():
    parcels = [_nzc_parcel(i, 100 + i) for i in range(4)]
    agg = aggregate_parcels(parcels)
    assert aggregate_projected_bytes(parcels) == agg.total_bytes


def test_agg_batches_exact_and_one_over():
    """An aggregate landing exactly on the limit stays one batch; one byte
    over splits it."""
    parcels = [(_nzc_parcel(i, 100), None) for i in range(2)]
    exact = AGG_PREAMBLE_BYTES + 2 * (AGG_PER_PARCEL_BYTES + 100)
    cfg = LCIPPConfig(name="t", aggregation=True, agg_eager=True, eager_threshold=exact)
    world, _ = _agg_world(cfg)
    pp = world.localities[0].parcelport
    assert len(pp._agg_batches(list(parcels))) == 1
    pp.agg_limit_bytes = exact - 1
    assert len(pp._agg_batches(list(parcels))) == 2


# ------------------------------------------------- world-level edge cases
def _sink_parcels(n: int, payload: int):
    return [
        serialize_action(1000 + i, 0, 1, "sink", (bytes([i]) * payload,), zero_copy_threshold=1 << 30)
        for i in range(n)
    ]


def test_aggregate_exactly_at_threshold_ships_one_eager_message():
    parcels = _sink_parcels(4, 900)
    need = aggregate_projected_bytes(parcels)
    cfg = LCIPPConfig(name="t_exact", aggregation=True, agg_eager=True, eager_threshold=need)
    world, got = _agg_world(cfg)
    _drain_burst(world, parcels)
    assert len(got) == 4
    st = world.fabric.stats
    assert st.eager_msgs == 1 and st.rendezvous_msgs == 0


def test_aggregate_one_byte_over_threshold_splits_without_spilling():
    """One byte over the threshold: the drain splits into two batches, and
    BOTH still ship eager — never a rendezvous spill."""
    parcels = _sink_parcels(4, 900)
    need = aggregate_projected_bytes(parcels)
    cfg = LCIPPConfig(name="t_over", aggregation=True, agg_eager=True, eager_threshold=need - 1)
    world, got = _agg_world(cfg)
    _drain_burst(world, parcels)
    assert len(got) == 4
    st = world.fabric.stats
    assert st.eager_msgs == 2 and st.rendezvous_msgs == 0


def test_oversize_parcel_gets_own_batch_and_rendezvous():
    """A single parcel over the threshold takes the rendezvous path alone;
    its eager-sized neighbours still coalesce eagerly."""
    small = _sink_parcels(3, 900)
    big = serialize_action(2000, 0, 1, "sink", (b"B" * 40_000,), zero_copy_threshold=1024)
    cfg = VARIANTS["lci_agg_eager"]
    world, got = _agg_world(cfg)
    _drain_burst(world, small[:2] + [big] + small[2:])
    assert sorted(len(a[0]) for a in got) == [900, 900, 900, 40_000]
    st = world.fabric.stats
    assert st.rendezvous_msgs >= 2  # header + zc follow-up for the big one
    assert st.eager_msgs >= 1  # the small ones still merged eagerly


def test_unbounded_merge_spills_same_burst_into_rendezvous():
    """Control for the above: the classic unbounded merge pushes the same
    eager-sized burst over the threshold onto the rendezvous path."""
    parcels = _sink_parcels(32, 3_000)
    cfg = VARIANTS["lci_agg_eager"].variant(name="t_unbounded", agg_eager=False)
    world, got = _agg_world(cfg)
    _drain_burst(world, parcels)
    assert len(got) == 32
    assert world.fabric.stats.rendezvous_msgs > 0

    cfg2 = VARIANTS["lci_agg_eager"]
    world2, got2 = _agg_world(cfg2)
    _drain_burst(world2, _sink_parcels(32, 3_000))
    assert len(got2) == 32
    assert world2.fabric.stats.rendezvous_msgs == 0


@pytest.mark.parametrize("other", ["lci", "lci_agg_eager"])
def test_agg_eager_delivers_identical_payloads(other):
    """Aggregation disabled vs threshold-aware: identical delivered payload
    multisets (content, not just lengths)."""
    payloads = [bytes([i % 251]) * (150 * (i + 1)) for i in range(12)]
    _, got = deliver_payloads(other, payloads)
    assert sorted(a[0] for a in got) == sorted(payloads)


def test_agg_eager_under_bounded_fabric():
    """Threshold-aware aggregation composes with bounded injection: tiny
    ring + pool, burst of eager-sized parcels — backpressure fires, the
    retry queue drains, everything arrives."""
    world, got = deliver_payloads(
        "lci_agg_eager",
        [bytes([i]) * 2_000 for i in range(40)],
        fabric_kwargs=dict(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=32_768),
    )
    assert len(got) == 40
    assert world.fabric.stats.backpressure_events > 0
    for loc in world.localities:
        assert loc.parcelport.retry_queue_depth() == 0
