"""Native-layer semantics: RNR + retry, one-sided put, tag matching."""
import pytest

from repro.core.completion import LCRQueue, Synchronizer
from repro.core.device import LCIDevice, LockMode
from repro.core.fabric import Fabric


def mk_pair(devices_per_rank=1, lock_mode=LockMode.NONE):
    fab = Fabric(2, devices_per_rank=devices_per_rank)
    cq0, cq1 = LCRQueue(), LCRQueue()
    d0 = LCIDevice(fab.device(0, 0), lock_mode=lock_mode, put_target_comp=cq0)
    d1 = LCIDevice(fab.device(1, 0), lock_mode=lock_mode, put_target_comp=cq1)
    return fab, d0, d1, cq0, cq1


def drain(*devs, rounds=50):
    for _ in range(rounds):
        moved = False
        for d in devs:
            if d.progress():
                moved = True
        if not moved:
            return


def test_rnr_retry_semantics():
    """Two-sided send with no remote posted receive RNRs, then retries."""
    fab = Fabric(2, devices_per_rank=1, recv_slots=0)
    # raw fabric: no prepost (LCIDevice preposts; use NetDevice directly)
    nd0, nd1 = fab.device(0), fab.device(1)
    nd0.post_send(1, 0, b"hello")
    assert fab.stats.rnr_events == 1
    assert nd1.cq_depth() == 0
    nd1.post_recv()
    assert nd0.hw_progress()  # retry succeeds now
    comps = nd1.poll_cq()
    assert len(comps) == 1 and comps[0].data == b"hello"


def test_put_dynamic_no_receive_needed():
    fab, d0, d1, cq0, cq1 = mk_pair()
    sent = Synchronizer()
    d0.put_dynamic(1, 0, b"payload", sent)
    drain(d0, d1)
    rec = cq1.pop()
    assert rec is not None and rec.op == "put_recv" and rec.data == b"payload"
    assert sent.test() is not None  # local send completion


def test_tag_matching_and_any_source():
    fab, d0, d1, cq0, cq1 = mk_pair()
    got = LCRQueue()
    d1.post_recv(src_rank=0, tag=7, comp=got)
    d0.post_send(1, 0, tag=7, data=b"tagged", comp=Synchronizer())
    drain(d0, d1)
    rec = got.pop()
    assert rec.op == "recv" and rec.tag == 7 and rec.data == b"tagged"
    # any-source
    got2 = LCRQueue()
    d1.post_recv(src_rank=-1, tag=9, comp=got2)
    d0.post_send(1, 0, tag=9, data=b"any", comp=Synchronizer())
    drain(d0, d1)
    assert got2.pop().data == b"any"


def test_unexpected_message_queue():
    """Send arrives before the receive is posted: matched on post."""
    fab, d0, d1, cq0, cq1 = mk_pair()
    d0.post_send(1, 0, tag=3, data=b"early", comp=Synchronizer())
    drain(d0, d1)
    got = LCRQueue()
    d1.post_recv(src_rank=0, tag=3, comp=got)
    rec = got.pop()
    assert rec is not None and rec.data == b"early"


def test_try_lock_progress_contention():
    fab, d0, d1, cq0, cq1 = mk_pair(lock_mode=LockMode.TRY)
    d0._coarse.acquire()  # simulate a holder
    assert d0.progress() is False  # try-lock gives up
    assert d0.lock_failures >= 1
    d0._coarse.release()
    d0.progress()  # now fine


def test_multi_device_isolation():
    fab = Fabric(2, devices_per_rank=2)
    cq = LCRQueue()
    send_dev = LCIDevice(fab.device(0, 1), put_target_comp=None)
    recv_dev = LCIDevice(fab.device(1, 1), put_target_comp=cq)
    other = LCIDevice(fab.device(1, 0), put_target_comp=LCRQueue())
    send_dev.put_dynamic(1, 1, b"dev1", Synchronizer())
    drain(send_dev, recv_dev, other)
    assert cq.pop().data == b"dev1"
    assert other.put_target_comp.pop() is None  # landed on the right device
