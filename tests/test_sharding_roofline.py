"""Sharding rules/specs + roofline HLO analysis."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SMOKES, get_config
from repro.models import init_params
from repro.roofline.analysis import decode_min_bytes, model_flops
from repro.roofline.hlo_parse import analyze_hlo
from repro.sharding.logical import ShardingRules, sanitize_spec
from repro.sharding.params import _zero_extend, batch_specs, param_specs


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_rules_dedup_axes():
    r = ShardingRules({"a": "model", "b": "model", "c": ("data", "model")})
    assert r.spec("a", "b") == P("model", None)  # one axis, one dim
    assert r.spec("c", "a") == P(("data", "model"), None)


def test_sanitize_divisibility():
    mesh = FakeMesh({"data": 4, "model": 8})
    spec = P("data", "model", None)
    assert sanitize_spec(spec, (8, 16, 3), mesh) == P("data", "model", None)
    assert sanitize_spec(spec, (6, 16, 3), mesh) == P(None, "model", None)
    assert sanitize_spec(P(("data", "model")), (32,), mesh) == P(("data", "model"))
    assert sanitize_spec(P(("data", "model")), (12,), mesh) == P(None)


def test_zero_extend_moments():
    mesh = FakeMesh({"data": 4, "model": 8})
    # free dim 0 divisible by data → gets it
    assert _zero_extend(P(None, "model"), (8, 16), ("data",), mesh) == P("data", "model")
    # nothing divisible → unchanged
    assert _zero_extend(P(None,), (7,), ("data",), mesh) == P(None)
    # already data-sharded → unchanged
    assert _zero_extend(P("data",), (8,), ("data",), mesh) == P("data")


@pytest.mark.parametrize("name", ["qwen2-7b", "deepseek-moe-16b", "mamba2-130m", "whisper-large-v3"])
def test_param_specs_cover_tree(name):
    cfg = SMOKES[name]
    params = jax.eval_shape(lambda r: init_params(r, cfg), jax.random.PRNGKey(0))
    rules = ShardingRules({"vocab": "model", "heads": "model", "mlp": "model",
                           "experts": "model", "embed": None, "kv_heads": "model",
                           "head_dim": None, "latent": None})
    specs = param_specs(params, rules)
    flat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat) == len(jax.tree.leaves(params))
    # embedding must be vocab-sharded
    assert specs["embed"] == P("model", None)


def test_batch_specs():
    rules = ShardingRules({"batch": ("pod", "data"), "seq": None, "embed": None})
    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "positions": jax.ShapeDtypeStruct((8,), jnp.int32),
        "frames": jax.ShapeDtypeStruct((8, 10, 4), jnp.float32),
    }
    specs = batch_specs(batch, rules)
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["positions"] == P(("pod", "data"))
    assert specs["frames"] == P(("pod", "data"), None, None)


# ------------------------------------------------------------------ roofline
SYNTH_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %ar = f32[8,8]{1,0} all-reduce(%gte), replica_groups={}, to_apply=%add
  %d = f32[8,8]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%c, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %cmp = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[16,8]{1,0} all-gather(%a), dimensions={0}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_parse_loop_multipliers():
    a = analyze_hlo(SYNTH_HLO)
    assert a.while_trip_counts == {"body": 5}
    # all-reduce inside the ×5 body: 8·8·4 B × 5; all-gather outside: 16·8·4
    assert a.collective_bytes["all-reduce"] == 8 * 8 * 4 * 5
    assert a.collective_bytes["all-gather"] == 16 * 8 * 4
    # dot: 2·(8·8)·8 flops × 5
    assert a.dot_flops == 2 * 64 * 8 * 5


def test_model_flops_shapes():
    mf_train = model_flops("tinyllama-1.1b", "train_4k")
    n = get_config("tinyllama-1.1b").param_count()
    assert abs(mf_train - 6 * n * 4096 * 256) / mf_train < 1e-6
    assert model_flops("tinyllama-1.1b", "decode_32k") == 2 * n * 128


def test_decode_min_bytes_sane():
    b_full = decode_min_bytes("qwen2-7b", "decode_32k")
    # params (2·7.6e9) + 28L·128B·32k·4kv·128hd·2·2B ≈ 15.2e9 + 240e9
    assert 2e11 < b_full < 3e11
    b_swa = decode_min_bytes("h2o-danube-3-4b", "decode_32k")
    assert b_swa < b_full  # window cache ≪ full cache
