"""The unified comm layer: interface conformance, cross-backend parity,
legacy variant-name equality, and the API-drift gate.

The paper's point (§2.3/§3.3) is that one communication abstraction can
carry both library families; these tests hold the reproduction to it:
`mpi`, `mpi_a`, `lci`, and `lci_agg_eager` run identical workloads through
the same `CommInterface`-shaped stack and must agree on what was delivered,
and every pre-redesign variant name must resolve to a config equal to its
old hard-coded dict value.
"""
import importlib.util
from pathlib import Path

import pytest

from repro.core.comm import (
    CommInterface,
    CompletionTarget,
    PostStatus,
    ResourceLimits,
    UnsupportedCapabilityError,
)
from repro.core.completion import (
    LCRQueue,
    LockQueue,
    MichaelScottQueue,
    Synchronizer,
    SynchronizerPool,
)
from repro.core.device import LCIDevice, LockMode
from repro.core.fabric import Fabric
from repro.core.harness import deliver_payloads
from repro.core.lci_parcelport import LCIPPConfig
from repro.core.mpi_sim import MPISim
from repro.core.variants import VARIANTS, make_parcelport_factory, variant_names

REPO = Path(__file__).resolve().parent.parent

PARITY_VARIANTS = ["mpi", "mpi_a", "lci", "lci_agg_eager", "collective",
                   "shmem", "shmem_put", "shmem_putq"]
PARITY_PAYLOADS = [bytes([i % 251]) * (7 + 311 * i % 20_000) for i in range(40)]


# ------------------------------------------------------------- conformance
def test_backends_conform_to_comm_interface():
    """Both library families are CommInterface backends: same five verbs,
    different capabilities."""
    fab = Fabric(2, devices_per_rank=1)
    lci = LCIDevice(fab.device(0), put_target_comp=LCRQueue())
    mpi = MPISim(fab, 1)
    assert isinstance(lci, CommInterface)
    assert isinstance(mpi, CommInterface)
    assert lci.capabilities.one_sided_put and lci.capabilities.explicit_progress
    assert lci.capabilities.queue_completion
    caps = mpi.capabilities
    assert not caps.one_sided_put and not caps.queue_completion
    assert not caps.explicit_progress and not caps.bounded_injection


def test_capabilities_reflect_bounded_fabric():
    unbounded = LCIDevice(Fabric(2).device(0))
    bounded = LCIDevice(Fabric(2, limits=ResourceLimits(send_queue_depth=4)).device(0))
    assert not unbounded.capabilities.bounded_injection
    assert bounded.capabilities.bounded_injection


def test_post_status_truthiness_and_kinds():
    assert PostStatus.OK and PostStatus.OK.ok
    assert not PostStatus.EAGAIN_QUEUE and not PostStatus.EAGAIN_BUFFER
    # a full ring and an exhausted pool report DIFFERENT refusals
    fab = Fabric(2, devices_per_rank=1, recv_slots=8,
                 limits=ResourceLimits(send_queue_depth=1, bounce_buffers=1,
                                       bounce_buffer_size=1024))
    nd = fab.device(0)
    assert nd.post_send(1, 0, b"x" * 16, eager=True) is PostStatus.OK
    assert nd.post_send(1, 0, b"y" * 16, eager=True) is PostStatus.EAGAIN_QUEUE
    fab2 = Fabric(2, devices_per_rank=1, recv_slots=8,
                  limits=ResourceLimits(bounce_buffers=1, bounce_buffer_size=1024))
    nd2 = fab2.device(0)
    assert nd2.post_send(1, 0, b"x" * 16, eager=True) is PostStatus.OK
    assert nd2.post_send(1, 0, b"y" * 16, eager=True) is PostStatus.EAGAIN_BUFFER


def test_mpi_backend_rejects_uncapable_path():
    mpi = MPISim(Fabric(2), 0)
    with pytest.raises(UnsupportedCapabilityError):
        mpi.post_put_signal(1, 0, b"data", Synchronizer())


@pytest.mark.parametrize("cls", [LCRQueue, MichaelScottQueue, LockQueue, Synchronizer])
def test_completion_targets_signal_reap(cls):
    """Queues and synchronizers all speak signal()/reap()."""
    target = cls()
    assert isinstance(target, CompletionTarget)
    assert target.reap() is None
    target.signal("item")
    assert target.reap() == "item"
    assert target.reap() is None


def test_synchronizer_pool_reap():
    pool = SynchronizerPool()
    sync = Synchronizer()
    pool.add(sync, payload="ctx")
    assert pool.reap() is None  # nothing signaled yet; re-queued round-robin
    sync.signal("rec")
    assert pool.reap() == ("ctx", "rec")


# ------------------------------------------------------------------ parity
def _run_parity(variant):
    world, got = deliver_payloads(variant, PARITY_PAYLOADS, n_loc=4)
    return world, sorted(len(a[0]) for a in got)


def test_delivery_parity_across_backends():
    """Identical workload, every backend: the same multiset of payloads
    arrives regardless of library family or aggregation strategy."""
    expected = sorted(len(p) for p in PARITY_PAYLOADS)
    for variant in PARITY_VARIANTS:
        _world, lengths = _run_parity(variant)
        assert lengths == expected, f"{variant} delivered {len(lengths)} != {len(expected)}"


@pytest.mark.parametrize("variant", PARITY_VARIANTS)
def test_stats_conservation_after_drain(variant):
    """Nothing invented, nothing lost: after drain, the world-wide sent
    count equals the world-wide received count (aggregates count once on
    both sides), and no parcelport still holds parked work."""
    world, _lengths = _run_parity(variant)
    pps = [loc.parcelport for loc in world.localities]
    sent = sum(pp.stats_sent for pp in pps)
    received = sum(pp.stats_received for pp in pps)
    assert sent == received > 0
    assert not any(pp.pending_work() for pp in pps)
    assert all(pp.retry_queue_depth() == 0 for pp in pps)


# ------------------------------------------- the collective backend (ISSUE 5)
def test_collective_backend_conforms_and_is_honest():
    """CollectiveComm is a full CommInterface backend with HONEST
    capabilities: the JAX collectives layer has no one-sided put, so the
    backend says so instead of emulating one."""
    from repro.core.comm.collective import CollectiveGroup

    comm = CollectiveGroup(2).endpoint(0)
    assert isinstance(comm, CommInterface)
    caps = comm.capabilities
    assert not caps.one_sided_put
    assert caps.queue_completion and caps.explicit_progress
    assert not caps.bounded_injection  # unbounded by default
    with pytest.raises(UnsupportedCapabilityError):
        comm.post_put_signal(1, 0, b"data", Synchronizer())
    bounded = CollectiveGroup(2, limits=ResourceLimits(send_queue_depth=4)).endpoint(0)
    assert bounded.capabilities.bounded_injection


def test_collective_eagain_kinds_surfaced():
    """A full transit ring and an exhausted eager bounce accounting are
    DIFFERENT refusals, exactly as on the fabric-backed device."""
    from repro.core.comm.collective import CollectiveGroup

    ring = CollectiveGroup(2, limits=ResourceLimits(send_queue_depth=1, bounce_buffers=1,
                                                    bounce_buffer_size=1024)).endpoint(0)
    assert ring.post_send(1, 0, 5, b"x" * 16, LCRQueue(), eager=True) is PostStatus.OK
    assert ring.post_send(1, 0, 5, b"y" * 16, LCRQueue(), eager=True) is PostStatus.EAGAIN_QUEUE
    pool = CollectiveGroup(2, limits=ResourceLimits(bounce_buffers=1,
                                                    bounce_buffer_size=1024)).endpoint(0)
    assert pool.post_send(1, 0, 5, b"x" * 16, LCRQueue(), eager=True) is PostStatus.OK
    assert pool.post_send(1, 0, 5, b"y" * 16, LCRQueue(), eager=True) is PostStatus.EAGAIN_BUFFER


def test_collective_roundtrip_matching_and_unexpected_queue():
    from repro.core.comm.collective import CollectiveGroup

    grp = CollectiveGroup(2)
    a, b = grp.endpoint(0), grp.endpoint(1)
    got = LCRQueue()
    b.post_recv(-1, 7, got)  # any-source receive
    a.post_send(1, 0, 7, b"hello", LCRQueue())
    a.progress()  # exchange
    b.progress()  # match
    rec = got.reap()
    assert rec.op == "recv" and rec.data == b"hello" and rec.src_rank == 0
    # arrival beats its receive: parks unexpected, matches on the post
    a.post_send(1, 0, 9, b"late", LCRQueue())
    a.progress()
    b.progress()
    late = LCRQueue()
    b.post_recv(0, 9, late)
    assert late.reap().data == b"late"
    # progress frees the ring slot and signals the send completion
    assert a._inflight == 0 and grp.stats.messages == 2


def test_collective_jax_stage_delivers_identical_bytes():
    """stage='jax' rides every payload through a JAX device buffer (the
    one-host degenerate collective) — bytes must survive unchanged."""
    pytest.importorskip("jax")
    from repro.core.comm.collective import CollectiveGroup

    grp = CollectiveGroup(2, stage="jax")
    a, b = grp.endpoint(0), grp.endpoint(1)
    payload = bytes(range(256)) * 33
    got = LCRQueue()
    b.post_recv(0, 3, got)
    a.post_send(1, 0, 3, payload, LCRQueue())
    a.progress()
    b.progress()
    assert got.reap().data == payload


def test_collective_parcelport_shares_resource_model():
    """variant_limits('collective') flows through the fabric into the one
    CollectiveGroup of the world — the shared ResourceLimits binds the
    collective transport exactly as it binds the fabric."""
    from repro.core.harness import transport_stats

    lim = ResourceLimits(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=65_536)
    world, got = deliver_payloads("collective", [bytes([i]) * 600 for i in range(30)],
                                  fabric_kwargs={"limits": lim})
    assert len(got) == 30
    group = world.fabric._collective_group
    assert group.limits is lim
    st = transport_stats(world)
    assert st is group.stats
    assert st.backpressure_events > 0  # the bound actually bit
    assert sum(loc.parcelport.stats_backpressure_parks for loc in world.localities) > 0


# --------------------------------------------------- legacy name equality
def _expected_legacy_variants():
    """The pre-redesign VARIANTS dict, reconstructed literally (PR 1-2
    definitions).  Every name must resolve to an equal config."""
    expected = {
        "lci": LCIPPConfig(name="lci"),
        "base": LCIPPConfig(name="base"),
        "sendrecv_queue": LCIPPConfig(name="sendrecv_queue", header_mode="sendrecv", header_comp="queue"),
        "sendrecv_sync": LCIPPConfig(name="sendrecv_sync", header_mode="sendrecv", header_comp="sync"),
        "sync": LCIPPConfig(name="sync", followup_comp="sync"),
        "queue_lock": LCIPPConfig(name="queue_lock", cq_kind="lock"),
        "queue_ms": LCIPPConfig(name="queue_ms", cq_kind="ms"),
    }
    ladder = dict(header_mode="sendrecv", header_comp="sync", followup_comp="sync", ndevices=1)
    expected["block"] = LCIPPConfig(name="block", lock_mode=LockMode.BLOCK, progress_mode="implicit", **ladder)
    expected["try"] = LCIPPConfig(name="try", lock_mode=LockMode.TRY, progress_mode="implicit", **ladder)
    expected["try_progress"] = LCIPPConfig(name="try_progress", lock_mode=LockMode.TRY, progress_mode="explicit", **ladder)
    expected["progress"] = LCIPPConfig(name="progress", lock_mode=LockMode.BLOCK, progress_mode="explicit", **ladder)
    expected["block_d2"] = LCIPPConfig(
        name="block_d2", header_mode="sendrecv", header_comp="sync", followup_comp="sync",
        ndevices=2, lock_mode=LockMode.BLOCK, progress_mode="implicit",
    )
    for n in (1, 2, 4, 8, 16, 32):
        expected[f"lci_d{n}"] = LCIPPConfig(name=f"lci_d{n}", ndevices=n)
        expected[f"lci_try_d{n}"] = LCIPPConfig(name=f"lci_try_d{n}", ndevices=n, lock_mode=LockMode.TRY)
    expected["lci_noeager"] = LCIPPConfig(name="lci_noeager", eager_threshold=0)
    for kib in (16, 64):
        expected[f"lci_eager_{kib}k"] = LCIPPConfig(name=f"lci_eager_{kib}k", eager_threshold=kib * 1024)
    expected["lci_eager"] = expected["lci_eager_16k"].variant(name="lci_eager")
    expected["lci_agg_eager"] = LCIPPConfig(
        name="lci_agg_eager", aggregation=True, agg_eager=True, eager_threshold=16 * 1024
    )
    return expected


def test_legacy_variant_names_resolve_to_equal_configs():
    expected = _expected_legacy_variants()
    for name, cfg in expected.items():
        assert VARIANTS[name] == cfg, f"{name} drifted from its pre-redesign config"
    # and every legacy name is still enumerated
    names = set(variant_names())
    assert set(expected) <= names
    assert {"mpi", "mpi_a"} <= names


# -------------------------------------------------- parameterized families
def test_family_members_resolve_without_preregistration():
    cfg = VARIANTS["lci_b8"]
    assert cfg.limits == ResourceLimits(send_queue_depth=8, bounce_buffers=8,
                                        bounce_buffer_size=64 * 1024)
    assert VARIANTS["lci_d7"].ndevices == 7
    assert VARIANTS["lci_try_d3"].lock_mode == LockMode.TRY
    assert VARIANTS["lci_eager_32k"].eager_threshold == 32 * 1024
    assert "lci_b8" in VARIANTS and "lci_bx" not in VARIANTS
    with pytest.raises(KeyError):
        VARIANTS["definitely_not_a_variant"]
    # resolution is cached: one name, one object
    assert VARIANTS["lci_b8"] is cfg
    # the collective family resolves on demand like every other family
    assert VARIANTS["collective_prg3"].progress_workers == 3
    assert VARIANTS["collective"].header_mode == "sendrecv"
    assert {"collective", "collective_prg2"} <= set(variant_names())


def test_family_factory_builds_bounded_world():
    """make_parcelport_factory('lci_b8') + a fabric built from the same
    limits = a world whose injection is actually bounded."""
    factory = make_parcelport_factory("lci_b8")
    assert factory is not None
    world, got = deliver_payloads("lci_b2", [bytes([i]) * 600 for i in range(30)])
    assert len(got) == 30
    assert world.fabric.limits.send_queue_depth == 2
    assert world.fabric.stats.backpressure_events > 0  # the bound bit


def test_des_and_functional_share_family_limits():
    from repro.amtsim.parcelport_sim import sim_config_for_variant

    sim = sim_config_for_variant("lci_b8")
    assert sim.limits == VARIANTS["lci_b8"].limits
    assert sim.send_queue_depth == 8  # legacy knob delegates through


# ------------------------------------------------- aggregate flag, not magic
def _magic_collision_payload():
    """A payload whose serialized nzc chunk STARTS with the aggregate
    framing magic (0xA6): the pickle-length prefix's low byte collides."""
    from repro.core.parcel import serialize_action

    for size in range(120, 1400):
        parcel = serialize_action(1, 0, 1, "sink", (b"Z" * size,), zero_copy_threshold=1 << 20)
        if parcel.nzc_chunk.data[0] == 0xA6:
            return b"Z" * size
    raise AssertionError("no colliding payload size found")


def test_aggregate_detection_is_out_of_band():
    """Found while driving the comm layer end to end: a plain parcel whose
    pickle length put AGG_MAGIC in nzc byte 0 used to be torn apart by
    split_aggregate (struct.error / silent corruption).  Aggregate-ness now
    travels as FLAG_AGGREGATE in the header, so the collision is harmless
    on every variant and path (eager, rendezvous, aggregated)."""
    from repro.core.comm.base import is_aggregate
    from repro.core.parcel import serialize_action

    payload = _magic_collision_payload()
    plain = serialize_action(1, 0, 1, "sink", (payload,), zero_copy_threshold=1 << 20)
    assert plain.nzc_chunk.data[0] == 0xA6 and not is_aggregate(plain)
    for variant in ("lci", "lci_noeager", "mpi", "mpi_a", "lci_agg_eager"):
        _world, got = deliver_payloads(variant, [payload, payload, b"x" * 9])
        assert sorted(len(a[0]) for a in got) == sorted([len(payload), len(payload), 9]), variant


# ------------------------------------------------------------- drift gate
def _load_check_api():
    spec = importlib.util.spec_from_file_location("check_api", REPO / "tools" / "check_api.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_api_gate_green():
    failures: list = []
    _load_check_api().check_api(failures)
    assert not failures, failures


def test_check_api_serving_gate_green():
    """Gate 5: the serving stack hands requests/responses through the
    shared CommInterface and no private hand-off loops have re-grown in
    serve/, launch/serve.py, or the executor."""
    failures: list = []
    _load_check_api().check_serving_comm(failures)
    assert not failures, failures


def test_check_api_put_capability_gate_green():
    """Gate 6 (ISSUE 6): outside the comm backends, nothing selects the
    one-sided put path by concrete backend type — only by the advertised
    Capabilities."""
    failures: list = []
    _load_check_api().check_put_capability(failures)
    assert not failures, failures


def test_check_api_membership_gate_green():
    """Gate 7 (ISSUE 8): worker threads are spawned/joined only through
    the membership nursery, so the lifecycle census stays exact."""
    failures: list = []
    _load_check_api().check_membership_thread_ownership(failures)
    assert not failures, failures
