import os
import sys

# tests see ONE device (the dry-run sets its own 512-device flag in a
# subprocess); keep any user XLA_FLAGS out of the way
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# sibling test helpers (_hypothesis_compat) are importable regardless of how
# pytest was invoked
sys.path.insert(0, os.path.dirname(__file__))

import jax

jax.config.update("jax_enable_x64", False)

import pytest


@pytest.fixture(scope="session", autouse=True)
def _repro_sanitize_session():
    """Under ``REPRO_SANITIZE=1`` (the CI sanitizer leg), assert at session
    end that the lockset checker saw real traffic and found no races in
    the shipped code.  Tests that *seed* violations on purpose snapshot
    and restore the sanitizer state themselves (see test_analysis.py)."""
    yield
    from repro.analysis import sanitizer

    if not sanitizer.enabled():
        return
    report = sanitizer.session_report()
    assert report["exercised"], (
        "REPRO_SANITIZE=1 but no instrumented structure was exercised — "
        "the sanitizer leg did not drive the comm layer"
    )
    assert not report["races"], report["races"]
