"""Per-architecture smoke + decode-consistency tests (assignment f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SMOKES, SHAPES, cell_is_applicable
from repro.models import decode_step, forward_train, init_cache, init_params, loss_fn, prefill

ARCH_NAMES = list(SMOKES)


def make_batch(cfg, rng, B, S):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["prefix"] = jax.random.normal(rng, (B, cfg.n_prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_shapes_no_nan(name):
    """Assignment requirement: reduced config, one forward step, shape +
    no-NaN assertions."""
    cfg = SMOKES[name]
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    B, S = 2, 32
    batch = make_batch(cfg, rng, B, S)
    logits, aux = forward_train(params, cfg, batch)
    total = S + (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step_no_nan(name):
    cfg = SMOKES[name]
    rng = jax.random.PRNGKey(1)
    params = init_params(rng, cfg)
    batch = make_batch(cfg, rng, 2, 16)
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, {**batch}), has_aux=True
    )(params)
    assert jnp.isfinite(loss)
    gnorms = [jnp.linalg.norm(g.astype(jnp.float32)) for g in jax.tree.leaves(grads)]
    assert all(bool(jnp.isfinite(g)) for g in gnorms)
    assert any(float(g) > 0 for g in gnorms)  # gradients actually flow


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_train_forward(name):
    """prefill(t0..t_{n-1}) + decode(t_n) logits ≡ train forward."""
    cfg = SMOKES[name].variant(dtype="float32")
    if cfg.is_moe:
        cfg = cfg.variant(capacity_factor=16.0)  # no token drops
    rng = jax.random.PRNGKey(2)
    params = init_params(rng, cfg)
    B, S = 2, 17
    batch = make_batch(cfg, rng, B, S)
    batch = {k: (v.astype(jnp.float32) if v.dtype == jnp.bfloat16 else v) for k, v in batch.items()}
    full_logits, _ = forward_train(params, cfg, batch)
    cache = init_cache(cfg, B, 64)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    lg_pre, cache = prefill(params, cfg, pre, cache)
    npref = cfg.n_prefix_tokens if cfg.frontend == "vision" else 0
    pos = jnp.full((B,), S - 1 + npref, jnp.int32)
    lg_dec, _ = decode_step(params, cfg, batch["tokens"][:, -1:], pos, cache)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1.0
    err_pre = float(jnp.max(jnp.abs(lg_pre[:, 0] - full_logits[:, npref + S - 2])))
    err_dec = float(jnp.max(jnp.abs(lg_dec[:, 0] - full_logits[:, npref + S - 1])))
    assert err_pre < 2e-3 * scale, f"prefill mismatch {err_pre}"
    assert err_dec < 2e-3 * scale, f"decode mismatch {err_dec}"


def test_swa_window_masks_old_tokens():
    """SWA logits at position t must ignore tokens older than the window."""
    cfg = SMOKES["h2o-danube-3-4b"].variant(dtype="float32", window=8, n_layers=1)
    rng = jax.random.PRNGKey(3)
    params = init_params(rng, cfg)
    toks = jax.random.randint(rng, (1, 24), 0, cfg.vocab_size)
    lg1, _ = forward_train(params, cfg, {"tokens": toks})
    # mutate a token far outside the window of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    lg2, _ = forward_train(params, cfg, {"tokens": toks2})
    assert float(jnp.max(jnp.abs(lg1[0, -1] - lg2[0, -1]))) < 1e-5


def test_chunked_attention_is_local():
    cfg = SMOKES["llama4-scout-17b-a16e"].variant(
        dtype="float32", window=8, n_layers=1, global_every=0, n_experts=4
    )
    rng = jax.random.PRNGKey(4)
    params = init_params(rng, cfg)
    toks = jax.random.randint(rng, (1, 24), 0, cfg.vocab_size)
    lg1, _ = forward_train(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 1].set((toks[0, 1] + 1) % cfg.vocab_size)  # chunk 0
    lg2, _ = forward_train(params, cfg, {"tokens": toks2})
    # position 23 is in chunk 2 → unaffected by chunk-0 mutation (1 layer)
    assert float(jnp.max(jnp.abs(lg1[0, -1] - lg2[0, -1]))) < 1e-5


def test_cell_applicability_table():
    cells = [(a.name, s.name, *cell_is_applicable(a, s)) for a in ARCHS.values() for s in SHAPES.values()]
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    assert {c[0] for c in skipped} == {
        "qwen2-7b", "minicpm3-4b", "tinyllama-1.1b", "whisper-large-v3",
        "internvl2-76b", "deepseek-moe-16b",
    }
    assert all(c[1] == "long_500k" for c in skipped)


def test_param_counts_match_public_sizes():
    expected = {
        "qwen2-7b": 7.6e9, "tinyllama-1.1b": 1.1e9, "minicpm3-4b": 4.1e9,
        "h2o-danube-3-4b": 4.0e9, "whisper-large-v3": 1.6e9,
        "mamba2-130m": 0.13e9, "zamba2-1.2b": 1.2e9,
        "deepseek-moe-16b": 16.4e9,
    }
    for name, exp in expected.items():
        got = ARCHS[name].param_count()
        assert abs(got - exp) / exp < 0.12, f"{name}: {got/1e9:.2f}B vs {exp/1e9:.2f}B"
