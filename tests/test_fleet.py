"""The serving fleet (ISSUE 7): router tier + sharded-KV workers over the
comm layer.

Headline acceptance: **token-stream equivalence** — for the same request
trace, the 1-router × N-worker fleet over every backend (inline /
collective / shmem) emits exactly the per-request token sequences of the
single-host reference, including under admission backpressure (EAGAIN
observed, zero requests dropped).  Plus: the row-independence fact the
sharding stands on, free-slot-load routing, chunk stickiness, chunked
prefill never dispatching a single-shot prefill, and the lifecycle leak
regression (threads + live shmem segments flat across create/close
cycles).
"""
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKES
from repro.core.comm.membership import GONE
from repro.core.comm.resources import ResourceLimits
from repro.core.comm.shmem import live_segments
from repro.models import decode_step, init_cache, init_params
from repro.serve import Fleet, FleetConfig, InferenceServer, ServeConfig

TRACE = [
    ([1, 2, 3], 4),
    ([4, 5], 5),
    ([6, 7, 8, 9, 10, 11, 12, 13, 14], 6),
    ([2, 2], 4),
    ([9, 1, 4], 5),
    ([7, 7, 7, 7, 7, 7], 6),
]


@pytest.fixture(scope="module")
def model():
    arch = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    return arch, init_params(jax.random.PRNGKey(0), arch)


def _run_single(model, chunk=0, slots=4):
    arch, params = model
    server = InferenceServer(
        arch, params,
        ServeConfig(slots=slots, context=64, transport="inline", prefill_chunk=chunk),
    )
    reqs = [server.submit(p, max_new=m) for p, m in TRACE]
    server.run_until_idle()
    assert all(r.done_event.is_set() for r in reqs)
    return [r.out_tokens for r in reqs]


def _run_fleet(model, transport, workers=2, chunk=0, slots=4, **cfg_kw):
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=workers, slots=slots, context=64, transport=transport,
                    prefill_chunk=chunk, **cfg_kw),
    )
    try:
        reqs = [fleet.submit(p, max_new=m) for p, m in TRACE]
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs), "fleet dropped a request"
        return [r.out_tokens for r in reqs], fleet
    finally:
        fleet.close()


def test_decode_rows_independent_of_batch_size(model):
    """The fact the slot sharding stands on: per-row decode results are
    bit-identical whatever the batch (= slot-shard) size, so splitting
    `slots` across workers cannot perturb any sequence."""
    arch, params = model
    c4 = init_cache(arch, 4, 64)
    c2 = init_cache(arch, 2, 64)
    t4, p4 = jnp.asarray([[3], [5], [7], [9]]), jnp.asarray([0, 0, 0, 0])
    t2, p2 = jnp.asarray([[3], [5]]), jnp.asarray([0, 0])
    for _ in range(4):
        l4, c4 = decode_step(params, arch, t4, p4, c4)
        l2, c2 = decode_step(params, arch, t2, p2, c2)
        assert jnp.array_equal(l4[:2, 0], l2[:, 0])  # bit-exact, not approx
        t4 = jnp.argmax(l4[:, 0], axis=-1)[:, None]
        t2 = jnp.argmax(l2[:, 0], axis=-1)[:, None]
        p4, p2 = p4 + 1, p2 + 1


@pytest.mark.parametrize("transport", ["inline", "collective", "shmem"])
def test_fleet_token_stream_equivalence(model, transport):
    """THE acceptance gate: same trace, same tokens, every backend."""
    ref = _run_single(model)
    out, fleet = _run_fleet(model, transport)
    assert out == ref
    # both workers actually served (the trace saturates both shards)
    assert all(w.core.tokens_out > 0 for w in fleet.workers)


@pytest.mark.parametrize("transport", ["inline", "collective", "shmem"])
def test_fleet_chunked_prefill_equivalence(model, transport):
    """Chunked prefill (prompts cross the wire in 4-token pieces,
    consumed interleaved with decode) preserves the token streams of the
    single-host reference with the SAME chunking — and no worker ever
    dispatches a single-shot prefill."""
    ref = _run_single(model, chunk=4)
    out, fleet = _run_fleet(model, transport, chunk=4)
    assert out == ref
    assert all(w.core.prefill_calls == 0 for w in fleet.workers)


def test_fleet_backpressure_eagain_requeues_never_drops(model):
    """An admission storm (tiny per-worker admission queue + bounded
    channel) must surface typed EAGAIN refusals AND still complete every
    request with reference-identical streams — re-queue, never drop."""
    ref = _run_single(model)
    limits = ResourceLimits(send_queue_depth=1, bounce_buffers=1, bounce_buffer_size=4_096)
    out, fleet = _run_fleet(
        model, "collective", admission_depth=1, limits=limits
    )
    assert out == ref
    assert fleet.eagain_events > 0  # backpressure genuinely triggered
    assert fleet.requeues == fleet.eagain_events
    assert fleet.completed == len(TRACE)  # zero dropped
    assert sum(w.eagain_refusals for w in fleet.workers) == fleet.eagain_events


def test_fleet_backpressure_on_put_backend(model):
    """The same storm over the put-capable shmem backend: refusals ride
    the one-sided response path, streams stay reference-identical."""
    ref = _run_single(model)
    out, fleet = _run_fleet(model, "shmem", admission_depth=1)
    assert out == ref
    assert fleet.eagain_events > 0
    assert fleet.completed == len(TRACE)


def test_fleet_routes_by_free_slot_load(model):
    """With both workers empty, admissions alternate by headroom: 4
    concurrent requests over 2 workers land 2 and 2 (deterministic ties
    to the lowest id)."""
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport="inline",
                    admission_depth=4),
    )
    try:
        reqs = [fleet.submit(p, max_new=m) for p, m in TRACE[:4]]
        fleet.step()
        seen = [len(w.rids_seen) for w in fleet.workers]
        assert seen == [2, 2], seen
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs)
    finally:
        fleet.close()


def test_fleet_chunk_stickiness(model):
    """Every follow-up chunk of a request goes to the worker that
    admitted its first chunk (cache affinity: the prefix KV lives
    there)."""
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=3, slots=3, context=64, transport="inline",
                    prefill_chunk=2),
    )
    try:
        long_prompts = [[i + 1] * 9 for i in range(6)]  # 9 tokens = 5 chunks
        reqs = [fleet.submit(p, max_new=3) for p in long_prompts]
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs)
        # each rid was admitted by exactly one worker, and that worker's
        # core consumed the FULL prompt for it (all chunks arrived there:
        # position after prefill+decode = len(prompt) + max_new - 1)
        admitted = {rid: w.wid for w in fleet.workers for rid in w.rids_seen}
        assert len(admitted) == len(reqs)
        counts = [len(w.rids_seen) for w in fleet.workers]
        assert counts == [2, 2, 2], counts  # load-balanced too
        assert [len(r.out_tokens) for r in reqs] == [3] * 6
    finally:
        fleet.close()


def test_fleet_lifecycle_no_thread_or_segment_leak(model):
    """50 create/close cycles of a 4-worker shmem fleet leave the process
    thread count and the live shmem-segment census flat (the PR 5
    lci_prg{n} join fix, extended to worker channels)."""
    arch, params = model
    cfg = dict(workers=4, slots=4, context=64, transport="shmem")
    # warm one full serve cycle so jit caches don't count as "growth"
    fleet = Fleet(arch, params, FleetConfig(**cfg))
    r = fleet.submit([1, 2, 3], max_new=2)
    fleet.run_until_idle()
    assert r.done_event.is_set()
    fleet.close()
    threads0, segs0 = threading.active_count(), live_segments()
    for i in range(50):
        fleet = Fleet(arch, params, FleetConfig(**cfg))
        if i % 10 == 0:  # periodically exercise the channels, not just ctor
            req = fleet.submit([1, 2, 3], max_new=2)
            fleet.run_until_idle()
            assert req.done_event.is_set()
        fleet.close()
    assert threading.active_count() == threads0
    assert live_segments() == segs0


@pytest.mark.parametrize("transport,expect_puts", [("shmem", True), ("collective", False)])
def test_fleet_put_selection_follows_capabilities(model, transport, expect_puts):
    """Response delivery rides ``post_put_signal`` into router-owned
    landing slots exactly when the backend advertises
    ``one_sided_put`` — never on capability-less backends, always on the
    shmem fleet (selection is purely capability-driven, per channel)."""
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport=transport),
    )
    try:
        for ch in fleet.channels:
            assert ch._put_responses == ch.server.capabilities.one_sided_put
            assert ch._put_responses == expect_puts
        reqs = [fleet.submit(p, max_new=m) for p, m in TRACE[:3]]
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs)
        puts = fleet.group.stats.puts
        assert (puts > 0) == expect_puts, f"puts={puts} on {transport}"
    finally:
        fleet.close()


def test_admission_cost_flat_in_slot_count(model):
    """Satellite 4: admitting one request must not pay for every other
    slot.  The old path rebuilt the full KV pytree per admission
    (``jax.tree.map`` splice => cost ~ O(slots)); the
    ``dynamic_update_slice`` fix makes it ~ O(1) in slot count.  Pin it:
    admission at 32 slots stays well under the ~16x the per-leaf rebuild
    would cost vs 2 slots (generous 6x bound for CI noise)."""
    import time

    from repro.serve.server import DecodeCore

    arch, params = model

    def admit_time(slots):
        core = DecodeCore(arch, params, slots=slots, context=64)
        sink = lambda *a: None

        class _R:  # duck-typed request: just what admit() reads
            def __init__(self, rid):
                # max_new=1 finishes at the prefill step, freeing the slot,
                # so repeated admissions time the admission path alone
                self.rid, self.prompt, self.max_new = rid, [1, 2, 3], 1

        core.admit(_R(0), sink)  # warm the jit caches for this shape
        best = float("inf")
        for rep in range(5):
            t0 = time.perf_counter()
            core.admit(_R(rep + 1), sink)
            best = min(best, time.perf_counter() - t0)
        return best

    t_small, t_big = admit_time(2), admit_time(32)
    assert t_big < 6 * t_small, (
        f"admission scaled with slot count: {t_big*1e3:.2f}ms @32 vs "
        f"{t_small*1e3:.2f}ms @2"
    )


def test_fleet_single_worker_degenerates_to_single_host(model):
    """workers=1 is the single-host server modulo the router hop."""
    ref = _run_single(model)
    out, _ = _run_fleet(model, "collective", workers=1)
    assert out == ref


# ------------------------------------------------ elastic fleet (ISSUE 8)
@pytest.mark.parametrize("transport", ["inline", "collective", "shmem"])
def test_fleet_mid_decode_leave_bit_identical(model, transport):
    """THE elastic acceptance gate: a worker leaves MID-DECODE, its KV
    slots hand off to a successor as checkpoint.snapshot payloads over the
    existing channel, and every request's token stream stays bit-identical
    to the single-host reference — zero drops, on every backend."""
    ref = _run_single(model)
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport=transport, max_workers=3),
    )
    try:
        reqs = [fleet.submit(p, max_new=m) for p, m in TRACE]
        for _ in range(3):
            fleet.step()  # decode genuinely underway on worker 0
        fleet.add_worker()  # the successor joins on the spare rank...
        assert fleet.leave_worker(0) is True  # ...and worker 0 drains out
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs), "leave dropped a request"
        assert [r.out_tokens for r in reqs] == ref  # bit-identical continuation
        assert fleet.completed == len(TRACE)
        assert fleet.handoffs >= 1  # slots really moved mid-stream
        assert (fleet.joins, fleet.leaves) == (1, 1)
        assert fleet.membership.state(0) == GONE
        assert sum(w.adoptions for w in fleet.workers if w is not None) == fleet.handoffs
    finally:
        fleet.close()


@pytest.mark.parametrize("transport", ["inline", "collective"])
def test_fleet_mid_prefill_leave_chunked(model, transport):
    """A leave while chunked prefill is still streaming: the snapshot
    carries the open prefill queue, sticky chunk routing re-points to the
    adopter, and a chunk that outran the splice is stashed — streams stay
    reference-identical."""
    ref = _run_single(model, chunk=4)
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport=transport,
                    prefill_chunk=4, max_workers=3),
    )
    try:
        reqs = [fleet.submit(p, max_new=m) for p, m in TRACE]
        fleet.step()  # prompts admitted, chunk plans still draining
        fleet.add_worker()
        fleet.leave_worker(0)
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs)
        assert [r.out_tokens for r in reqs] == ref
        assert fleet.completed == len(TRACE)
    finally:
        fleet.close()


def test_fleet_join_leave_cycles_threads_segments_flat(model):
    """25 join/leave cycles against a live shmem fleet: the spare rank's
    pre-provisioned channel/slab is REUSED every cycle, so the process
    thread count and the live shmem-segment census never move."""
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport="shmem", max_workers=3),
    )
    try:
        wid = fleet.add_worker()  # warm one full cycle (jit, channels)
        fleet.leave_worker(wid)
        r = fleet.submit([1, 2, 3], max_new=2)
        fleet.run_until_idle()
        assert r.done_event.is_set()
        threads0, segs0 = threading.active_count(), live_segments()
        ranks = set()
        for i in range(25):
            ranks.add(fleet.add_worker())
            if i % 5 == 0:  # serve through some cycles, not just churn
                req = fleet.submit([2, 3, 4], max_new=2)
            fleet.leave_worker(2)
            fleet.run_until_idle()
            assert threading.active_count() == threads0
            assert live_segments() == segs0
        assert ranks == {2}  # the same rank slot every cycle — true reuse
        assert fleet.joins == 26 and fleet.leaves == 26
        assert fleet.completed == 6  # warm + 5 churn-cycle requests, zero lost
    finally:
        fleet.close()


def test_fleet_abandoned_worker_swept_and_rank_reused(model):
    """Satellite regression: a fleet worker that dies WITHOUT leave() is
    reaped by the membership finalizer sweep — its rank returns to the
    pool and the fleet keeps serving."""
    import gc

    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport="inline", max_workers=3),
    )
    try:
        w = fleet.workers[1]
        fleet.workers[1] = None  # the router's strong ref goes away...
        del w  # ...and the worker dies with no leave()
        gc.collect()
        assert fleet.membership.sweep() == [1]
        assert fleet.membership.state(1) == GONE
        assert fleet.membership.active_ranks() == (0,)
        assert fleet.add_worker() == 1  # the abandoned rank is reusable
        r = fleet.submit([1, 2, 3], max_new=2)
        fleet.run_until_idle()
        assert r.done_event.is_set()
    finally:
        fleet.close()


def test_fleet_leave_edge_cases(model):
    """Double leave is idempotent; the last active worker may not leave;
    a full fleet refuses further joins."""
    arch, params = model
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=2, slots=4, context=64, transport="inline", max_workers=2),
    )
    try:
        assert fleet.leave_worker(1) is True
        assert fleet.leave_worker(1) is False  # idempotent no-op
        with pytest.raises(ValueError, match="last active"):
            fleet.leave_worker(0)
        assert fleet.add_worker() == 1  # GONE rank rejoins...
        with pytest.raises(ValueError, match="max_workers"):
            fleet.add_worker()  # ...but the fleet is bounded
    finally:
        fleet.close()
