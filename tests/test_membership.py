"""The Membership/Fleet-control subsystem (ISSUE 8 tentpole).

State-machine edges (double-leave idempotent, join-during-drain, typed
EAGAIN_DRAINING on posts racing a leave, epoch-stale completion discard),
the finalizer-based abandoned-worker sweep, the resizable
ProgressWorkerPool (threads joined on every shrink), the
ElasticProgressController's hysteresis + cooldown guards, and a
hypothesis property over random join/leave/post schedules (every posted
message is delivered exactly once after quiesce — a leave re-queues,
never loses).
"""
import gc
import threading
import weakref

import pytest

from repro.core.comm.interface import PostStatus
from repro.core.comm.membership import (
    ACTIVE,
    DRAINING,
    GONE,
    JOINING,
    ElasticProgressController,
    Membership,
    ProgressWorkerPool,
    live_worker_count,
    spawn_worker,
)
from tests._hypothesis_compat import given, settings, st


# ------------------------------------------------------- state machine
def test_lifecycle_happy_path_and_events():
    m = Membership()
    m.join(0)
    assert m.state(0) == JOINING
    assert m.guard_post(0) == PostStatus.OK  # joining ranks accept posts
    m.activate(0)
    assert m.state(0) == ACTIVE and m.active_ranks() == (0,)
    assert m.begin_drain(0) is True
    assert m.state(0) == DRAINING and m.active_ranks() == ()
    assert m.finish_leave(0) is True
    assert m.state(0) == GONE
    kinds = [e[0] for e in m.drain_events()]
    assert kinds == ["join", "active", "drain", "gone"]
    # epochs strictly increase across transitions
    m2 = Membership()
    m2.join(1)
    m2.activate(1)
    epochs = [e[2] for e in m2.drain_events()]
    assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs)


def test_double_leave_is_idempotent():
    """A second leave() — from a racing controller or a retried teardown —
    is a no-op at every stage, and the on_gone hook runs exactly once."""
    hook_runs = []
    m = Membership()
    m.join(0, on_gone=lambda member: hook_runs.append(member.rank))
    m.activate(0)
    assert m.begin_drain(0) is True
    assert m.begin_drain(0) is False  # already draining
    assert m.finish_leave(0) is True
    assert m.finish_leave(0) is False  # already gone
    assert m.begin_drain(0) is False  # gone ranks can't re-drain
    assert hook_runs == [0]


def test_join_while_another_rank_drains():
    """A join during a drain is independent: the newcomer becomes routable
    while the leaver quiesces, and the epoch totally orders the two."""
    m = Membership()
    m.join(0)
    m.activate(0)
    m.begin_drain(0)
    member = m.join(1)  # joins mid-drain
    m.activate(1)
    assert m.state(0) == DRAINING and m.state(1) == ACTIVE
    assert m.active_ranks() == (1,)
    assert m.guard_post(0) == PostStatus.EAGAIN_DRAINING
    assert m.guard_post(1) == PostStatus.OK
    m.finish_leave(0)
    assert m.epoch > member.epoch  # the leave happened-after the join


def test_rejoin_only_after_gone():
    m = Membership()
    m.join(0)
    with pytest.raises(ValueError, match="already a member"):
        m.join(0)
    m.activate(0)
    with pytest.raises(ValueError, match="activate from"):
        m.activate(0)
    m.begin_drain(0)
    m.finish_leave(0)
    again = m.join(0)  # GONE rank re-joins at a fresh epoch
    assert again.state == JOINING and again.epoch == m.epoch


def test_post_racing_a_leave_requeues_never_drops():
    """The post-side arbiter: a post that raced a leave gets the *typed*
    EAGAIN_DRAINING (falsy, like every resource EAGAIN) and the caller
    re-queues to a surviving rank — zero loss by construction."""
    m = Membership()
    for r in (0, 1):
        m.join(r)
        m.activate(r)
    inbox = {0: [], 1: []}
    pending = [(0, i) for i in range(8)]  # all aimed at rank 0
    m.begin_drain(0)  # the leave races the posts
    delivered = []
    while pending:
        rank, msg = pending.pop(0)
        status = m.guard_post(rank)
        if status:
            inbox[rank].append(msg)
            delivered.append(msg)
        else:
            assert status == PostStatus.EAGAIN_DRAINING and not status
            successor = m.active_ranks()[0]
            pending.append((successor, msg))  # re-queue, never drop
    assert inbox[0] == [] and sorted(inbox[1]) == list(range(8))
    assert sorted(delivered) == list(range(8))


def test_stale_completion_discarded_exactly_once():
    """The completion-side arbiter: a completion dispatched under a view
    whose epoch predates the member's departure is discarded (counted),
    and a live member's completions always land."""
    m = Membership()
    m.join(0)
    m.activate(0)
    view = m.view()  # routing decision taken here
    assert m.admit_completion(0, view.epoch) is True  # live: admitted
    m.begin_drain(0)
    m.finish_leave(0)
    assert m.admit_completion(0, view.epoch) is False  # stale: discarded
    assert m.stale_discards == 1
    m.join(0)  # rank reused at a fresh epoch
    m.activate(0)
    assert m.admit_completion(0, m.view().epoch) is True  # fresh view lands
    assert m.stale_discards == 1  # the discard happened exactly once


def test_view_is_immutable_snapshot():
    m = Membership()
    m.join(0)
    m.activate(0)
    view = m.view()
    assert 0 in view and view.active == (0,)
    m.begin_drain(0)
    assert 0 in view  # the snapshot does not move...
    assert 0 not in m.view()  # ...the live table does
    assert m.view().epoch > view.epoch


# --------------------------------------- abandoned-worker liveness sweep
def test_abandoned_owner_swept_and_rank_reused():
    """Satellite regression: a worker that dies WITHOUT leave() is reaped
    by the finalizer backstop — sweep() forces it to GONE, its on_gone
    hook returns the slots, and the rank is reusable."""
    freed = []
    m = Membership()

    class Owner:  # stands for the worker object whose lifetime we track
        pass

    owner = Owner()
    m.join(0, owner=owner, on_gone=lambda member: freed.append(member.rank))
    m.activate(0)
    assert m.sweep() == []  # owner alive: nothing to reap
    del owner
    gc.collect()
    assert m.sweep() == [0]
    assert m.state(0) == GONE and freed == [0]
    assert m.sweep() == []  # idempotent
    m.join(0)  # the slot is back in the pool
    assert m.state(0) == JOINING


def test_clean_leave_detaches_finalizer():
    """After an orderly leave the finalizer must NOT fire when the owner
    is later collected — no double-free of the rank's slots."""
    m = Membership()

    class Owner:
        pass

    owner = Owner()
    m.join(0, owner=owner)
    m.activate(0)
    m.begin_drain(0)
    m.finish_leave(0)
    del owner
    gc.collect()
    assert m.sweep() == []  # nothing abandoned: the leave already ran


# ----------------------------------------------- hypothesis: exactly-once
@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.one_of(
            st.tuples(st.just("join"), st.integers(0, 3)),
            st.tuples(st.just("leave"), st.integers(0, 3)),
            st.tuples(st.just("post"), st.integers(0, 3)),
        ),
        max_size=40,
    )
)
def test_random_schedule_delivers_exactly_once(schedule):
    """Property: under ANY interleaving of join/leave/post, every posted
    message is delivered exactly once after quiesce — an EAGAIN_DRAINING
    re-queues to a survivor, a leave never loses, and nothing duplicates."""
    m = Membership()
    inbox = {r: [] for r in range(5)}
    pending = []  # (rank, msg-id) awaiting (re-)post
    next_msg = 0

    def deliver(rank, msg):
        status = m.guard_post(rank)
        if status:
            inbox[rank].append(msg)
            return True
        pending.append(msg)  # typed refusal: re-queue, never drop
        return False

    for op, rank in schedule:
        if op == "join":
            if m.state(rank) in (None, GONE):
                m.join(rank)
                m.activate(rank)
        elif op == "leave":
            if m.begin_drain(rank):
                m.finish_leave(rank)
        else:  # post
            deliver(rank, next_msg)
            next_msg += 1
            # retry the backlog against whoever is active right now
            active = m.active_ranks()
            if active:
                backlog, pending[:] = list(pending), []
                for msg in backlog:
                    deliver(active[0], msg)
    # quiesce: guarantee a live member, then flush the backlog
    if not m.active_ranks():
        m.join(4)
        m.activate(4)
    for msg in list(pending):
        assert deliver(m.active_ranks()[0], msg)
    got = sorted(x for box in inbox.values() for x in box)
    assert got == list(range(next_msg))  # exactly once: no loss, no dupes


# ------------------------------------------------- the worker thread pool
class _Endpoint:
    """Minimal progress endpoint for pool tests."""

    def progress_work(self):
        return False


def test_pool_resize_spawns_and_joins_real_threads():
    ep = _Endpoint()
    base = threading.active_count()
    pool = ProgressWorkerPool(weakref.ref(ep), "t-prg")
    pool.resize(3)
    assert pool.size() == 3 and pool.spawned_total == 3
    assert threading.active_count() == base + 3
    pool.resize(1)  # shrink joins the surplus — not just stops them
    assert pool.size() == 1 and pool.joined_total == 2
    assert threading.active_count() == base + 1
    pool.resize(2)  # regrow gets fresh serials, survivors undisturbed
    assert pool.size() == 2 and pool.spawned_total == 4
    pool.close()
    pool.close()  # idempotent
    assert pool.size() == 0 and threading.active_count() == base


def test_spawn_worker_census():
    done = threading.Event()
    before = live_worker_count()
    t = spawn_worker(done.wait, name="census-probe")
    assert live_worker_count() == before + 1
    done.set()
    t.join(timeout=5.0)
    assert live_worker_count() == before


# ------------------------------------------- the elastic controller
class _FakeEngine:
    def __init__(self, occ=0.0):
        self.occ = occ

    def reap_latency_stats(self):
        return {"occupancy_ewma": self.occ}


def _controller(occ, lo=0, hi=2, **kw):
    ep = _Endpoint()
    pool = ProgressWorkerPool(weakref.ref(ep), "ec-prg")
    pool.resize(lo)
    eng = _FakeEngine(occ)
    ctl = ElasticProgressController(eng, pool, lo, hi, **kw)
    return ctl, eng, pool, ep


def test_controller_grows_under_backlog_and_respects_hi():
    ctl, eng, pool, _ep = _controller(occ=8.0, lo=0, hi=2, cooldown=0.0)
    assert ctl.maybe_resize() and pool.size() == 1
    assert ctl.maybe_resize() and pool.size() == 2
    assert not ctl.maybe_resize() and pool.size() == 2  # pinned at hi
    assert ctl.grows == 2 and ctl.shrinks == 0
    pool.close()


def test_controller_shrinks_when_idle_and_respects_lo():
    ctl, eng, pool, _ep = _controller(occ=8.0, lo=1, hi=3, cooldown=0.0)
    ctl.maybe_resize()
    ctl.maybe_resize()
    assert pool.size() == 3
    eng.occ = 0.1  # reapers idle: dedicated cores are wasted
    assert ctl.maybe_resize() and pool.size() == 2
    assert ctl.maybe_resize() and pool.size() == 1
    assert not ctl.maybe_resize() and pool.size() == 1  # pinned at lo
    pool.close()


def test_controller_hysteresis_band_holds_steady():
    """Occupancy between the thresholds is the stable band: neither grow
    nor shrink fires, however often the controller is polled."""
    ctl, eng, pool, _ep = _controller(occ=8.0, lo=0, hi=2, cooldown=0.0)
    ctl.maybe_resize()
    eng.occ = 2.0  # inside (shrink_at=1.0, grow_at=4.0)
    for _ in range(10):
        assert not ctl.maybe_resize()
    assert pool.size() == 1 and ctl.resizes == 1
    pool.close()


def test_naive_controller_oscillates_where_hysteresis_holds():
    """hysteresis=False degenerates to one threshold + no cooldown — at
    occupancy exactly on the threshold it grows then immediately shrinks,
    forever; the hysteresis band holds after one resize.  (The DES
    elasticity_study measures the same contrast with charged costs.)"""
    naive, eng_n, pool_n, _e1 = _controller(occ=4.0, lo=0, hi=1, hysteresis=False)
    hyst, eng_h, pool_h, _e2 = _controller(occ=4.0, lo=0, hi=1, cooldown=0.0)
    for _ in range(6):
        naive.maybe_resize()
        hyst.maybe_resize()
    assert naive.resizes >= 2 * max(hyst.resizes, 1)
    assert hyst.resizes == 1  # grew once, then held
    pool_n.close()
    pool_h.close()


def test_controller_cooldown_bounds_resize_rate():
    ctl, eng, pool, _ep = _controller(occ=8.0, lo=0, hi=2, cooldown=30.0)
    assert ctl.maybe_resize()
    assert not ctl.maybe_resize()  # inside the cooldown window
    assert pool.size() == 1
    pool.close()


def test_controller_rejects_bad_bounds():
    ep = _Endpoint()
    pool = ProgressWorkerPool(weakref.ref(ep), "bad")
    with pytest.raises(ValueError, match="bounds"):
        ElasticProgressController(_FakeEngine(), pool, 3, 1)


# ------------------------------------- the lci_eprg family, end to end
def test_elastic_parcelport_delivers_within_bounds_and_closes_clean():
    """The lci_eprg{lo}_{hi} family: real elastic pool on a real world —
    full delivery, pool never escapes its bounds, close() joins every
    thread (census flat)."""
    from repro.core.parcelport import World
    from repro.core.variants import VARIANTS, make_parcelport_factory, max_devices

    cfg = VARIANTS["lci_eprg0_2"]
    assert cfg.elastic_progress == (0, 2) and cfg.progress_workers == 0
    base = threading.active_count()
    world = World(2, make_parcelport_factory("lci_eprg0_2"),
                  devices_per_rank=max_devices("lci_eprg0_2"))
    got = []
    world.localities[1].register_action("sink", lambda *a: got.append(a))
    for i in range(40):
        world.localities[0].async_action(1, "sink", b"x" * (64 + i))
    world.drain()
    assert len(got) == 40
    for loc in world.localities:
        pp = loc.parcelport
        assert pp._elastic is not None
        assert 0 <= pp._pw_pool.size() <= 2
    world.close()
    assert threading.active_count() <= base + 1
    for loc in world.localities:
        assert loc.parcelport._pw_pool.size() == 0
