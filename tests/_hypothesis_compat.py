"""Optional-hypothesis shim (mirrors ``pytest.importorskip`` semantics, but
at test granularity instead of module granularity).

``pip install -e .[test]`` provides hypothesis and this module re-exports the
real ``given``/``settings``/``st``.  In a bare environment the property-based
tests self-skip with a clear reason while every plain test in the same module
still collects and runs — the suite never dies with a ModuleNotFoundError.

Setting ``REPRO_REQUIRE_HYPOTHESIS=1`` turns the skip into a hard failure:
the CI property-tests leg exports it so the randomized channel/fleet suite
can never silently degrade to "0 ran, all skipped" (ISSUE 7) — a leg that
claims to run the properties must actually run them.
"""
import functools
import inspect
import os

import pytest

REQUIRED = bool(os.environ.get("REPRO_REQUIRE_HYPOTHESIS"))

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute access or
        call returns itself, so strategy expressions evaluated at decoration
        time (``st.lists(st.integers(...), ...)``) are inert no-ops."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*given_args, **given_kwargs):
        def decorate(fn):
            # Hide the hypothesis-supplied parameters from pytest: the
            # real @given fills the RIGHTMOST positional params (and any
            # keyword-named ones) itself, so the skipper's visible
            # signature keeps only what pytest must still resolve —
            # parametrize args and genuine fixtures.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if given_args:
                params = params[: -len(given_args)]
            params = [p for p in params if p.name not in given_kwargs]

            @functools.wraps(fn)
            def skipper(*args, **kwargs):
                if REQUIRED:  # pragma: no cover - the CI property leg
                    pytest.fail(
                        "REPRO_REQUIRE_HYPOTHESIS is set but hypothesis is "
                        "not installed — the property suite MUST run here "
                        "(pip install -e .[test])"
                    )
                pytest.skip("hypothesis not installed (pip install -e .[test])")

            skipper.__signature__ = sig.replace(parameters=params)
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn
