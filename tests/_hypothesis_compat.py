"""Optional-hypothesis shim (mirrors ``pytest.importorskip`` semantics, but
at test granularity instead of module granularity).

``pip install -e .[test]`` provides hypothesis and this module re-exports the
real ``given``/``settings``/``st``.  In a bare environment the property-based
tests self-skip with a clear reason while every plain test in the same module
still collects and runs — the suite never dies with a ModuleNotFoundError.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute access or
        call returns itself, so strategy expressions evaluated at decoration
        time (``st.lists(st.integers(...), ...)``) are inert no-ops."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def decorate(fn):
            def skipper():
                pytest.skip("hypothesis not installed (pip install -e .[test])")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn
