"""AMT executor (work stealing, background-work contract) + inference server."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.executor import AMTExecutor
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve import InferenceServer, ServeConfig


def test_executor_submit_and_result():
    ex = AMTExecutor(n_workers=2)
    try:
        futs = [ex.submit(lambda x=i: x * x) for i in range(20)]
        assert [f.result(timeout=10) for f in futs] == [i * i for i in range(20)]
    finally:
        ex.shutdown()


def test_executor_error_propagates():
    ex = AMTExecutor(n_workers=1)
    try:
        f = ex.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            f.result(timeout=10)
    finally:
        ex.shutdown()


def test_executor_background_work_pumped():
    calls = []
    ex = AMTExecutor(n_workers=1, background_work=lambda: calls.append(1) or False)
    try:
        time.sleep(0.05)
        assert len(calls) > 0  # idle workers pump background work (Listing 2)
    finally:
        ex.shutdown()


def test_executor_work_stealing():
    ex = AMTExecutor(n_workers=2)
    try:
        # submit everything to worker 0; worker 1 must steal
        futs = [ex.submit(lambda: time.sleep(0.002), worker=0) for _ in range(20)]
        for f in futs:
            f.result(timeout=10)
        stats = ex.stats()
        assert sum(stats["steals"]) > 0
    finally:
        ex.shutdown()


# ------------------------------------------------------------------- serving
def test_server_completes_requests_and_matches_reference():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InferenceServer(cfg, params, ServeConfig(slots=2, context=64))
    prompt = list(range(1, 9))
    req = server.submit(prompt, max_new=6)
    server.run_until_idle()
    assert req.done_event.is_set()
    assert len(req.out_tokens) == 6
    # reference: sequential greedy decode
    cache = init_cache(cfg, 1, 64)
    lg, cache = prefill(params, cfg, {"tokens": jnp.asarray([prompt])}, cache)
    ref = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = decode_step(params, cfg, jnp.asarray([[ref[-1]]]), jnp.asarray([pos]), cache)
        ref.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    assert req.out_tokens == ref


def test_server_continuous_batching_interleaves():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InferenceServer(cfg, params, ServeConfig(slots=2, context=64))
    r1 = server.submit([1, 2, 3], max_new=8)
    server.step()  # r1 admitted + one decode
    r2 = server.submit([4, 5, 6], max_new=3)  # joins mid-flight
    server.run_until_idle()
    assert r1.done_event.is_set() and r2.done_event.is_set()
    assert len(r1.out_tokens) == 8 and len(r2.out_tokens) == 3


def test_server_multithreaded_submission():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InferenceServer(cfg, params, ServeConfig(slots=3, context=64))
    reqs = []
    lock = threading.Lock()

    def client(seed):
        rng = np.random.default_rng(seed)
        for _ in range(3):
            r = server.submit(rng.integers(0, cfg.vocab_size, 5).tolist(), max_new=4)
            with lock:
                reqs.append(r)

    ts = [threading.Thread(target=client, args=(s,)) for s in range(3)]
    for t in ts:
        t.start()
    while any(t.is_alive() for t in ts):
        server.step()
        time.sleep(0.001)
    for t in ts:
        t.join()
    server.run_until_idle()
    assert all(r.done_event.is_set() for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
