"""AMT executor (work stealing, background-work contract) + inference server.

Since ISSUE 5 the serving request/response hand-off rides the shared comm
layer (CommInterface verbs on a CollectiveComm pair, driven by the one
ProgressEngine); these tests cover both hand-off paths and their parity,
the ServeConfig-aliasing regression, the bounded (EAGAIN) serving channel,
and the executor's engine-driven idle pump."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core.comm.resources import ResourceLimits
from repro.core.executor import AMTExecutor
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve import InferenceServer, ServeConfig


def test_executor_submit_and_result():
    ex = AMTExecutor(n_workers=2)
    try:
        futs = [ex.submit(lambda x=i: x * x) for i in range(20)]
        assert [f.result(timeout=10) for f in futs] == [i * i for i in range(20)]
    finally:
        ex.shutdown()


def test_executor_error_propagates():
    ex = AMTExecutor(n_workers=1)
    try:
        f = ex.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            f.result(timeout=10)
    finally:
        ex.shutdown()


def test_executor_background_work_pumped():
    calls = []
    ex = AMTExecutor(n_workers=1, background_work=lambda: calls.append(1) or False)
    try:
        time.sleep(0.05)
        assert len(calls) > 0  # idle workers pump background work (Listing 2)
    finally:
        ex.shutdown()


def test_executor_idle_pump_drives_shared_engine():
    """comm=<parcelport>: idle workers run canonical steps of the ONE
    ProgressEngine (run_step under their own worker id) instead of an
    opaque callable — parcels deliver with no explicit pumping at all."""
    from repro.core.parcelport import World
    from repro.core.variants import make_parcelport_factory

    world = World(2, make_parcelport_factory("lci"), devices_per_rank=2)
    got: list = []
    world.localities[1].register_action("sink", lambda *a: got.append(a))
    execs = [
        AMTExecutor(n_workers=2, comm=loc.parcelport, name=f"rank{loc.rank}")
        for loc in world.localities
    ]
    try:
        for i in range(10):
            world.localities[0].async_action(1, "sink", bytes([i]) * 1_000)
        deadline = time.monotonic() + 20
        while len(got) < 10 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert len(got) == 10
    finally:
        for ex in execs:
            ex.shutdown()
        world.close()


def test_executor_work_stealing():
    ex = AMTExecutor(n_workers=2)
    try:
        # submit everything to worker 0; worker 1 must steal
        futs = [ex.submit(lambda: time.sleep(0.002), worker=0) for _ in range(20)]
        for f in futs:
            f.result(timeout=10)
        stats = ex.stats()
        assert sum(stats["steals"]) > 0
    finally:
        ex.shutdown()


# ------------------------------------------------------------------- serving
def _smoke_model():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_serve_config_not_aliased_between_servers():
    """Regression: `cfg: ServeConfig = ServeConfig()` evaluated the
    default ONCE at import — every no-arg server shared one mutable
    config object.  Two servers must get independent configs."""
    arch, params = _smoke_model()
    s1 = InferenceServer(arch, params)
    s2 = InferenceServer(arch, params)
    assert s1.cfg is not s2.cfg
    s1.cfg.slots = 99
    assert s2.cfg.slots != 99
    assert ServeConfig().slots != 99  # the dataclass default is untouched


def _run_stream(transport, limits=None):
    arch, params = _smoke_model()
    kw = {"limits": limits} if limits is not None else {}
    server = InferenceServer(
        arch, params, ServeConfig(slots=2, context=64, transport=transport, **kw)
    )
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [2, 2], [9, 1, 4]]
    reqs = [server.submit(p, max_new=4 + i % 3) for i, p in enumerate(prompts)]
    server.run_until_idle()
    assert all(r.done_event.is_set() for r in reqs)
    assert [len(r.out_tokens) for r in reqs] == [4 + i % 3 for i in range(len(prompts))]
    return [r.out_tokens for r in reqs], server


def test_serving_roundtrip_parity_inline_vs_collective():
    """The acceptance gate (ISSUE 5): the same request stream produces
    IDENTICAL responses on the legacy direct path and on the CommInterface
    hand-off — the comm layer moved the bytes, not the math."""
    inline, _ = _run_stream("inline")
    collective, server = _run_stream("collective")
    assert inline == collective
    # and the collective path actually carried the traffic
    assert server._channel.group.stats.messages > 0


def test_serving_shmem_responses_ride_put_into_router_slots():
    """ISSUE 6: with the put-capable shmem backend the SAME request stream
    produces identical responses, token batches ride one-sided put into
    the router-owned response queue, and the path is selected purely by
    the advertised Capabilities — never by backend name or type."""
    inline, _ = _run_stream("inline")
    shmem, server = _run_stream("shmem")
    assert inline == shmem
    ch = server._channel
    assert ch._put_responses  # = server endpoint's capabilities.one_sided_put
    assert ch.server.capabilities.one_sided_put
    assert ch.group.stats.puts > 0  # responses genuinely rode put
    # requests stay two-sided (tagged sends), so both verbs carried traffic
    assert ch.group.stats.sends > 0
    # the collective backend advertises no put: same channel code, two-sided
    _, coll = _run_stream("collective")
    assert not coll._channel._put_responses
    assert coll._channel.group.stats.puts == 0


def test_serving_collective_backpressure_throttles_not_loses():
    """A tightly bounded hand-off channel must surface EAGAIN (parked
    posts) AND still complete every request — the §3.3.4 throttle on the
    serving hot path."""
    limits = ResourceLimits(send_queue_depth=1, bounce_buffers=1, bounce_buffer_size=4_096)
    tokens, server = _run_stream("collective", limits=limits)
    assert server._channel.backpressure_parks() > 0
    assert server._channel.group.stats.backpressure_events > 0
    # identical responses regardless of the bound (backpressure delays,
    # never drops or reorders a request's tokens)
    unbounded, _ = _run_stream("collective")
    assert tokens == unbounded


def test_executor_pumps_serving_engine_concurrently():
    """The documented integration: AMTExecutor(comm=server) idle workers
    pump the serving engine WHILE the serve loop steps.  The engine's
    step_lock serializes dispatch and the FIFO throttle keeps token
    batches ordered — responses identical to the single-driver run."""
    arch, params = _smoke_model()
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]

    def run(with_executor, limits=None):
        kw = {"limits": limits} if limits is not None else {}
        server = InferenceServer(
            arch, params, ServeConfig(slots=2, context=64, transport="collective", **kw)
        )
        ex = AMTExecutor(n_workers=2, comm=server) if with_executor else None
        try:
            reqs = [server.submit(p, max_new=5) for p in prompts]
            server.run_until_idle()
            assert all(r.done_event.is_set() for r in reqs)
            return [r.out_tokens for r in reqs]
        finally:
            if ex is not None:
                ex.shutdown()

    reference = run(False)
    assert run(True) == reference
    # and under a tightly bounded channel: concurrent drain vs fresh posts
    # must keep token batches FIFO (the throttle's non-overtaking lock)
    tight = ResourceLimits(send_queue_depth=1, bounce_buffers=1, bounce_buffer_size=4_096)
    assert run(True, limits=tight) == reference


def test_serving_policy_ladder_delivers():
    """The serving engine consumes ProgressPolicy.for_config like any
    parcelport: the implicit (worker-polling) policy must serve the same
    stream as the explicit default."""
    arch, params = _smoke_model()
    out = {}
    for mode in ("explicit", "implicit"):
        server = InferenceServer(
            arch, params,
            ServeConfig(slots=2, context=64, transport="collective", progress_mode=mode),
        )
        reqs = [server.submit([1, 2, 3], max_new=5), server.submit([4, 5], max_new=5)]
        server.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs)
        out[mode] = [r.out_tokens for r in reqs]
    assert out["explicit"] == out["implicit"]


def test_server_completes_requests_and_matches_reference():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InferenceServer(cfg, params, ServeConfig(slots=2, context=64))
    prompt = list(range(1, 9))
    req = server.submit(prompt, max_new=6)
    server.run_until_idle()
    assert req.done_event.is_set()
    assert len(req.out_tokens) == 6
    # reference: sequential greedy decode
    cache = init_cache(cfg, 1, 64)
    lg, cache = prefill(params, cfg, {"tokens": jnp.asarray([prompt])}, cache)
    ref = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = decode_step(params, cfg, jnp.asarray([[ref[-1]]]), jnp.asarray([pos]), cache)
        ref.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    assert req.out_tokens == ref


def test_server_continuous_batching_interleaves():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InferenceServer(cfg, params, ServeConfig(slots=2, context=64))
    r1 = server.submit([1, 2, 3], max_new=8)
    server.step()  # r1 admitted + one decode
    r2 = server.submit([4, 5, 6], max_new=3)  # joins mid-flight
    server.run_until_idle()
    assert r1.done_event.is_set() and r2.done_event.is_set()
    assert len(r1.out_tokens) == 8 and len(r2.out_tokens) == 3


def test_server_multithreaded_submission():
    cfg = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InferenceServer(cfg, params, ServeConfig(slots=3, context=64))
    reqs = []
    lock = threading.Lock()

    def client(seed):
        rng = np.random.default_rng(seed)
        for _ in range(3):
            r = server.submit(rng.integers(0, cfg.vocab_size, 5).tolist(), max_new=4)
            with lock:
                reqs.append(r)

    ts = [threading.Thread(target=client, args=(s,)) for s in range(3)]
    for t in ts:
        t.start()
    while any(t.is_alive() for t in ts):
        server.step()
        time.sleep(0.001)
    for t in ts:
        t.join()
    server.run_until_idle()
    assert all(r.done_event.is_set() for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
