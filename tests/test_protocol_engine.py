"""Eager/rendezvous protocol engine + injection backpressure (this repo's
ISSUE 1 tentpole): crossover behaviour around ``eager_threshold``, retry
under a bounded fabric, aggregation x eager across every variant, and the
reserved-bit-range aggregate sub-id scheme."""
import pytest

from repro.core.fabric import Fabric, RegisteredBufferPool
from repro.core.harness import deliver_payloads as run_world
from repro.core.parcel import (
    Chunk,
    Parcel,
    decode_header,
    eager_wire_size,
    encode_eager,
    serialize_action,
)
from repro.core.parcelport import (
    AGG_SUB_SHIFT,
    World,
    aggregate_parcels,
    split_aggregate,
)
from repro.core.variants import VARIANTS, make_parcelport_factory
from repro.core.lci_parcelport import LCIParcelport


# ------------------------------------------------------- eager wire format
def test_eager_encode_decode_roundtrip():
    p = serialize_action(7, 0, 1, "act", (b"meta", b"z" * 4000), zero_copy_threshold=1024)
    assert p.num_zc == 1
    wire = encode_eager(p, device_index=1)
    assert len(wire) == eager_wire_size(p)
    h = decode_header(wire)
    assert h.is_eager and h.num_followups == 0
    assert h.parcel_id == 7 and h.device_index == 1
    assert h.piggybacked_nzc == p.nzc_chunk.data
    assert h.inline_zc == [p.zc_chunks[0].data]


# ------------------------------------------------- crossover round trips
@pytest.mark.parametrize("size", [100, 2_000, 14_000, 15_500, 17_000, 60_000])
def test_eager_rendezvous_crossover(size):
    """Sizes straddling lci_eager's 16 KiB threshold round-trip on both
    sides of the crossover, and land on the right protocol counter."""
    world, got = run_world("lci_eager", [bytes([size % 251]) * size])
    assert [len(a[0]) for a in got] == [size]
    st = world.fabric.stats
    # the serialized parcel is a bit larger than the payload; anything
    # comfortably under/over 16 KiB must pick the matching protocol
    if size <= 15_500:
        assert st.eager_msgs >= 1 and st.rendezvous_msgs == 0
    elif size >= 17_000:
        assert st.eager_msgs == 0 and st.rendezvous_msgs >= 2


def test_eager_fewer_fabric_messages_than_noeager():
    """The acceptance gate: for sub-threshold parcels carrying zero-copy
    chunks, the eager variant uses strictly fewer fabric messages/parcel."""
    payloads = [bytes([i]) * 4_000 for i in range(8)]  # zc chunks at 1 KiB thr.
    w_eager, got_e = run_world("lci_eager", payloads)
    w_plain, got_p = run_world("lci_noeager", payloads)
    assert len(got_e) == len(got_p) == len(payloads)
    assert w_eager.fabric.stats.messages < w_plain.fabric.stats.messages
    assert w_eager.fabric.stats.messages == len(payloads)  # one msg each
    assert w_plain.fabric.stats.messages == 2 * len(payloads)  # header + zc


def test_eager_threshold_zero_forces_rendezvous():
    world, got = run_world("lci_noeager", [b"x" * 50])
    assert len(got) == 1
    assert world.fabric.stats.eager_msgs == 0
    assert world.fabric.stats.rendezvous_msgs >= 1


def test_eager_respects_bounce_buffer_capacity():
    """A parcel under the threshold but over the bounce-buffer size must
    fall back to rendezvous instead of livelocking on acquire()."""
    world, got = run_world(
        "lci_eager_64k",
        [b"q" * 30_000],
        fabric_kwargs=dict(bounce_buffers=4, bounce_buffer_size=8_192),
    )
    assert [len(a[0]) for a in got] == [30_000]
    assert world.fabric.stats.eager_msgs == 0  # didn't fit a bounce buffer


# -------------------------------------------------------- backpressure
def test_backpressure_retry_tiny_send_queue():
    world, got = run_world(
        "lci",
        [bytes([i % 256]) * 64 for i in range(150)],
        fabric_kwargs=dict(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=16_384),
    )
    st = world.fabric.stats
    assert len(got) == 150
    assert st.backpressure_events > 0
    for loc in world.localities:
        pp = loc.parcelport
        assert pp.retry_queue_depth() == 0  # throttle drained everything


def test_backpressure_rendezvous_followups():
    """Large parcels (rendezvous follow-ups) also ride the retry path."""
    world, got = run_world(
        "lci_noeager",
        [b"B" * 40_000 for _ in range(20)],
        fabric_kwargs=dict(send_queue_depth=1),
    )
    assert len(got) == 20
    assert world.fabric.stats.backpressure_events > 0


def test_eager_sendrecv_wire_overhead_vs_bounce_capacity():
    """Regression: sendrecv mode prepends an 8-byte tag to the eager wire
    message; payloads whose wire size sits within that margin of the bounce
    buffer used to park forever (silent loss).  Every size in the boundary
    band must deliver — eager if it truly fits, rendezvous otherwise."""
    for size in range(3_980, 4_080, 8):
        world, got = run_world(
            "sendrecv_queue",
            [b"q" * size],
            fabric_kwargs=dict(bounce_buffers=4, bounce_buffer_size=4_096),
        )
        assert [len(a[0]) for a in got] == [size]
        pp = world.localities[0].parcelport
        assert pp.retry_queue_depth() == 0


def test_mpi_bounded_fabric_delivers_all():
    """Regression: MPISim used to drop sends the bounded fabric refused;
    they must queue MPI-internally and flush on progress."""
    for variant in ("mpi", "mpi_a"):
        world, got = run_world(
            variant,
            [bytes([i]) * 64 for i in range(10)],
            fabric_kwargs=dict(send_queue_depth=1),
        )
        assert len(got) == 10
        assert world.fabric.stats.backpressure_events > 0


def test_drain_raises_on_undeliverable_parked_post():
    """A post that can never succeed must turn into a loud drain error,
    not a quiet 'quiescent' return with the parcel lost."""
    world = World(2, make_parcelport_factory("lci"), devices_per_rank=2)
    world.localities[0].parcelport._retry_q.append(lambda: False)
    with pytest.raises(RuntimeError, match="parked"):
        world.drain()


def test_bounce_pool_recycles():
    pool = RegisteredBufferPool(2, 1024)
    a = pool.acquire(100)
    b = pool.acquire(1024)
    assert a is not None and b is not None
    assert pool.acquire(1) is None  # exhausted
    assert pool.acquire(2048) is None  # never fits
    pool.release(a)
    assert pool.free_count() == 1 and pool.acquire(512) is not None


def test_fabric_stats_protocol_split():
    fab = Fabric(2, devices_per_rank=1, recv_slots=4)
    nd = fab.device(0)
    assert nd.post_send(1, 0, b"e" * 10, eager=True)
    assert nd.post_send(1, 0, b"r" * 10)
    assert fab.stats.eager_msgs == 1 and fab.stats.rendezvous_msgs == 1


def test_send_queue_slot_freed_on_cq_reap():
    fab = Fabric(2, devices_per_rank=1, recv_slots=8, send_queue_depth=1)
    nd = fab.device(0)
    assert nd.post_send(1, 0, b"one")
    assert not nd.post_send(1, 0, b"two")  # ring full until CQ reaped
    assert fab.stats.backpressure_events == 1
    nd.poll_cq()
    assert nd.inflight_sends() == 0
    assert nd.post_send(1, 0, b"two")


# --------------------------------------------- aggregation x eager matrix
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_aggregation_eager_interaction(variant):
    """Every variant delivers a burst of same-destination parcels (which
    aggregation may merge) mixed across the eager/rendezvous boundary."""
    cfg = VARIANTS[variant].variant(aggregation=True)
    world = World(
        2,
        lambda loc, fab: LCIParcelport(loc, fab, cfg),
        devices_per_rank=cfg.ndevices,
    )
    got = []
    world.localities[1].register_action("sink", lambda *a: got.append(a))
    payloads = [b"s" * 32, b"m" * 3_000, b"L" * 20_000, b"s2" * 16, b"X" * 70_000]
    for pl in payloads:
        world.localities[0].async_action(1, "sink", pl, zero_copy_threshold=1024)
    world.drain()
    assert sorted(len(a[0]) for a in got) == sorted(len(p) for p in payloads)


# ------------------------------------------------- split_aggregate sub-ids
def test_split_aggregate_subids_unique_when_dense_and_large():
    """Regression: the old ``parcel_id * 1000 + i`` scheme collided for
    dense ids or aggregates of >= 1000 parcels; the reserved bit range
    cannot."""

    def mk(pid):
        return Parcel(parcel_id=pid, source=0, dest=1, nzc_chunk=Chunk(b"p"))

    # two dense neighbouring aggregates, each above the old 1000 limit
    agg_a = aggregate_parcels([mk(500) for _ in range(1100)])
    agg_b = aggregate_parcels([mk(501) for _ in range(1100)])
    ids_a = [p.parcel_id for p in split_aggregate(agg_a)]
    ids_b = [p.parcel_id for p in split_aggregate(agg_b)]
    all_ids = ids_a + ids_b
    assert len(set(all_ids)) == len(all_ids)
    # sub-ids live in the reserved range and preserve the base id
    for i, sid in enumerate(ids_a):
        assert sid >> AGG_SUB_SHIFT == i + 1
        assert sid & ((1 << AGG_SUB_SHIFT) - 1) == 500


def test_split_aggregate_roundtrip_content():
    parcels = [
        serialize_action(100 + i, 0, 1, "act", (bytes([i]) * (10 + i),), zero_copy_threshold=64)
        for i in range(5)
    ]
    agg = aggregate_parcels(parcels)
    out = split_aggregate(agg)
    assert len(out) == 5
    for orig, split in zip(parcels, out):
        assert split.nzc_chunk.data == orig.nzc_chunk.data
        assert [c.data for c in split.zc_chunks] == [c.data for c in orig.zc_chunks]
