"""Parcel serialization: roundtrip, zero-copy threshold, aggregation."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.parcel import (
    Chunk,
    Parcel,
    decode_header,
    encode_header,
    deserialize_action,
    serialize_action,
    zc_sizes_from_nzc,
)
from repro.core.parcelport import aggregate_parcels, is_aggregate, split_aggregate


def mk(pid, args, threshold=256):
    return serialize_action(pid, 0, 1, "act", args, zero_copy_threshold=threshold)


def test_roundtrip_small_args():
    p = mk(1, (b"abc", b"d" * 10))
    action, args = deserialize_action(p)
    assert action == "act"
    assert args == [b"abc", b"d" * 10]
    assert p.num_zc == 0  # all below threshold


def test_zero_copy_threshold():
    big = b"x" * 1000
    p = mk(2, (b"small", big), threshold=256)
    assert p.num_zc == 1
    assert p.zc_chunks[0].size == 1000
    action, args = deserialize_action(p)
    assert args == [b"small", big]


def test_zc_sizes_from_nzc():
    p = mk(3, (b"a" * 500, b"b" * 700), threshold=256)
    sizes = zc_sizes_from_nzc(p.nzc_chunk.data)
    assert tuple(sizes) == (500, 700)


def test_header_roundtrip():
    p = mk(4, (b"y" * 5000,), threshold=256)
    hdr = encode_header(p, device_index=3)
    h = decode_header(hdr)
    assert h.num_followups >= 1


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.binary(min_size=0, max_size=2048), min_size=1, max_size=5),
    st.integers(min_value=16, max_value=1024),
)
def test_roundtrip_property(args, threshold):
    p = serialize_action(7, 0, 1, "a", tuple(args), zero_copy_threshold=threshold)
    action, out = deserialize_action(p)
    assert out == list(args)
    for a in args:
        if len(a) > threshold:
            assert any(c.size == len(a) for c in p.zc_chunks)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.binary(min_size=1, max_size=512), min_size=1, max_size=8))
def test_aggregation_roundtrip(payloads):
    parcels = [mk(10 + i, (pl,)) for i, pl in enumerate(payloads)]
    agg = aggregate_parcels(parcels)
    assert is_aggregate(agg)
    back = split_aggregate(agg)
    assert len(back) == len(parcels)
    for orig, got in zip(parcels, back):
        a1, args1 = deserialize_action(orig)
        a2, args2 = deserialize_action(got)
        assert (a1, args1) == (a2, args2)
