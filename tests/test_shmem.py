"""The shared-memory transport (ISSUE 6): a TRUE one-sided put backend.

Covers the backend×verb conformance matrix (every CommInterface backend
completes each of the five verbs or raises UnsupportedCapabilityError
exactly per its advertised Capabilities), the slab mechanics (bytes
genuinely staged through the one shared buffer, receiver-owned slot
accounting with typed EAGAIN, both completion modes, both backings,
oversize rejection), the shared resource model on the shmem parcelport,
and the capability-ladder variant wiring.
"""
import pytest

from repro.core.comm import (
    CommInterface,
    PostStatus,
    ResourceLimits,
    UnsupportedCapabilityError,
)
from repro.core.comm.collective import CollectiveGroup
from repro.core.comm.shmem import DEFAULT_SLOTS, ShmemComm, ShmemGroup
from repro.core.completion import LCRQueue
from repro.core.device import LCIDevice
from repro.core.fabric import Fabric
from repro.core.harness import deliver_payloads, transport_stats
from repro.core.mpi_sim import MPISim
from repro.core.variants import VARIANTS, variant_names


# ------------------------------------------------- backend builders (matrix)
def _mk_lci():
    fab = Fabric(2, devices_per_rank=1)
    cq0, cq1 = LCRQueue(), LCRQueue()
    a = LCIDevice(fab.device(0, 0), put_target_comp=cq0)
    b = LCIDevice(fab.device(1, 0), put_target_comp=cq1)
    return a, b, cq1


def _mk_mpi():
    fab = Fabric(2, devices_per_rank=1)
    return MPISim(fab, 0), MPISim(fab, 1), None


def _mk_collective():
    grp = CollectiveGroup(2)
    return grp.endpoint(0), grp.endpoint(1), None


def _mk_shmem(completion_mode):
    grp = ShmemGroup(2, completion_mode=completion_mode)
    a, b = grp.endpoint(0), grp.endpoint(1)
    a.put_target_comp = LCRQueue()
    b.put_target_comp = LCRQueue()
    return a, b, b.put_target_comp


BACKENDS = {
    "lci": _mk_lci,
    "mpi": _mk_mpi,
    "collective": _mk_collective,
    "shmem_queue": lambda: _mk_shmem("queue"),
    "shmem_signal": lambda: _mk_shmem("signal"),
}


def _drive(*ends, rounds=50):
    for _ in range(rounds):
        if not any(e.progress() for e in ends):
            return


@pytest.mark.parametrize("name", sorted(BACKENDS))
def test_backend_verb_conformance_matrix(name):
    """The conformance contract: each backend either completes a verb or
    raises UnsupportedCapabilityError, exactly as its Capabilities say —
    never a silent no-op, never an undeclared success."""
    a, b, put_landing = BACKENDS[name]()
    assert isinstance(a, CommInterface) and isinstance(b, CommInterface)
    caps = a.capabilities

    # post_recv + post_send: the two-sided pair every backend must carry
    got = LCRQueue()
    sent = LCRQueue()
    b.post_recv(-1, 7, got, ctx="rx")
    assert a.post_send(1, 0, 7, b"hello", sent, ctx="tx") is PostStatus.OK
    _drive(a, b)
    rec = got.reap()
    assert rec is not None and rec.data == b"hello" and rec.src_rank == 0
    assert sent.reap() is not None  # the local send completion surfaced

    # post_put_signal: completes iff one_sided_put is advertised
    if caps.one_sided_put:
        comp = LCRQueue()
        assert a.post_put_signal(1, 0, b"put-bytes", comp, ctx="put") is PostStatus.OK
        _drive(a, b)
        landed = put_landing.reap()
        assert landed is not None and landed.data == b"put-bytes"
        assert landed.src_rank == 0
        assert comp.reap() is not None  # local injection completion
    else:
        with pytest.raises(UnsupportedCapabilityError):
            a.post_put_signal(1, 0, b"put-bytes", LCRQueue())

    # progress + poll: every backend exposes both driving verbs, and a
    # quiesced endpoint reports no movement
    assert a.progress() in (True, False)
    assert a.poll() in (True, False)
    assert b.progress() is False and b.poll() is False


def test_matrix_capabilities_are_the_advertised_ladder():
    """The matrix rows advertise exactly the capability set the paper's
    ladder assigns them (§2.3/§3.3.1)."""
    for name, one_sided in (("lci", True), ("mpi", False), ("collective", False),
                            ("shmem_queue", True), ("shmem_signal", True)):
        a, _b, _ = BACKENDS[name]()
        assert a.capabilities.one_sided_put is one_sided, name


# ----------------------------------------------------------- slab mechanics
def test_put_bytes_genuinely_stage_through_shared_slab():
    """The tentpole property: the payload bytes are IN the receiver-owned
    shared slab before the receiver ever runs — a real one-sided store,
    not a Python-object hand-off."""
    grp = ShmemGroup(2, completion_mode="queue")
    a, b = grp.endpoint(0), grp.endpoint(1)
    a.put_target_comp = LCRQueue()
    b.put_target_comp = LCRQueue()
    payload = bytes(range(256)) * 4
    assert a.post_put_signal(1, 0, payload, LCRQueue()) is PostStatus.OK
    # receiver has NOT progressed: read the slab directly
    seg = grp.segments[(1, 0)]
    assert seg.pending()
    kind, src, src_dev, tag, stored = seg.read(0)  # first allocated slot
    assert stored == payload and src == 0
    # now the receiver consumes the very same slot
    b.progress()
    rec = b.put_target_comp.reap()
    assert rec.data == payload and rec.op == "put_recv"
    assert seg.free_slots() == grp.nslots  # slot returned to the pool


def test_put_slot_exhaustion_surfaces_eagain_buffer():
    """Receiver-owned slot accounting from the shared ResourceLimits: an
    exhausted remote slab refuses the put with EAGAIN_BUFFER (and counts
    backpressure); the receiver's progress frees slots and the post then
    succeeds — throttled, never lost."""
    lim = ResourceLimits(recv_slots=2, bounce_buffer_size=1024)
    grp = ShmemGroup(2, limits=lim, completion_mode="queue")
    a, b = grp.endpoint(0), grp.endpoint(1)
    a.put_target_comp = LCRQueue()
    b.put_target_comp = LCRQueue()
    assert grp.nslots == 2
    assert a.post_put_signal(1, 0, b"one", LCRQueue()) is PostStatus.OK
    assert a.post_put_signal(1, 0, b"two", LCRQueue()) is PostStatus.OK
    assert a.post_put_signal(1, 0, b"three", LCRQueue()) is PostStatus.EAGAIN_BUFFER
    assert grp.stats.backpressure_events == 1
    b.progress()  # consume both slots
    assert a.post_put_signal(1, 0, b"three", LCRQueue()) is PostStatus.OK
    _drive(a, b)
    assert [b.put_target_comp.reap().data for _ in range(3)] == [b"one", b"two", b"three"]


def test_put_ring_exhaustion_surfaces_eagain_queue():
    """A full local injection ring is a DIFFERENT refusal than an
    exhausted remote slab, exactly as on the fabric-backed device."""
    lim = ResourceLimits(send_queue_depth=1, bounce_buffer_size=1024)
    grp = ShmemGroup(2, limits=lim, completion_mode="queue")
    a, b = grp.endpoint(0), grp.endpoint(1)
    a.put_target_comp = LCRQueue()
    b.put_target_comp = LCRQueue()
    assert a.capabilities.bounded_injection
    assert a.post_put_signal(1, 0, b"x", LCRQueue()) is PostStatus.OK
    assert a.post_put_signal(1, 0, b"y", LCRQueue()) is PostStatus.EAGAIN_QUEUE
    a.progress()  # the local completion frees the ring slot
    assert a.post_put_signal(1, 0, b"y", LCRQueue()) is PostStatus.OK


def test_signal_mode_discovers_puts_by_scanning():
    """Put-signal rung: commits raise the per-slot flag in the slab, and
    the receiver's progress claims them by scanning — no descriptor ever
    enters the ring."""
    grp = ShmemGroup(2, completion_mode="signal")
    a, b = grp.endpoint(0), grp.endpoint(1)
    a.put_target_comp = LCRQueue()
    b.put_target_comp = LCRQueue()
    a.post_put_signal(1, 0, b"sig", LCRQueue())
    seg = grp.segments[(1, 0)]
    assert seg.pop_announced() is None  # nothing in the descriptor ring
    assert seg.buf[0] == 2  # _ST_SIG raised in the shared state array
    b.progress()
    assert b.put_target_comp.reap().data == b"sig"


def test_oversized_message_rejected_with_valueerror():
    grp = ShmemGroup(2, limits=ResourceLimits(bounce_buffer_size=64))
    a = grp.endpoint(0)
    a.put_target_comp = LCRQueue()
    with pytest.raises(ValueError, match="slot capacity"):
        a.post_put_signal(1, 0, b"z" * 65, LCRQueue())
    with pytest.raises(ValueError, match="slot capacity"):
        a.post_send(1, 0, 3, b"z" * 65, LCRQueue())


def test_put_without_registered_target_is_uncapable():
    grp = ShmemGroup(2)
    a = grp.endpoint(0)
    assert not a.capabilities.one_sided_put
    with pytest.raises(UnsupportedCapabilityError):
        a.post_put_signal(1, 0, b"x", LCRQueue())


def test_shm_backing_roundtrip_and_explicit_close():
    """The named-POSIX-segment backing: same slab semantics, released by
    the explicit close (idempotent; the weakref finalizer is only the GC
    backstop)."""
    grp = ShmemGroup(2, limits=ResourceLimits(recv_slots=4, bounce_buffer_size=256),
                     backing="shm")
    a, b = grp.endpoint(0), grp.endpoint(1)
    a.put_target_comp = LCRQueue()
    b.put_target_comp = LCRQueue()
    payload = b"\xa5" * 200
    assert a.post_put_signal(1, 0, payload, LCRQueue()) is PostStatus.OK
    b.progress()
    assert b.put_target_comp.reap().data == payload
    _drive(a, b)
    grp.close()
    grp.close()  # idempotent
    for seg in grp.segments.values():
        assert seg._closed


# -------------------------------------------- parcelport / variant wiring
def test_shmem_variants_registered_with_ladder_configs():
    """The rungs map onto the EXISTING config axes — no new fields, so the
    DES inherits them through sim_config_for_variant unchanged."""
    assert VARIANTS["shmem"].header_mode == "sendrecv"
    assert VARIANTS["shmem"].header_comp == "queue"
    assert VARIANTS["shmem_put"].header_mode == "put"
    assert VARIANTS["shmem_put"].header_comp == "sync"
    assert VARIANTS["shmem_putq"].header_mode == "put"
    assert VARIANTS["shmem_putq"].header_comp == "queue"
    assert VARIANTS["shmem_prg2"].progress_workers == 2
    assert {"shmem", "shmem_put", "shmem_putq", "shmem_prg2"} <= set(variant_names())


def test_shmem_parcelport_shares_resource_model():
    """variant delivery over the shmem transport under a tight shared
    ResourceLimits: the one ShmemGroup of the world draws the fabric's
    limits, backpressures, and still delivers everything."""
    lim = ResourceLimits(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=65_536)
    world, got = deliver_payloads("shmem_putq", [bytes([i]) * 600 for i in range(30)],
                                  fabric_kwargs={"limits": lim})
    assert len(got) == 30
    group = world.fabric._shmem_group
    assert group.limits is lim
    st = transport_stats(world)
    assert st is group.stats
    assert st.puts > 0  # headers genuinely rode one-sided puts
    assert st.backpressure_events > 0  # the bound actually bit


def test_shmem_two_sided_rung_issues_no_puts():
    world, got = deliver_payloads("shmem", [bytes([i]) * 600 for i in range(10)])
    assert len(got) == 10
    st = transport_stats(world)
    assert st.puts == 0 and st.sends > 0


def test_one_group_per_world_and_completion_mode_pinned():
    """shmem_group_for keys the group on the fabric and refuses a second
    completion mode — one world, one discovery discipline."""
    from repro.core.comm.shmem import shmem_group_for

    fab = Fabric(2)
    g1 = shmem_group_for(fab, completion_mode="queue")
    assert shmem_group_for(fab, completion_mode="queue") is g1
    with pytest.raises(AssertionError, match="one completion mode"):
        shmem_group_for(fab, completion_mode="signal")


def test_default_slot_count_matches_device_prepost_depth():
    assert ShmemGroup(2).nslots == DEFAULT_SLOTS
    assert isinstance(ShmemGroup(2).endpoint(0), ShmemComm)

# ------------------------------------------- fleet verb usage (ISSUE 7, S2)
# The serving fleet extends the conformance matrix to multi-endpoint
# worlds: one router endpoint (rank 0) + N worker endpoints on ONE shared
# group.  Requests always ride the two-sided pair; responses ride
# post_put_signal into the router-owned landing queue exactly when the
# backend's Capabilities advertise one_sided_put — never selected by
# backend name or type.


def _mk_fleet_world(kind, workers=2):
    if kind == "collective":
        grp = CollectiveGroup(1 + workers, 1)
    else:
        grp = ShmemGroup(1 + workers, 1, completion_mode=kind.split("_")[1])
    return grp, grp.endpoint(0), [grp.endpoint(1 + w) for w in range(workers)]


@pytest.mark.parametrize("kind", ["collective", "shmem_queue", "shmem_signal"])
def test_fleet_verb_usage_conformance(kind):
    """Router/worker traffic at the raw verb level over a 1+N world:
    two-sided requests fan out to every worker; responses converge on the
    ONE router-owned landing queue — via put iff capable, with honest
    src_rank attribution either way."""
    grp, router, ws = _mk_fleet_world(kind)
    landing = LCRQueue()
    put_capable = kind != "collective"
    if put_capable:
        router.put_target_comp = landing  # router-owned landing slots
        for ep in ws:  # what makes each worker's capability honest
            ep.put_target_comp = LCRQueue()
    assert all(ep.capabilities.one_sided_put is put_capable for ep in ws)

    # router -> each worker: the two-sided request pair, per-worker tag'd CQ
    req_cqs = []
    for w, ep in enumerate(ws):
        cq = LCRQueue()
        ep.post_recv(-1, 11, cq, ctx=f"request:{w}")
        req_cqs.append(cq)
        st = router.post_send(1 + w, 0, 11, b"req%d" % w, LCRQueue(), ctx="tx")
        assert st is PostStatus.OK
    _drive(router, *ws)
    for w, cq in enumerate(req_cqs):
        rec = cq.reap()
        assert rec is not None and rec.data == b"req%d" % w
        assert rec.src_rank == 0 and rec.ctx == f"request:{w}"

    # worker -> router: put iff the Capabilities say so, else two-sided
    if put_capable:
        for w, ep in enumerate(ws):
            st = ep.post_put_signal(0, 0, b"resp%d" % w, LCRQueue(), ctx="tx")
            assert st is PostStatus.OK
    else:
        for w, ep in enumerate(ws):
            with pytest.raises(UnsupportedCapabilityError):
                ep.post_put_signal(0, 0, b"resp%d" % w, LCRQueue())
            router.post_recv(-1, 12, landing, ctx="response")
            st = ep.post_send(0, 0, 12, b"resp%d" % w, LCRQueue(), ctx="tx")
            assert st is PostStatus.OK
    _drive(router, *ws)
    got = {}
    while True:
        rec = landing.reap()
        if rec is None:
            break
        got[rec.src_rank] = rec.data
    assert got == {1 + w: b"resp%d" % w for w in range(len(ws))}


@pytest.mark.parametrize("transport", ["shmem", "collective"])
def test_fleet_channels_share_router_landing(transport):
    """The fleet's per-worker channels all land responses in channel 0's
    response queue (the router-owned slots), each channel's put selection
    reads ONLY its server endpoint's Capabilities, and rebinding the
    shared client endpoint to a different landing queue is refused."""
    import jax

    from repro.configs import SMOKES
    from repro.core.comm.collective import CommChannel
    from repro.models import init_params
    from repro.serve import Fleet, FleetConfig

    arch = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), arch)
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=3, slots=3, context=64, transport=transport),
    )
    try:
        shared = fleet.channels[0].response_cq
        for ch in fleet.channels:
            assert ch.response_cq is shared
            assert ch._put_responses == ch.server.capabilities.one_sided_put
            assert ch._put_responses == (transport == "shmem")
        if transport == "shmem":  # the rebind guard lives on put targets
            with pytest.raises(AssertionError, match="landing"):
                CommChannel(
                    backend=transport, group=fleet.group,
                    client_rank=0, server_rank=1, response_cq=LCRQueue(),
                )
    finally:
        fleet.close()
