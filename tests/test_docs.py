"""Docs stay truthful: intra-repo links resolve, README's quoted commands
parse, and the documented variant matrix covers every code variant.  The
same checks run standalone in CI's docs job (``python tools/check_docs.py``)."""
import importlib.util
import shutil
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location("check_docs", REPO / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_intra_repo_links_resolve():
    failures: list = []
    check_docs.check_links(failures)
    assert not failures, failures


@pytest.mark.skipif(shutil.which("bash") is None, reason="bash not available")
def test_readme_commands_parse():
    failures: list = []
    check_docs.check_readme_commands(failures)
    assert not failures, failures


def test_variant_table_covers_all_variants():
    failures: list = []
    check_docs.check_variant_table(failures)
    assert not failures, failures


def test_variant_table_mentions_new_variant():
    # the table must document the threshold-aware aggregation variant
    text = (REPO / "docs" / "VARIANTS.md").read_text()
    assert "lci_agg_eager" in text
