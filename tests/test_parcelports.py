"""End-to-end parcel delivery across every parcelport variant (Figs 6-9)."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.parcelport import World
from repro.core.variants import make_parcelport_factory, variant_names

SMALL_VARIANTS = [
    "mpi",
    "mpi_a",
    "lci",
    "sendrecv_queue",
    "sendrecv_sync",
    "sync",
    "queue_lock",
    "queue_ms",
    "block",
    "try",
    "try_progress",
    "block_d2",
    "lci_d4",
    "lci_try_d4",
]


def deliver(variant, payloads, n_loc=2, devices=None):
    from repro.core.variants import max_devices

    world = World(
        n_loc,
        make_parcelport_factory(variant),
        devices_per_rank=devices or max_devices(variant),
    )
    got = []
    for loc in world.localities:
        loc.register_action("sink", lambda *args, _got=got: _got.append(args))
    for i, pl in enumerate(payloads):
        world.localities[i % n_loc].async_action((i + 1) % n_loc, "sink", pl)
    world.drain()
    return got


@pytest.mark.parametrize("variant", SMALL_VARIANTS)
def test_variant_delivers_small_and_large(variant):
    payloads = [b"s" * 10, b"L" * 50_000, b"m" * 2_000, b"X" * 200_000]
    got = deliver(variant, payloads)
    assert sorted(len(a[0]) for a in got) == sorted(len(p) for p in payloads)
    assert all(set(a[0]) == {a[0][0]} for a in got if a[0])  # content intact


@pytest.mark.parametrize("variant", ["mpi", "mpi_a", "lci"])
def test_many_parcels_multi_locality(variant):
    payloads = [bytes([i % 256]) * (10 + 97 * i % 5000) for i in range(60)]
    got = deliver(variant, payloads, n_loc=4)
    assert len(got) == len(payloads)


def test_send_callback_fires():
    world = World(2, make_parcelport_factory("lci"), devices_per_rank=2)
    world.localities[1].register_action("nop", lambda *a: None)
    fired = []
    world.localities[0].async_action(1, "nop", b"x" * 99_999, cb=lambda p: fired.append(1))
    world.drain()
    assert fired == [1]


def test_zero_copy_chunks_arrive_in_order():
    world = World(2, make_parcelport_factory("lci"), devices_per_rank=2)
    out = []
    world.localities[1].register_action("multi", lambda *a: out.append(a))
    big1, big2 = b"A" * 100_000, b"B" * 80_000
    world.localities[0].async_action(1, "multi", b"meta", big1, big2)
    world.drain()
    assert out == [(b"meta", big1, big2)]


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=100_000), min_size=1, max_size=10),
    st.sampled_from(["mpi", "mpi_a", "lci", "sendrecv_sync", "block"]),
)
def test_delivery_property(sizes, variant):
    """Any mix of sizes is delivered exactly once on any variant."""
    payloads = [bytes([i % 251]) * s for i, s in enumerate(sizes)]
    got = deliver(variant, payloads)
    assert sorted(len(a[0]) for a in got) == sorted(sizes)


def test_variant_names_cover_paper_figs():
    names = variant_names()
    for required in ("mpi", "mpi_a", "lci", "sendrecv_sync", "sync", "queue_ms",
                     "block", "try", "try_progress", "block_d2", "lci_d32", "lci_try_d8"):
        assert required in names
