"""DES simulator: determinism + the paper's qualitative orderings."""
import pytest

from repro.amtsim.costs import DELTA, EXPANSE
from repro.amtsim.des import Acquire, Env, Lock, Store, Timeout
from repro.amtsim.workloads import chains, flood, octotiger


# ------------------------------------------------------------------- kernel
def test_des_timeout_ordering():
    env = Env()
    log = []

    def proc(name, dt):
        yield Timeout(dt)
        log.append((env.now, name))

    env.process(proc("b", 2e-6))
    env.process(proc("a", 1e-6))
    env.run()
    assert [n for _, n in log] == ["a", "b"]


def test_des_lock_fifo():
    env = Env()
    order = []

    def proc(name):
        yield Acquire(lock)
        yield Timeout(1e-6)
        order.append(name)
        lock.release()

    lock = Lock(env)
    for n in ("p0", "p1", "p2"):
        env.process(proc(n))
    env.run()
    assert order == ["p0", "p1", "p2"]
    assert lock.contentions == 2


def test_des_store():
    env = Env()
    got = []

    def consumer():
        from repro.amtsim.des import Get

        item = yield Get(store)
        got.append(item)

    store = Store(env)
    env.process(consumer())
    store.put("x")
    env.run()
    assert got == ["x"]


# ---------------------------------------------------------------- workloads
def test_flood_deterministic():
    r1 = flood("lci", msg_size=8, nthreads=8, nmsgs=500)
    r2 = flood("lci", msg_size=8, nthreads=8, nmsgs=500)
    assert r1.elapsed == r2.elapsed and r1.messages == r2.messages


def test_flood_orderings_small_msgs():
    """Paper Fig 3a qualitative: lci > mpi_a > mpi at 8 B."""
    rates = {v: flood(v, msg_size=8, nthreads=32, nmsgs=2000).rate for v in ("lci", "mpi", "mpi_a")}
    assert rates["lci"] > rates["mpi_a"] > rates["mpi"]


def test_flood_orderings_large_msgs():
    """Paper §4.2: zero-copy chunks cannot be combined, so aggregation's
    large-message gain collapses relative to its small-message gain (the
    *ordering* mpi_a < mpi on Expanse is not reproduced by the cost model
    — documented in EXPERIMENTS.md §Paper-validation)."""
    small = {v: flood(v, msg_size=8, nthreads=32, nmsgs=2000).rate for v in ("mpi", "mpi_a")}
    large = {v: flood(v, msg_size=16384, nthreads=32, nmsgs=1000).rate for v in ("lci", "mpi", "mpi_a")}
    assert large["lci"] > large["mpi_a"] and large["lci"] > large["mpi"]
    gain_small = small["mpi_a"] / small["mpi"]
    gain_large = large["mpi_a"] / large["mpi"]
    assert gain_large < 0.5 * gain_small  # aggregation helps large messages far less


def test_latency_ordering():
    lat = {v: chains(v, msg_size=8, nchains=8, nsteps=20, nthreads=8).elapsed for v in ("lci", "mpi")}
    assert lat["lci"] < lat["mpi"]


def test_factor_study_multithreading_ladder():
    """Fig 8: block ≲ try ≲ try_progress ≲ lci on the flood microbenchmark."""
    rates = {
        v: flood(v, msg_size=8, nthreads=32, nmsgs=1500).rate
        for v in ("block", "try_progress", "lci")
    }
    assert rates["lci"] >= rates["try_progress"] >= rates["block"]


def test_device_scaling_monotone():
    """Fig 9: more devices → higher message rate (lockless family)."""
    r1 = flood("lci_d1", msg_size=8, nthreads=32, nmsgs=2000).rate
    r4 = flood("lci_d4", msg_size=8, nthreads=32, nmsgs=2000).rate
    assert r4 > r1 * 1.5


def test_octotiger_lci_beats_mpi():
    e = {}
    for v in ("lci", "mpi"):
        e[v] = octotiger(v, n_nodes=4, workers=8, total_subgrids=256, timesteps=3).elapsed
    assert e["lci"] < e["mpi"]


def test_slingshot_lock_penalty():
    """Fig 5: Delta's libfabric CQ lock lowers peak message rate vs Expanse."""
    r_exp = flood("lci", msg_size=8, nthreads=32, nmsgs=1500, platform=EXPANSE).rate
    r_delta = flood("lci", msg_size=8, nthreads=32, nmsgs=1500, platform=DELTA).rate
    assert r_delta < r_exp


# ------------------------------------------------------- bounded injection
def _bounded_cfg(depth=2, bufs=2, buf_size=16_384, recv_slots=0):
    import dataclasses

    from repro.amtsim.parcelport_sim import sim_config_for_variant
    from repro.core.comm.resources import ResourceLimits

    return dataclasses.replace(
        sim_config_for_variant("lci"),
        name="lci_bounded",
        limits=ResourceLimits(
            send_queue_depth=depth,
            bounce_buffers=bufs,
            bounce_buffer_size=buf_size,
            recv_slots=recv_slots,
        ),
    )


def test_des_bounded_injection_backpressure_and_delivery():
    """The acceptance gate: a small-queue DES config reports nonzero
    backpressure_events and still delivers everything; the unbounded model
    reports exactly zero."""
    r_unbounded = flood("lci", msg_size=64, nthreads=8, nmsgs=400)
    r_bounded = flood(_bounded_cfg(), msg_size=64, nthreads=8, nmsgs=400)
    assert r_unbounded.backpressure_events == 0
    assert r_unbounded.messages == 400
    assert r_bounded.backpressure_events > 0
    assert r_bounded.messages == 400  # throttled, never lost
    # the ring depth is a hard bound, and parked posts actually queued up
    assert 0 < r_bounded.send_queue_hw <= 2
    assert r_bounded.retry_queue_hw > 0


def test_des_bounded_injection_deterministic():
    r1 = flood(_bounded_cfg(), msg_size=64, nthreads=8, nmsgs=300)
    r2 = flood(_bounded_cfg(), msg_size=64, nthreads=8, nmsgs=300)
    assert (r1.elapsed, r1.messages, r1.backpressure_events) == (
        r2.elapsed,
        r2.messages,
        r2.backpressure_events,
    )


def test_des_bounded_injection_throttles_rate():
    """Backpressure is a cost, not a free pass: the bounded config cannot
    outrun the unbounded one (the paper's contention-mitigation regime —
    injection is limited by resource recycling, Figs 3/8)."""
    r_u = flood("lci", msg_size=64, nthreads=16, nmsgs=1000)
    r_b = flood(_bounded_cfg(depth=1, bufs=1), msg_size=64, nthreads=16, nmsgs=1000)
    assert r_b.messages == 1000
    assert r_b.rate < r_u.rate


def test_des_bounded_mpi_path_delivers():
    import dataclasses

    from repro.amtsim.parcelport_sim import sim_config_for_variant
    from repro.core.comm.resources import ResourceLimits

    cfg = dataclasses.replace(
        sim_config_for_variant("mpi"), name="mpi_bounded", limits=ResourceLimits(send_queue_depth=1)
    )
    r = flood(cfg, msg_size=64, nthreads=4, nmsgs=150)
    assert r.messages == 150
    assert r.backpressure_events > 0


def test_des_bounded_chains_complete():
    r = chains(_bounded_cfg(depth=1, bufs=1), msg_size=64, nchains=8, nsteps=10, nthreads=8)
    assert r.messages == 80


def test_des_rnr_receiver_not_ready_counted_and_recovered():
    """ROADMAP follow-up: with ``limits.recv_slots`` set the DES models RNR
    the way ``core.fabric`` does — an arrival beyond the posted-receive
    depth is counted, parked, and redelivered on reap (never lost),
    surfaced through injection_stats / MicroResult.rnr_events."""
    r = flood(_bounded_cfg(depth=0, bufs=0, recv_slots=1), msg_size=64, nthreads=8, nmsgs=300)
    assert r.rnr_events > 0
    assert r.messages == 300  # retransmitted, not dropped


def test_des_rnr_scoped_to_bounded_mode():
    """The unbounded model never reports RNR (recv_slots=0 takes no new
    code path), and the RNR path is deterministic."""
    assert flood("lci", msg_size=64, nthreads=8, nmsgs=300).rnr_events == 0
    cfg = _bounded_cfg(depth=0, bufs=0, recv_slots=1)
    r1 = flood(cfg, msg_size=64, nthreads=8, nmsgs=300)
    r2 = flood(cfg, msg_size=64, nthreads=8, nmsgs=300)
    assert (r1.elapsed, r1.rnr_events) == (r2.elapsed, r2.rnr_events)


def test_des_eager_aggregate_charges_bounce_copy_mechanism():
    """ROADMAP follow-up: an eager aggregate bigger than the piggyback
    limit pays the calibrated bounce-buffer copy (its own mechanism), on
    top of the serialize/merge cost.  Pinned by inflating the constant:
    the over-piggyback aggregate workload slows down; a sub-piggyback
    workload is untouched."""
    from repro.amtsim.costs import DEFAULT_MECHANISMS

    inflated = DEFAULT_MECHANISMS.variant(t_bounce_copy_per_byte=100 * DEFAULT_MECHANISMS.t_bounce_copy_per_byte)
    # 6 KB parcels aggregate (agg_eager, 16 KiB budget) into >8 KiB eager
    # batches -> the bounce copy is charged per aggregate
    base = flood("lci_agg_eager", msg_size=6_000, nthreads=8, nmsgs=200)
    slow = flood("lci_agg_eager", msg_size=6_000, nthreads=8, nmsgs=200, mech=inflated)
    assert slow.messages == base.messages == 200
    assert slow.elapsed > base.elapsed
    # control: nothing over the piggyback limit ships -> constant is inert
    base_small = flood("lci", msg_size=512, nthreads=8, nmsgs=200)
    same_small = flood("lci", msg_size=512, nthreads=8, nmsgs=200, mech=inflated)
    assert same_small.elapsed == base_small.elapsed


def test_des_eager_capped_by_bounce_buffer_size():
    """A payload under the eager threshold but over the bounce-buffer size
    must take rendezvous instead of parking forever (mirrors the functional
    layer's capacity check)."""
    cfg = _bounded_cfg(depth=0, bufs=2, buf_size=4_096)
    import dataclasses

    cfg = dataclasses.replace(cfg, eager_threshold=65_536)
    r = flood(cfg, msg_size=12_000, nthreads=4, nmsgs=100, max_seconds=2.0)
    assert r.messages == 100


def test_des_agg_batches_greedy_packing():
    """Threshold-aware DES aggregation packs FIFO up to eager_threshold;
    an op alone over budget gets its own batch."""
    from repro.amtsim.parcelport_sim import ParcelOp, SimWorld, sim_config_for_variant

    world = SimWorld(2, 1, sim_config_for_variant("lci_agg_eager"))  # 16 KiB budget
    ops = [ParcelOp(src=0, dst=1, size=s) for s in (6_000, 6_000, 6_000, 20_000, 100)]
    batches = world._agg_batches(ops)
    assert [[op.size for op in b] for b in batches] == [[6_000, 6_000], [6_000], [20_000], [100]]


def test_des_agg_eager_flood_delivers():
    r = flood("lci_agg_eager", msg_size=600, nthreads=8, nmsgs=400)
    assert r.messages == 400
    assert r.backpressure_events == 0


def test_des_store_tracks_high_water():
    from repro.amtsim.des import Env, Store

    env = Env()
    store = Store(env)
    for i in range(5):
        store.put(i)
    store.get_nowait()
    store.put(99)
    assert store.max_depth == 5


def test_dedicated_progress_cores_not_justified():
    """Paper §3.3.4: 'we have not found sufficient evidence to justify'
    dedicated progress cores.  Reproduced: with a lock-free runtime they
    give no microbenchmark gain and cost the application compute cores."""
    import dataclasses

    from repro.amtsim.parcelport_sim import sim_config_for_variant

    base = sim_config_for_variant("lci")
    with_pw = dataclasses.replace(base, name="lci_pw4", progress_workers=4)
    r0 = flood(base, msg_size=8, nthreads=32, nmsgs=2000)
    r4 = flood(with_pw, msg_size=8, nthreads=32, nmsgs=2000)
    assert r4.rate < r0.rate * 1.1  # no meaningful gain
    a0 = octotiger(base, n_nodes=4, workers=8, total_subgrids=256, timesteps=3)
    a4 = octotiger(with_pw, n_nodes=4, workers=8, total_subgrids=256, timesteps=3)
    assert a4.elapsed > a0.elapsed  # reserved cores hurt the application
