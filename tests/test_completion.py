"""Completion mechanisms: MPMC queues (incl. threaded lossless/duplicate-free
property checks), synchronizers, pools — paper §3.3.2/§5.2 structures."""
import threading

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.completion import (
    LCRQueue,
    LockQueue,
    MichaelScottQueue,
    Synchronizer,
    SynchronizerPool,
    make_completion_queue,
)

QUEUES = [LCRQueue, MichaelScottQueue, LockQueue]


@pytest.mark.parametrize("qcls", QUEUES)
def test_fifo_single_thread(qcls):
    q = qcls()
    for i in range(100):
        q.push(i)
    out = [q.pop() for _ in range(100)]
    assert out == list(range(100))
    assert q.pop() is None


@pytest.mark.parametrize("qcls", QUEUES)
def test_interleaved(qcls):
    q = qcls()
    q.push("a")
    assert q.pop() == "a"
    assert q.pop() is None
    q.push("b")
    q.push("c")
    assert q.pop() == "b"


def test_lcrq_segment_overflow():
    q = LCRQueue(segment_size=8)
    n = 100
    for i in range(n):
        q.push(i)
    got = [q.pop() for _ in range(n)]
    assert got == list(range(n))


@pytest.mark.parametrize("qcls", QUEUES)
def test_mpmc_lossless_duplicate_free(qcls):
    """Threaded torture: every pushed item popped exactly once."""
    q = qcls()
    n_prod, n_cons, per = 4, 4, 500
    popped = []
    popped_lock = threading.Lock()
    done = threading.Event()

    def producer(pid):
        for i in range(per):
            q.push((pid, i))

    def consumer():
        local = []
        while not done.is_set() or len(q) > 0:
            item = q.pop()
            if item is not None:
                local.append(item)
        with popped_lock:
            popped.extend(local)

    cons = [threading.Thread(target=consumer) for _ in range(n_cons)]
    prods = [threading.Thread(target=producer, args=(p,)) for p in range(n_prod)]
    for t in cons + prods:
        t.start()
    for t in prods:
        t.join()
    done.set()
    for t in cons:
        t.join()
    # drain stragglers
    while True:
        item = q.pop()
        if item is None:
            break
        popped.append(item)
    assert sorted(popped) == sorted((p, i) for p in range(n_prod) for i in range(per))


def test_synchronizer_single_slot():
    s = Synchronizer()
    assert s.test() is None
    s.signal("x")
    assert s.ready
    assert s.test() == "x"
    assert s.test() is None  # consumed


def test_synchronizer_pool_round_robin():
    pool = SynchronizerPool()
    syncs = [Synchronizer() for _ in range(3)]
    for i, s in enumerate(syncs):
        pool.add(s, payload=i)
    syncs[2].signal("done")
    results = [pool.poll_one() for _ in range(6)]
    hits = [r for r in results if r is not None]
    assert hits == [(2, "done")]
    assert len(pool) == 2  # completed one removed


def test_factory():
    for kind in ("lcrq", "ms", "lock"):
        assert make_completion_queue(kind).cost_model_name in ("lcrq", "ms", "lock")
    with pytest.raises(ValueError):
        make_completion_queue("bogus")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(), min_size=0, max_size=200))
def test_lcrq_sequential_equiv_property(items):
    """LCRQ behaves as a FIFO queue under any sequential program."""
    q = LCRQueue(segment_size=16)
    import collections

    ref = collections.deque()
    for it in items:
        q.push((it,))
        ref.append((it,))
    while ref:
        assert q.pop() == ref.popleft()
    assert q.pop() is None
