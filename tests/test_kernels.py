"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True on CPU; BlockSpecs are the TPU deployment config)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.ref import attention_ref, grouped_matmul_ref, ssd_chunk_ref
from repro.kernels.ssd_scan import ssd_chunk_kernel

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return 5e-5 if dtype == jnp.float32 else 4e-2


# ------------------------------------------------------------ flash attention
FLASH_CASES = [
    # (B, S, H, KV, D, causal, window, chunk, dtype, bq, bk)
    (2, 256, 4, 2, 64, True, 0, 0, jnp.float32, 128, 128),
    (1, 512, 4, 4, 128, True, 0, 0, jnp.float32, 128, 128),
    (2, 256, 8, 2, 64, True, 64, 0, jnp.float32, 128, 128),
    (2, 256, 4, 2, 64, True, 0, 128, jnp.float32, 128, 128),
    (1, 256, 8, 2, 64, False, 0, 0, jnp.float32, 128, 128),
    (1, 256, 4, 2, 128, True, 0, 0, jnp.bfloat16, 128, 128),
    (1, 128, 2, 2, 64, True, 0, 0, jnp.float32, 64, 64),
    (1, 384, 6, 3, 64, True, 128, 0, jnp.float32, 128, 128),
    (2, 128, 2, 1, 32, True, 0, 0, jnp.float32, 64, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_sweep(case):
    b, s, h, kv, d, causal, window, chunk, dtype, bq, bk = case
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < _tol(dtype), f"{case}: err={err}"


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([64, 128]),
    st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    st.sampled_from([128, 256]),
    st.booleans(),
)
def test_flash_attention_property(d, heads, s, causal):
    h, kv = heads
    ks = jax.random.split(jax.random.PRNGKey(d * s + h), 3)
    q = jax.random.normal(ks[0], (1, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, s, kv, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-5


# ------------------------------------------------------------------ SSD chunk
SSD_CASES = [
    # (B, H, G, nc, Q, P, N)
    (2, 4, 2, 3, 64, 64, 128),
    (1, 2, 1, 2, 128, 64, 64),
    (1, 8, 8, 1, 64, 32, 128),
    (2, 2, 1, 4, 32, 64, 32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_chunk_kernel_sweep(case):
    B, H, G, NC, Q, P, N = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 4)
    a = -jnp.abs(jax.random.normal(ks[0], (B, H, NC, Q))) * 0.1
    x = jax.random.normal(ks[1], (B, H, NC, Q, P))
    bb = jax.random.normal(ks[2], (B, G, NC, Q, N)) * 0.3
    cc = jax.random.normal(ks[3], (B, G, NC, Q, N)) * 0.3
    y, st_ = ssd_chunk_kernel(a, x, bb, cc, interpret=True)
    rep = H // G
    for b_ in range(B):
        for h_ in range(H):
            for c_ in range(NC):
                yr, sr = ssd_chunk_ref(
                    x[b_, h_, c_][None, :, None, :],
                    a[b_, h_, c_][None, :, None],
                    bb[b_, h_ // rep, c_][None, :, None, :],
                    cc[b_, h_ // rep, c_][None, :, None, :],
                )
                assert float(jnp.max(jnp.abs(y[b_, h_, c_] - yr[0, :, 0]))) < 1e-4
                assert float(jnp.max(jnp.abs(st_[b_, h_, c_] - sr[0, 0]))) < 1e-4


def test_ssd_model_path_matches_kernel_path():
    """ssd_chunked (model) == kernel-backed path, end to end."""
    import os

    from repro.models.ssm import ssd_chunked

    B, S, H, G, P, N, Q = 2, 64, 4, 1, 32, 64, 16
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    a_dt = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.1
    b = jax.random.normal(ks[2], (B, S, G, N), jnp.float32) * 0.3
    c = jax.random.normal(ks[3], (B, S, G, N), jnp.float32) * 0.3
    os.environ["REPRO_KERNELS"] = "xla"
    y1, s1 = ssd_chunked(x, a_dt, b, c, Q)
    os.environ["REPRO_KERNELS"] = "pallas-interpret"
    try:
        y2, s2 = ssd_chunked(x, a_dt, b, c, Q)
    finally:
        os.environ["REPRO_KERNELS"] = "xla"
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4


# -------------------------------------------------------------- grouped matmul
GMM_CASES = [
    (4, 256, 512, 384, jnp.float32, 128, 128, 256),
    (2, 128, 128, 128, jnp.float32, 128, 128, 128),
    (8, 128, 256, 128, jnp.bfloat16, 128, 128, 256),
    (1, 512, 1024, 256, jnp.float32, 128, 128, 512),
]


@pytest.mark.parametrize("case", GMM_CASES)
def test_grouped_matmul_sweep(case):
    e, c, d, f, dtype, bc, bf, bd = case
    ks = jax.random.split(jax.random.PRNGKey(e + c + d), 2)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    w = jax.random.normal(ks[1], (e, d, f), dtype) * 0.05
    out = grouped_matmul(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=True)
    ref = grouped_matmul_ref(x, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < (1e-4 if dtype == jnp.float32 else 5e-2), f"{case}: {err}"


def test_flash_attention_equals_model_attention_core():
    """Model q-chunked scan path and Pallas kernel agree through the
    attention entry point (kernel_mode switch)."""
    import os

    from repro.models.attention import _attention_core

    B, S, H, KV, D = 1, 256, 4, 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    os.environ["REPRO_KERNELS"] = "xla"
    ref = _attention_core(q, k, v, pos, pos, "full", 0)
    os.environ["REPRO_KERNELS"] = "pallas-interpret"
    try:
        out = _attention_core(q, k, v, pos, pos, "full", 0)
    finally:
        os.environ["REPRO_KERNELS"] = "xla"
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-5
