"""Checkpointing (async, atomic, elastic) + deterministic data pipeline."""
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import SMOKES
from repro.core.executor import AMTExecutor
from repro.data import PrefetchingLoader, SyntheticLM


def small_state(rng=0):
    k = jax.random.PRNGKey(rng)
    return {
        "params": {
            "w": jax.random.normal(k, (8, 16), jnp.float32),
            "e": jax.random.normal(k, (32, 8)).astype(jnp.bfloat16),
        },
        "opt": {"mu": {"w": jnp.zeros((8, 16))}, "count": jnp.zeros((), jnp.int32)},
        "step": jnp.asarray(5, jnp.int32),
    }


def test_save_restore_roundtrip_sync(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    state = small_state()
    cm.save(state, step=5)
    abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, step = cm.restore(abstract)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_async_save_with_executor(tmp_path):
    ex = AMTExecutor(n_workers=2)
    try:
        cm = CheckpointManager(str(tmp_path), executor=ex)
        state = small_state()
        cm.save(state, step=1)
        cm.wait()
        assert cm.latest_step() == 1
    finally:
        ex.shutdown()


def test_atomic_commit_no_partial(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(small_state(), step=2)
    # a stale tmp dir must never be listed
    (tmp_path / "step_9.tmp").mkdir()
    assert cm.available_steps() == [2]


def test_keep_k_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(small_state(), step=s)
    assert cm.available_steps() == [3, 4]


def test_restore_validates_shapes(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(small_state(), step=1)
    bad = small_state()
    bad["params"]["w"] = jnp.zeros((9, 16))
    abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), bad)
    with pytest.raises(ValueError, match="shape"):
        cm.restore(abstract)


def test_elastic_restore_across_meshes(tmp_path):
    """Save under one sharding, restore under another (subprocess with 8
    host devices) — the elastic-rescale contract."""
    import subprocess
    import sys

    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

state = {{"w": jnp.arange(64.0).reshape(8, 8)}}
mesh_a = jax.make_mesh((4,), ("x",))
sh_a = NamedSharding(mesh_a, P("x", None))
state = {{"w": jax.device_put(state["w"], sh_a)}}
cm = CheckpointManager({str(tmp_path)!r})
cm.save(state, step=1)

mesh_b = jax.make_mesh((2, 4), ("a", "b"))
sh_b = {{"w": NamedSharding(mesh_b, P("a", "b"))}}
abstract = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
restored, step = cm.restore(abstract, shardings=sh_b)
assert step == 1
np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding == sh_b["w"]
print("ELASTIC_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=120)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ----------------------------------------------------------------- data
def test_data_determinism_and_resume():
    cfg = SMOKES["tinyllama-1.1b"]
    src = SyntheticLM(cfg, batch=2, seq=16, seed=42)
    b0a, b0b = src.make_batch(0), src.make_batch(0)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    assert not np.array_equal(src.make_batch(1)["tokens"], b0a["tokens"])
    assert (b0a["labels"][:, :-1] == b0a["tokens"][:, 1:]).all()


def test_prefetching_loader_in_order():
    cfg = SMOKES["tinyllama-1.1b"]
    ex = AMTExecutor(n_workers=2)
    try:
        src = SyntheticLM(cfg, batch=2, seq=16, seed=7)
        loader = PrefetchingLoader(src, ex, depth=3)
        got = [loader.next() for _ in range(6)]
        for i, b in enumerate(got):
            np.testing.assert_array_equal(b["tokens"], src.make_batch(i)["tokens"])
    finally:
        ex.shutdown()


def test_prefetching_loader_restart_index():
    cfg = SMOKES["tinyllama-1.1b"]
    ex = AMTExecutor(n_workers=2)
    try:
        src = SyntheticLM(cfg, batch=2, seq=16, seed=7)
        loader = PrefetchingLoader(src, ex, depth=2, start_index=10)
        b = loader.next()
        np.testing.assert_array_equal(b["tokens"], src.make_batch(10)["tokens"])
    finally:
        ex.shutdown()
