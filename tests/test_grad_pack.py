"""Device data plane (ISSUE 9): fused quantize+pack kernel parity, the
versioned binary wire format, copy discipline, and staged aggregation.

The tentpole contract is BIT parity, not tolerance: the fused kernel's
wire bytes (xla and pallas-interpret lowerings) must equal the host
reference ``pack_grads_q8`` byte for byte — same header, same offset
table, same scales, same tile-padded int8 payload — at every size in the
Fig-3 ladder, for f32 and bf16 leaves, ragged shapes, and across
multi-step error-feedback evolution.
"""
import pickle
import struct
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import wire
from repro.kernels.grad_pack import (
    pack_grads_fused,
    packed_nbytes,
    unpack_grads_fused,
)
from repro.train.grad_sync import (
    compress_grads_int8_ef,
    pack_grads,
    pack_grads_q8,
    unpack_grads,
)

# Fig 3 size ladder (same points as benchmarks/latency.py CROSSOVER_SIZES):
# per size S, a tree whose quantized payload is about S bytes.
FIG3_SIZES = (512, 4096, 8192, 16384, 32768, 65536)


def _tree_for_size(nelems: int, seed: int = 0):
    """A ragged multi-leaf tree totalling ``nelems`` elements."""
    rng = np.random.default_rng(seed)
    a = max(1, nelems // 2)
    b = max(1, nelems // 3)
    c = max(0, nelems - a - b)
    tree = {
        "w": jnp.asarray(rng.standard_normal(a), jnp.float32),
        "b": jnp.asarray(rng.standard_normal(b) * 1e-3, jnp.float32),
        "v": jnp.asarray(rng.standard_normal(c), jnp.float32),
    }
    ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
    return tree, ef


def _zeros_ef(tree):
    return jax.tree.map(lambda x: jnp.zeros(np.shape(x), jnp.float32), tree)


# --------------------------------------------------------------- wire format


def test_grad_header_roundtrip():
    arrs = [np.zeros((3, 4), np.float32), np.zeros((0,), np.int8),
            np.zeros((), np.float32), np.zeros((2, 1, 5), np.int32)]
    specs = [wire.leaf_spec(a) for a in arrs]
    hdr = wire.encode_grad_header(wire.KIND_RAW, specs)
    kind, got, off = wire.parse_grad_header(hdr)
    assert kind == wire.KIND_RAW and off == len(hdr)
    assert [(s.shape, s.dtype, s.nbytes) for s in got] == [
        (a.shape, a.dtype, a.nbytes) for a in arrs
    ]


def test_msg_codec_roundtrip_and_container_fidelity():
    msgs = [
        (3, [1, 2, 3], 16),
        ("new", 7, [5, 6], True, 8),
        [("eagain", 0, 3), (4, 17, False)],
        (),
        {"k": b"\x00\xff", "v": -1.5},
        None,
    ]
    for m in msgs:
        out = wire.decode_msg(wire.encode_msg(m))
        assert out == m
        assert type(out) is type(m)  # list stays list, tuple stays tuple
    with pytest.raises(TypeError):
        wire.encode_msg(object())


def test_pack_grads_matches_old_pickle_decoded_values():
    """Satellite 1: the binary format carries exactly what the old pickle
    stream carried — decoding both yields the same leaf values/dtypes."""
    rng = np.random.default_rng(3)
    tree = {
        "w": (jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
              jnp.asarray(rng.integers(-100, 100, (8,)), jnp.int8)),
        "b": jnp.asarray(rng.standard_normal((5,)).astype(np.float16)),
    }
    # the pre-ISSUE-9 wire: pickle of (leaf ndarray list)
    old = pickle.dumps([np.asarray(l) for l in jax.tree.leaves(tree)])
    new = pack_grads(tree)
    got = unpack_grads(new, tree)
    for g, o in zip(jax.tree.leaves(got), pickle.loads(old)):
        assert np.asarray(g).dtype == o.dtype
        np.testing.assert_array_equal(np.asarray(g), o)
    # int8 leaves stay int8 on the wire (the 4x reduction) and the binary
    # format beats pickle's overhead
    assert len(new) < len(old)


def test_pack_grads_rejects_garbage():
    with pytest.raises(ValueError):
        wire.parse_grad_header(b"\x00" * 16)


# ------------------------------------------------- fused kernel: bit parity


@pytest.mark.parametrize("size", FIG3_SIZES)
def test_fused_pack_bit_parity_fig3_ladder(size):
    """Wire bytes from the fused kernel == host reference, bit for bit, at
    every Fig-3 ladder size, in both CI lowerings."""
    tree, ef = _tree_for_size(size, seed=size)
    want, ef_host = pack_grads_q8(tree, ef)
    for mode in ("xla", "pallas-interpret"):
        got, ef_dev = pack_grads_fused(tree, ef, mode=mode)
        assert got == want, f"mode={mode} size={size}: wire bytes differ"
        for eh, ed in zip(jax.tree.leaves(ef_host), jax.tree.leaves(ef_dev)):
            np.testing.assert_array_equal(np.asarray(ed), np.asarray(eh))
    assert len(want) == packed_nbytes(tree)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fused_pack_bit_parity_dtypes_ragged(dtype):
    rng = np.random.default_rng(11)
    dt = jnp.dtype(dtype)
    tree = {
        "attn": (jnp.asarray(rng.standard_normal((33, 17)), dt),
                 jnp.asarray(rng.standard_normal((129,)), dt)),
        "mlp": [jnp.asarray(rng.standard_normal((7, 3, 5)), dt),
                jnp.asarray(rng.standard_normal((1,)), dt)],
    }
    ef = _zeros_ef(tree)
    want, _ = pack_grads_q8(tree, ef)
    for mode in ("xla", "pallas-interpret"):
        got, _ = pack_grads_fused(tree, ef, mode=mode)
        assert got == want, f"mode={mode} dtype={dtype}"


def test_fused_pack_multistep_ef_bit_parity():
    """10 steps of EF evolution: feeding each path its OWN ef state keeps
    the wire bytes identical every step (ef states must therefore agree
    bitwise too — drift anywhere would desynchronize the streams)."""
    rng = np.random.default_rng(23)
    tree0 = {"w": jnp.asarray(rng.standard_normal((640,)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((9,)) * 1e-4, jnp.float32)}
    ef_h, ef_x, ef_p = _zeros_ef(tree0), _zeros_ef(tree0), _zeros_ef(tree0)
    for step in range(10):
        g = jax.tree.map(
            lambda x: x * np.float32(1.0 + 0.1 * step) + np.float32(0.01 * step), tree0
        )
        want, ef_h = pack_grads_q8(g, ef_h)
        got_x, ef_x = pack_grads_fused(g, ef_x, mode="xla")
        got_p, ef_p = pack_grads_fused(g, ef_p, mode="pallas-interpret")
        assert got_x == want, f"xla step {step}"
        assert got_p == want, f"pallas-interpret step {step}"


def test_fused_ef_equivalent_to_compress_grads_int8_ef():
    """Same quantizer, same EF semantics, over 10 steps.  The in-jit path
    computes EF as fma-contracted ``g - q*scale`` while the fused path
    uses ``(r - q) * scale`` (see grad_pack.py's _RECIP127 note): the
    1-ulp EF difference can flip a round-half element by one quantization
    bucket, so the per-step comparison allows exactly that — and the EF
    identity plus the accumulated applied stream must both hold tightly
    (quantizer unbiasedness is about the running sum, not one step)."""
    rng = np.random.default_rng(29)
    tree = {"w": jnp.asarray(rng.standard_normal((257,)), jnp.float32)}
    ef_a, ef_b = _zeros_ef(tree), _zeros_ef(tree)
    acc_a = acc_b = np.zeros(257, np.float32)
    for _ in range(10):
        deq_a, ef_a = compress_grads_int8_ef(tree, ef_a)
        g32 = np.asarray(tree["w"]) + np.asarray(ef_b["w"])  # pre-update EF
        data, ef_b = pack_grads_fused(tree, ef_b, mode="xla")
        deq_b = unpack_grads_fused(data, tree)
        scale = float(np.max(np.abs(g32))) / 127
        diff = np.abs(np.asarray(deq_b["w"]) - np.asarray(deq_a["w"]))
        assert float(np.max(diff)) <= 1.5 * scale  # at most one bucket apart
        assert int(np.count_nonzero(diff > 1e-6)) <= 3  # and only knife-edges
        # the fused EF identity: deq + new_ef == g + old_ef (to float slop)
        np.testing.assert_allclose(
            np.asarray(deq_b["w"]) + np.asarray(ef_b["w"]), g32, atol=1e-5
        )
        acc_a = acc_a + np.asarray(deq_a["w"])
        acc_b = acc_b + np.asarray(deq_b["w"])
    # both streams applied the same total update (EF carries the residual)
    np.testing.assert_allclose(acc_b / 10, acc_a / 10, atol=2e-2)


def test_fused_pack_edge_trees():
    # empty tree
    data, ef = pack_grads_fused({}, {})
    kind, specs, _ = wire.parse_grad_header(data)
    assert kind == wire.KIND_Q8 and specs == []
    assert unpack_grads_fused(data, {}) == {}
    # single scalar leaf
    t = {"s": jnp.asarray(0.75, jnp.float32)}
    want, _ = pack_grads_q8(t, _zeros_ef(t))
    for mode in ("xla", "pallas-interpret"):
        got, ef2 = pack_grads_fused(t, _zeros_ef(t), mode=mode)
        assert got == want
        assert np.shape(np.asarray(ef2["s"])) == ()
    back = unpack_grads_fused(want, t)
    assert abs(float(back["s"]) - 0.75) < 0.01
    # empty leaf next to a real one
    t2 = {"e": jnp.zeros((0,), jnp.float32), "w": jnp.ones((3,), jnp.float32)}
    want2, _ = pack_grads_q8(t2, _zeros_ef(t2))
    got2, _ = pack_grads_fused(t2, _zeros_ef(t2), mode="xla")
    assert got2 == want2
    back2 = unpack_grads_fused(want2, t2)
    assert np.asarray(back2["e"]).shape == (0,)
    np.testing.assert_allclose(np.asarray(back2["w"]), np.ones(3), atol=0.01)


def test_unpack_grads_reads_fused_wire():
    """The host unpacker and the fused unpacker agree on KIND_Q8 bytes —
    one wire format, two consumers."""
    tree, ef = _tree_for_size(2048, seed=7)
    data, _ = pack_grads_fused(tree, ef, mode="xla")
    a = unpack_grads(data, tree)
    b = unpack_grads_fused(data, tree)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fused_wire_is_4x_smaller_than_raw_f32():
    tree, ef = _tree_for_size(65536, seed=1)
    raw = pack_grads(tree)
    q8, _ = pack_grads_fused(tree, ef, mode="xla")
    assert len(q8) * 3.5 < len(raw)


def test_make_packer_knob_dispatch_and_parity():
    """TrainConfig.grad_pack resolves through make_packer; both packers
    emit identical wire bytes, so the knob is pure performance."""
    from repro.train.grad_sync import make_packer
    from repro.train.step import TrainConfig

    tree, ef = _tree_for_size(1024, seed=5)
    host_data, _ = make_packer(TrainConfig(grad_pack="host").grad_pack)(tree, ef)
    dev_data, _ = make_packer(TrainConfig(grad_pack="device").grad_pack)(tree, ef)
    assert host_data == dev_data
    with pytest.raises(ValueError):
        make_packer("nope")
    with pytest.raises(AssertionError):
        TrainConfig(grad_pack="nope")


# ------------------------------------------------------------ DP end-to-end


def test_dp_exchange_fused_over_comm_channel():
    """Two DP ranks exchange fused-packed gradients through a CommChannel
    and average — identical to the direct in-memory average of the
    dequantized trees (the fused analogue of the ISSUE-5 handoff test)."""
    from repro.core.comm.collective import CommChannel

    rng = np.random.default_rng(31)
    grads = [
        {"w": (jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
               jnp.asarray(rng.standard_normal((8,)), jnp.float32))}
        for _ in range(2)
    ]
    wires, deq = [], []
    for g in grads:
        data, _ = pack_grads_fused(g, _zeros_ef(g), mode="xla")
        wires.append(data)
        deq.append(unpack_grads_fused(data, g))
    channel = CommChannel()
    channel.send_request(wires[0])
    channel.send_response(wires[1])
    for _ in range(4):
        channel.progress()

    def reap_recv(source):
        for _ in range(8):
            rec = channel.reap(source)
            if rec is not None and rec.op == "recv":
                return rec
        raise AssertionError(f"no arrived payload on {source}")

    from_peer0 = unpack_grads_fused(reap_recv("request").data, grads[1])
    from_peer1 = unpack_grads_fused(reap_recv("response").data, grads[0])
    avg_comm = jax.tree.map(lambda a, b: (a + b) / 2, deq[0], from_peer1)
    avg_direct = jax.tree.map(lambda a, b: (a + b) / 2, deq[0], deq[1])
    for got, want in zip(jax.tree.leaves(avg_comm), jax.tree.leaves(avg_direct)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    avg_peer = jax.tree.map(lambda a, b: (a + b) / 2, from_peer0, deq[1])
    for got, want in zip(jax.tree.leaves(avg_peer), jax.tree.leaves(avg_direct)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- staged aggregation


def test_jax_stage_batches_one_transfer_per_drain():
    """stage='jax': a whole progress drain rides ONE staged device buffer
    — FabricStats counts one batch for N messages, and every payload
    arrives intact."""
    from repro.core.comm.collective import CommChannel

    channel = CommChannel(stage="jax")
    payloads = [bytes([i]) * (50 + i) for i in range(5)]
    for p in payloads:
        channel.send_request(p)
    channel.progress()
    st = channel.group.stats
    assert st.staged_batches == 1
    assert st.staged_bytes == sum(len(p) for p in payloads)
    got = []
    for _ in range(16):
        rec = channel.reap("request")
        if rec is not None and rec.op == "recv":
            got.append(bytes(rec.data))
    assert got == payloads


def test_jax_stage_empty_drain_counts_nothing():
    from repro.core.comm.collective import CollectiveGroup

    g = CollectiveGroup(2, 1, stage="jax")
    assert g._stage_batch([]) == []
    assert g.stats.staged_batches == 0 and g.stats.staged_bytes == 0


# ----------------------------------------------------------- copy discipline


def test_pack_grads_copy_discipline():
    """Satellite 2: contiguous host leaves go to the wire as views — the
    only big allocation in pack_grads is the joined output buffer (< 1.5x
    payload; the old np.asarray-per-leaf path allocated > 2x)."""
    leaves = [np.random.default_rng(i).standard_normal(32768).astype(np.float32)
              for i in range(4)]
    tree = {f"l{i}": a for i, a in enumerate(leaves)}
    payload = sum(a.nbytes for a in leaves)
    pack_grads(tree)  # warm any lazy imports
    tracemalloc.start()
    data = pack_grads(tree)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert len(data) > payload
    assert peak < 1.5 * payload, f"pack_grads copied leaves: peak={peak}"


def test_split_aggregate_zero_copy():
    """comm/base.py split_aggregate slices the aggregation buffer as
    memoryviews — no bytes() copy of the chunk payloads."""
    from repro.core.comm.base import aggregate_parcels, split_aggregate
    from repro.core.parcel import Chunk, Parcel

    chunks = [bytes([i]) * 20000 for i in range(6)]
    parcel = aggregate_parcels(
        [Parcel(parcel_id=i, source=0, dest=1, nzc_chunk=Chunk(c))
         for i, c in enumerate(chunks)]
    )
    total = sum(len(c) for c in chunks)
    tracemalloc.start()
    out = split_aggregate(parcel)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert [bytes(c.nzc_chunk.data) for c in out] == chunks
    assert peak < 0.5 * total, f"split_aggregate copied payloads: peak={peak}"
