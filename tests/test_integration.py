"""End-to-end integration: trainer with restart, dry-run on a small mesh."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import SMOKES
from repro.optim import OptHParams
from repro.train import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_trainer_end_to_end_with_restart(tmp_path):
    arch = SMOKES["tinyllama-1.1b"]
    hp = OptHParams(lr_peak=5e-3, warmup_steps=2, total_steps=16)
    tcfg = TrainConfig(microbatches=1, remat="none")

    run1 = TrainerConfig(batch=4, seq=32, steps=8, ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100)
    t1 = Trainer(arch, hp, tcfg, run1)
    s1 = t1.train()
    assert s1["steps"] == 8

    # restart: resumes from step 8, runs 8 more
    run2 = TrainerConfig(batch=4, seq=32, steps=16, ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100)
    t2 = Trainer(arch, hp, tcfg, run2)
    s2 = t2.train()
    assert s2["steps"] == 8  # only the remaining steps
    # Restart semantics, robust to per-step loss noise at this tiny scale:
    # the resumed run starts from the trained checkpoint (well below the
    # from-scratch initial loss, i.e. not re-initialized) …
    init_loss = t1.metrics_log[0]["loss"]
    assert t2.metrics_log[0]["loss"] < init_loss
    # … and continued training stays sane (no divergence after restore).
    assert s2["final_loss"] < init_loss


def test_trainer_straggler_watchdog():
    arch = SMOKES["tinyllama-1.1b"]
    hp = OptHParams(total_steps=6)
    t = Trainer(arch, hp, TrainConfig(), TrainerConfig(batch=2, seq=16, steps=6, log_every=100))
    t.train()
    # first (compile) step is typically flagged relative to later medians —
    # the watchdog mechanism itself must function without error
    assert isinstance(t.straggler_steps, list)


@pytest.mark.slow
def test_dryrun_cell_small_mesh_subprocess(tmp_path):
    """The dry-run machinery end-to-end on a 16-device host mesh."""
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import dryrun_cell
res = dryrun_cell("tinyllama-1.1b", "decode_32k")
assert res["status"] == "ok", res
assert res["n_devices"] == 256
assert sum(res["collective_bytes"].values()) > 0
print("DRYRUN_OK", res["mesh"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=600)
    assert "DRYRUN_OK 16x16" in out.stdout, out.stderr[-2000:]


def test_multipod_mesh_shapes_subprocess():
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
print("MESH_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=300)
    assert "MESH_OK" in out.stdout, out.stderr[-2000:]


def test_sharded_train_step_on_test_mesh_subprocess():
    """Real (allocated) sharded train step on an 8-device host mesh —
    verifies the sharding rules run, not just compile."""
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import SMOKES
from repro.launch.mesh import make_rules
from repro.optim import OptHParams
from repro.sharding.logical import use_rules
from repro.sharding.params import batch_specs, param_specs, tree_shardings
from repro.train import TrainConfig, init_train_state, make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
cfg = SMOKES["tinyllama-1.1b"]
with use_rules(rules), mesh:
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    hp = OptHParams(lr_peak=5e-3, warmup_steps=1, total_steps=8)
    step = jax.jit(make_train_step(cfg, hp))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l0 = None
    for _ in range(6):
        state, met = step(state, batch)
        l0 = l0 or float(met["loss"])
    assert float(met["loss"]) < l0
print("SHARDED_TRAIN_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=600)
    assert "SHARDED_TRAIN_OK" in out.stdout, out.stderr[-2000:]


def test_sequence_parallel_attention_matches_default_subprocess():
    """SP attention (seq_act→model) must be numerically equivalent to the
    default q-chunked path — same math, different partitioning."""
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import SMOKES
from repro.launch.mesh import make_rules
from repro.models import forward_train, init_params
from repro.sharding.logical import use_rules

mesh = jax.make_mesh((2, 4), ("data", "model"))
for name in ("qwen2-7b", "minicpm3-4b"):
    cfg = SMOKES[name].variant(dtype="float32", n_heads=6, n_kv_heads=2 if name=="qwen2-7b" else 6)
    if name == "minicpm3-4b":
        cfg = cfg.variant(n_kv_heads=6)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    ref, _ = forward_train(params, cfg, {"tokens": toks})  # no mesh: default path
    rules = make_rules(mesh, overrides={"seq_act": "model", "heads": None, "kv_heads": None})
    with use_rules(rules), mesh:
        sp, _ = jax.jit(lambda p, t: forward_train(p, cfg, {"tokens": t}))(params, toks)
    err = float(jnp.max(jnp.abs(ref - sp)))
    assert err < 2e-4, (name, err)
print("SP_EQUIV_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=600)
    assert "SP_EQUIV_OK" in out.stdout, out.stderr[-2000:]


def test_gpipe_pipeline_parallelism_subprocess():
    """GPipe over a 4-stage mesh axis ≡ sequential stage application."""
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import gpipe

n_stages, M, B, D = 4, 6, 2, 16
mesh = jax.make_mesh((4,), ("pod",))
rng = jax.random.PRNGKey(0)
params = jax.random.normal(rng, (n_stages, D, D)) * 0.3
micro = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

stage_fn = lambda w, x: jnp.tanh(x @ w)
out = gpipe(stage_fn, params, micro, mesh, axis="pod")

ref = micro
for s in range(n_stages):
    ref = jnp.tanh(ref @ params[s])
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
print("GPIPE_OK", err)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=300)
    assert "GPIPE_OK" in out.stdout, out.stderr[-2000:]
