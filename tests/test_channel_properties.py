"""Property-based channel/fleet suite (ISSUE 7).

Hypothesis drives randomized interleavings of submits, EAGAIN refusals,
progress steps and out-of-order completions against every
:class:`~repro.core.comm.collective.CommChannel` backend, checking the
two invariants the serving tier stands on:

* **FIFO non-overtaking** — at every reap point, the payloads received in
  each direction are a strict prefix of the payloads submitted in that
  direction (the InjectionThrottle's contract under EAGAIN parks);
* **deliver-exactly-once** — after quiescing, every submitted payload was
  delivered exactly once, in order: no drop, no duplicate, no reorder.

Failures shrink (hypothesis minimizes the op schedule) and the assertion
message prints the shrunk schedule, so a reproducing interleaving can be
pasted straight into a regression test.

The fleet property at the bottom randomizes whole request traces and
worker counts: the router/worker tier must emit exactly the single-host
reference's per-request token streams for ANY trace — admission order,
slot sharding and backpressure must never perturb the math.
"""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.comm.collective import CommChannel
from repro.core.comm.resources import ResourceLimits
from repro.core.comm.shmem import ShmemGroup

# op codes for the schedule strategy: plain ints keep shrinking effective
OP_SUBMIT_REQ = 0
OP_SUBMIT_RESP = 1
OP_PROGRESS_CLIENT = 2
OP_PROGRESS_SERVER = 3
OP_DRAIN = 4
OP_REAP_REQ = 5
OP_REAP_RESP = 6
_OP_NAMES = ["submit_req", "submit_resp", "progress_c", "progress_s",
             "drain", "reap_req", "reap_resp"]

TIGHT = dict(send_queue_depth=1, bounce_buffers=1, bounce_buffer_size=4_096)


def _make_channel(backend: str, limits: ResourceLimits) -> CommChannel:
    if backend == "shmem_signal":
        # the put-signal completion rung: raised flags discovered by scan
        group = ShmemGroup(2, 1, limits=limits, completion_mode="signal")
        return CommChannel(limits=limits, backend="shmem", group=group)
    return CommChannel(limits=limits, backend=backend)


BACKENDS = ["collective", "shmem", "shmem_signal"]
LIMITS = {"unbounded": lambda: ResourceLimits(),
          "tight": lambda: ResourceLimits(**TIGHT)}


class _Driver:
    """Applies an op schedule to a channel, recording delivery order."""

    def __init__(self, channel: CommChannel, schedule):
        self.ch = channel
        self.schedule = schedule
        self.sent_req = []  # payloads submitted client -> server, in order
        self.sent_resp = []  # payloads submitted server -> client, in order
        self.got_req = []  # payloads reaped on the server side, in order
        self.got_resp = []  # payloads reaped on the client side, in order

    def _fail(self, why: str):  # the shrunk schedule, printable
        named = [_OP_NAMES[op] for op in self.schedule]
        pytest.fail(f"{why}\nop schedule: {named}")

    def _check_prefix(self):
        # FIFO non-overtaking, checked at EVERY reap point
        if self.got_req != self.sent_req[: len(self.got_req)]:
            self._fail(f"requests overtook: got {self.got_req} of {self.sent_req}")
        if self.got_resp != self.sent_resp[: len(self.got_resp)]:
            self._fail(f"responses overtook: got {self.got_resp} of {self.sent_resp}")

    def _reap_one(self, source: str) -> bool:
        rec = self.ch.reap(source)
        if rec is None:
            return False
        if rec.op != "send":  # arrivals only; send completions carry no payload
            self.ch.repost(rec.ctx)
            (self.got_req if source == "request" else self.got_resp).append(rec.data)
            self._check_prefix()
        return True

    def run(self):
        n = 0
        for op in self.schedule:
            if op == OP_SUBMIT_REQ:
                payload = b"q%d" % n
                self.sent_req.append(payload)
                self.ch.send_request(payload)  # EAGAIN parks inside
            elif op == OP_SUBMIT_RESP:
                payload = b"r%d" % n
                self.sent_resp.append(payload)
                self.ch.send_response(payload)
            elif op == OP_PROGRESS_CLIENT:
                self.ch.client.progress()
            elif op == OP_PROGRESS_SERVER:
                self.ch.server.progress()
            elif op == OP_DRAIN:
                self.ch.drain_retries()
            elif op == OP_REAP_REQ:
                self._reap_one("request")
            elif op == OP_REAP_RESP:
                self._reap_one("response")
            n += 1
        # quiesce: whatever the schedule left parked/in flight must drain
        for _ in range(500):
            moved = self.ch.drain_retries()
            moved = self.ch.progress() or moved
            while self._reap_one("request"):
                moved = True
            while self._reap_one("response"):
                moved = True
            if not moved and not self.ch.pending_work():
                break
        else:
            self._fail(
                f"channel failed to quiesce (pending_work="
                f"{self.ch.pending_work()}, parks={self.ch.backpressure_parks()})"
            )
        # deliver-exactly-once: everything submitted arrived, in order
        if self.got_req != self.sent_req:
            self._fail(f"request delivery mismatch: {self.got_req} != {self.sent_req}")
        if self.got_resp != self.sent_resp:
            self._fail(f"response delivery mismatch: {self.got_resp} != {self.sent_resp}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bound", sorted(LIMITS))
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=6), min_size=0, max_size=60))
def test_channel_fifo_and_exactly_once(backend, bound, schedule):
    """Randomized interleavings of submits, progress, EAGAIN parks and
    reaps preserve per-direction FIFO and deliver-exactly-once on every
    backend, bounded or not."""
    _Driver(_make_channel(backend, LIMITS[bound]()), schedule).run()


@pytest.mark.parametrize("backend", BACKENDS)
def test_channel_regression_burst_then_drain(backend):
    """A deterministic pin of the worst shrunk shape: submit a burst in
    both directions with NO interleaved progress (everything parks or
    queues), then rely on the quiescence loop alone to deliver."""
    schedule = [OP_SUBMIT_REQ] * 8 + [OP_SUBMIT_RESP] * 8
    _Driver(_make_channel(backend, ResourceLimits(**TIGHT)), schedule).run()


# --------------------------------------------------------------------- fleet
@pytest.fixture(scope="module")
def smoke_model():
    import jax

    from repro.configs import SMOKES
    from repro.models import init_params

    arch = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    return arch, init_params(jax.random.PRNGKey(0), arch)


@settings(max_examples=6, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.lists(st.integers(min_value=1, max_value=50), min_size=1, max_size=6),
            st.integers(min_value=1, max_value=4),
        ),
        min_size=1,
        max_size=4,
    ),
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=0, max_value=2),
)
def test_fleet_matches_single_host_on_random_traces(
    smoke_model, trace, workers, chunk
):
    """For ANY request trace, worker count and chunking choice, the fleet
    emits exactly the per-request token streams of a single-host server
    with the same chunking — sharding and routing move bytes, not math."""
    from repro.serve import Fleet, FleetConfig, InferenceServer, ServeConfig

    arch, params = smoke_model
    slots = max(2, workers)
    single = InferenceServer(
        arch, params,
        ServeConfig(slots=slots, context=64, transport="inline", prefill_chunk=chunk),
    )
    fleet = Fleet(
        arch, params,
        FleetConfig(workers=workers, slots=slots, context=64, transport="inline",
                    prefill_chunk=chunk),
    )
    try:
        ref = [single.submit(p, max_new=m) for p, m in trace]
        single.run_until_idle()
        out = [fleet.submit(p, max_new=m) for p, m in trace]
        fleet.run_until_idle()
        assert all(r.done_event.is_set() for r in ref)
        assert all(r.done_event.is_set() for r in out), (
            f"fleet dropped requests on trace={trace} workers={workers} chunk={chunk}"
        )
        assert [r.out_tokens for r in out] == [r.out_tokens for r in ref], (
            f"token streams diverged on trace={trace} workers={workers} chunk={chunk}"
        )
    finally:
        fleet.close()
