#!/usr/bin/env python3
"""API-drift gate (the CI docs job, also run as a tier-1 test).

The redesign's core guarantee is ONE shared resource model:
``repro.core.comm.resources.ResourceLimits`` is the single source of
resource knobs, consumed by the functional fabric, the parcelports, and
the DES ``SimConfig``.  Before it, ``SimConfig`` hand-mirrored the fabric
knobs field by field — a drift machine.  This gate fails if the mirror
ever re-grows:

1. **No mirrored fields** — no dataclass *field* of ``SimConfig`` or
   ``LCIPPConfig`` may share a name with a ``ResourceLimits`` field
   (read-only delegating properties are fine; duplicated storage is not).
2. **Shared object, not copies** — both configs carry a ``limits`` field
   typed ``ResourceLimits``, ``Fabric`` exposes the one it was built
   with, and ``sim_config_for_variant`` hands the DES the *same* limits
   the functional variant resolves to (checked on ``lci_b8``, a
   parameterized family member resolved on demand).
3. **Delegates stay wired** — the legacy ``SimConfig.send_queue_depth``
   etc. read through to ``limits``.

Exit code is nonzero on any failure; failures are listed one per line.
"""
from __future__ import annotations

import dataclasses
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def check_api(failures: list) -> None:
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.amtsim.parcelport_sim import SimConfig, sim_config_for_variant
        from repro.core.comm.resources import ResourceLimits
        from repro.core.fabric import Fabric
        from repro.core.lci_parcelport import LCIPPConfig
        from repro.core.variants import VARIANTS
    except Exception as exc:  # pragma: no cover - environment-dependent
        failures.append(f"import failed: {exc}")
        return

    limit_fields = {f.name for f in dataclasses.fields(ResourceLimits)}

    # 1. no config may re-grow a field duplicating the shared model
    for cfg_cls in (SimConfig, LCIPPConfig):
        dup = limit_fields & {f.name for f in dataclasses.fields(cfg_cls)}
        if dup:
            failures.append(
                f"{cfg_cls.__name__} duplicates ResourceLimits fields {sorted(dup)} "
                "(use the shared `limits` object, not mirrored fields)"
            )

    # 2. every layer consumes the one shared object
    for cfg_cls in (SimConfig, LCIPPConfig):
        names = {f.name: f for f in dataclasses.fields(cfg_cls)}
        if "limits" not in names:
            failures.append(f"{cfg_cls.__name__} has no `limits: ResourceLimits` field")
        elif not isinstance(cfg_cls().limits, ResourceLimits):
            failures.append(f"{cfg_cls.__name__}().limits is not a ResourceLimits")
    lim = ResourceLimits(send_queue_depth=3, bounce_buffers=2, bounce_buffer_size=4096)
    fab = Fabric(2, limits=lim)
    if getattr(fab, "limits", None) is not lim:
        failures.append("Fabric does not expose the ResourceLimits it was built with")
    if fab.device(0).send_queue_depth != 3:
        failures.append("Fabric devices ignore limits.send_queue_depth")
    try:
        functional = VARIANTS["lci_b8"].limits
        des = sim_config_for_variant("lci_b8").limits
        if functional != des:
            failures.append(
                f"lci_b8: functional limits {functional} != DES limits {des} "
                "(the two layers drifted)"
            )
    except KeyError:
        failures.append("parameterized family member lci_b8 failed to resolve")

    # 3. legacy knob names still read through to the shared model
    probe = SimConfig(limits=ResourceLimits(send_queue_depth=7, bounce_buffers=5,
                                            bounce_buffer_size=1234, retry_budget=9,
                                            recv_slots=6))
    for knob, want in (("send_queue_depth", 7), ("bounce_buffers", 5),
                       ("bounce_buffer_size", 1234), ("retry_budget", 9),
                       ("recv_slots", 6)):
        if getattr(probe, knob, None) != want:
            failures.append(f"SimConfig.{knob} does not delegate to limits.{knob}")
    if LCIPPConfig(limits=ResourceLimits(retry_budget=3)).retry_budget != 3:
        failures.append("LCIPPConfig.retry_budget does not delegate to limits.retry_budget")


def main() -> int:
    failures: list = []
    check_api(failures)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"check_api: {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
