#!/usr/bin/env python3
"""API-drift gate — thin CLI shim over ``repro.analysis`` (ISSUE 10).

The eight gates this script historically implemented inline now live as
registered passes in ``src/repro/analysis/gates.py``, sharing the one
cached AST walk, import-alias map, and call graph with the concurrency
passes (lock order, blocking-under-lock, PostStatus, capability
dominance, thread ownership — run those via ``tools/analyze.py``).  The
AST ports also fix the old line-greps' blind spots: aliased imports
(``from ..completion import LCRQueue as Q``) and calls wrapped across
lines now resolve.

This shim preserves the historical contract exactly — the same six
module-level functions appending human-readable strings to a
``failures`` list, the same ``FAIL: ...`` lines and ``check_api: N
failure(s)`` summary, the same nonzero exit on failure — so CI and the
tier-1 gate tests keep loading it unchanged:

1–3. **One shared resource model** — no mirrored config fields, every
     layer consumes the one ``ResourceLimits`` object, legacy knobs
     delegate through (``check_api``).
4.   **One progress engine** — no private reap loops (``check_progress_engine``).
5.   **Serving rides the comm layer** (``check_serving_comm``).
6.   **Put-path selection is capability-driven only** (``check_put_capability``).
7.   **One thread nursery** (``check_membership_thread_ownership``).
8.   **No pickle on the wire** (``check_no_pickle_wire``).

Exit code is nonzero on any failure; failures are listed one per line.
"""
from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

_CTX = None
_CTX_ERR = None


def _context():
    """One shared AnalysisContext for all gates (one AST walk per module)."""
    global _CTX, _CTX_ERR
    if _CTX is None and _CTX_ERR is None:
        try:
            from repro.analysis.registry import AnalysisContext

            _CTX = AnalysisContext.for_repo(REPO)
        except Exception as exc:  # pragma: no cover - environment-dependent
            _CTX_ERR = f"import failed: {exc}"
    return _CTX, _CTX_ERR


def _run(failures: list, *pass_ids: str) -> None:
    ctx, err = _context()
    if err is not None:
        failures.append(err)
        return
    from repro.analysis.registry import run_passes

    for f in run_passes(ctx, list(pass_ids)):
        failures.append(f.message)


def check_api(failures: list) -> None:
    """Gates 1–3: the ONE shared resource model (runtime dataclass probes)."""
    _run(failures, "gate-resource-mirror", "gate-resource-shared", "gate-resource-delegates")


def check_progress_engine(failures: list) -> None:
    """Gate 4: completions are reaped and dispatched ONLY by the shared
    ProgressEngine and its op adapters (no re-grown private loops)."""
    _run(failures, "gate-progress-engine")


def check_serving_comm(failures: list) -> None:
    """Gate 5: the serving stack's request/response hand-off goes through
    the shared CommInterface; no private hand-off loops in ``serve/``,
    ``launch/serve.py``, or the executor."""
    _run(failures, "gate-serving-comm")


def check_put_capability(failures: list) -> None:
    """Gate 6: one-sided-put path selection rides the advertised
    ``Capabilities`` alone — never the backend's concrete type."""
    _run(failures, "gate-put-capability")


def check_membership_thread_ownership(failures: list) -> None:
    """Gate 7: worker threads are spawned/joined only via the membership
    nursery — rebuilt on the call graph (alias-aware Thread resolution)."""
    _run(failures, "gate-thread-nursery")


def check_no_pickle_wire(failures: list) -> None:
    """Gate 8: wire-path modules carry the versioned binary format from
    ``core/comm/wire.py`` — no pickle imports or calls (AST-based)."""
    _run(failures, "gate-no-pickle-wire")


def main() -> int:
    failures: list = []
    check_api(failures)
    check_progress_engine(failures)
    check_serving_comm(failures)
    check_put_capability(failures)
    check_membership_thread_ownership(failures)
    check_no_pickle_wire(failures)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"check_api: {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
