#!/usr/bin/env python3
"""API-drift gate (the CI docs job, also run as a tier-1 test).

The redesign's core guarantee is ONE shared resource model:
``repro.core.comm.resources.ResourceLimits`` is the single source of
resource knobs, consumed by the functional fabric, the parcelports, and
the DES ``SimConfig``.  Before it, ``SimConfig`` hand-mirrored the fabric
knobs field by field — a drift machine.  This gate fails if the mirror
ever re-grows:

1. **No mirrored fields** — no dataclass *field* of ``SimConfig`` or
   ``LCIPPConfig`` may share a name with a ``ResourceLimits`` field
   (read-only delegating properties are fine; duplicated storage is not).
2. **Shared object, not copies** — both configs carry a ``limits`` field
   typed ``ResourceLimits``, ``Fabric`` exposes the one it was built
   with, and ``sim_config_for_variant`` hands the DES the *same* limits
   the functional variant resolves to (checked on ``lci_b8``, a
   parameterized family member resolved on demand).
3. **Delegates stay wired** — the legacy ``SimConfig.send_queue_depth``
   etc. read through to ``limits``.

Since PR 4 the gate also protects the second shared component: **ONE
progress engine** (``repro.core.comm.progress.ProgressEngine``).  Before
it, the completion-reap loop existed three times (LCI parcelport, MPI
parcelport, ~270 duplicated DES lines) — exactly the drift this gate now
fails on if it re-grows:

4. **No private reap loops** — ``poll_cq`` (the raw hardware reap verb)
   may appear only in the fabric (its definition) and the LCI device (the
   ``CommInterface`` progress verb); both functional parcelports'
   ``background_work`` must be thin ``run_step`` calls into the engine;
   the DES must not re-grow backend-specific background-work generators
   (``_lci_background_work`` / ``_mpi_background_work`` /
   ``_progress_device``), and ``_handle_completion`` may be called only
   from the engine's op driver.

Since ISSUE 5 the gate also protects the serving stack's hand-off:

5. **Serving rides the comm layer** — ``serve/server.py`` must hand
   requests/responses through the shared abstraction (``CommChannel`` +
   the one ``ProgressEngine`` via ``ProgressPolicy.for_config`` and
   ``run_step``), and neither ``serve/``, ``launch/serve.py``, nor
   ``core/executor.py`` may re-grow private send/recv hand-off machinery
   (raw completion-queue construction, the MPI ``isend``/``irecv``
   veneer, or hand-rolled ``_send_loop``/``_recv_loop`` pumps).

Since ISSUE 6 the gate also protects the capability ladder's selection
surface:

6. **Put-path selection is capability-driven only** — outside the comm
   backends themselves (``core/comm/``, ``core/device.py``,
   ``core/mpi_sim.py``), no code line may branch on a backend's concrete
   type (``isinstance`` against ``LCIDevice`` / ``ShmemComm`` /
   ``CollectiveComm`` / ``MPISim``), and any file that posts a one-sided
   put (``.post_put_signal(``) must consult ``one_sided_put`` from the
   advertised ``Capabilities`` — the paper's point (§2.3) is that the
   protocol engine selects paths from what the transport *advertises*,
   never from what it *is*.

Since ISSUE 8 the gate also protects worker-lifecycle ownership:

7. **One thread nursery** — worker threads (progress workers, fleet
   workers, the executor's task workers) are spawned and joined ONLY
   through ``core/comm/membership.py`` (``spawn_worker`` /
   ``join_workers`` / ``ProgressWorkerPool``); no module in ``serve/``,
   ``amtsim/``, the executor, or the parcelports may call
   ``threading.Thread(`` directly — otherwise the membership census
   (``live_worker_count``, the abandoned-member sweep) silently
   undercounts.  Benchmark *client* load generators (``launch/serve.py``)
   are not workers and are exempt.

Since ISSUE 9 the gate also protects the wire format:

8. **No pickle on the wire** — everything that crosses the comm layer is
   the versioned binary format from ``core/comm/wire.py`` (grad header +
   typed message codec); ``train/grad_sync.py``, ``core/comm/``, and
   ``serve/`` may not import or call ``pickle`` (AST-checked, so
   docstrings that merely *mention* pickle don't trip it).  Pickle's
   self-describing stream is both slower and version-fragile, and a
   pickling hop would silently break the fused kernel's bit-parity
   contract with the host pack path.

Exit code is nonzero on any failure; failures are listed one per line.
"""
from __future__ import annotations

import ast
import dataclasses
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def check_api(failures: list) -> None:
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.amtsim.parcelport_sim import SimConfig, sim_config_for_variant
        from repro.core.comm.resources import ResourceLimits
        from repro.core.fabric import Fabric
        from repro.core.lci_parcelport import LCIPPConfig
        from repro.core.variants import VARIANTS
    except Exception as exc:  # pragma: no cover - environment-dependent
        failures.append(f"import failed: {exc}")
        return

    limit_fields = {f.name for f in dataclasses.fields(ResourceLimits)}

    # 1. no config may re-grow a field duplicating the shared model
    for cfg_cls in (SimConfig, LCIPPConfig):
        dup = limit_fields & {f.name for f in dataclasses.fields(cfg_cls)}
        if dup:
            failures.append(
                f"{cfg_cls.__name__} duplicates ResourceLimits fields {sorted(dup)} "
                "(use the shared `limits` object, not mirrored fields)"
            )

    # 2. every layer consumes the one shared object
    for cfg_cls in (SimConfig, LCIPPConfig):
        names = {f.name: f for f in dataclasses.fields(cfg_cls)}
        if "limits" not in names:
            failures.append(f"{cfg_cls.__name__} has no `limits: ResourceLimits` field")
        elif not isinstance(cfg_cls().limits, ResourceLimits):
            failures.append(f"{cfg_cls.__name__}().limits is not a ResourceLimits")
    lim = ResourceLimits(send_queue_depth=3, bounce_buffers=2, bounce_buffer_size=4096)
    fab = Fabric(2, limits=lim)
    if getattr(fab, "limits", None) is not lim:
        failures.append("Fabric does not expose the ResourceLimits it was built with")
    if fab.device(0).send_queue_depth != 3:
        failures.append("Fabric devices ignore limits.send_queue_depth")
    try:
        functional = VARIANTS["lci_b8"].limits
        des = sim_config_for_variant("lci_b8").limits
        if functional != des:
            failures.append(
                f"lci_b8: functional limits {functional} != DES limits {des} "
                "(the two layers drifted)"
            )
    except KeyError:
        failures.append("parameterized family member lci_b8 failed to resolve")

    # 3. legacy knob names still read through to the shared model
    probe = SimConfig(limits=ResourceLimits(send_queue_depth=7, bounce_buffers=5,
                                            bounce_buffer_size=1234, retry_budget=9,
                                            recv_slots=6))
    for knob, want in (("send_queue_depth", 7), ("bounce_buffers", 5),
                       ("bounce_buffer_size", 1234), ("retry_budget", 9),
                       ("recv_slots", 6)):
        if getattr(probe, knob, None) != want:
            failures.append(f"SimConfig.{knob} does not delegate to limits.{knob}")
    if LCIPPConfig(limits=ResourceLimits(retry_budget=3)).retry_budget != 3:
        failures.append("LCIPPConfig.retry_budget does not delegate to limits.retry_budget")


def check_progress_engine(failures: list) -> None:
    """Gate 4: completions are reaped and dispatched ONLY by the shared
    ProgressEngine and its op adapters (no re-grown private loops)."""
    src = REPO / "src" / "repro"
    core = src / "core"
    # 4a. poll_cq stays behind the CommInterface progress verb (match the
    # call syntax on code lines, not mentions in comments/docstrings)
    allowed_poll_cq = {core / "fabric.py", core / "device.py"}
    for path in sorted(src.rglob("*.py")):
        if path in allowed_poll_cq:
            continue
        if any(
            ".poll_cq(" in line
            for line in path.read_text().splitlines()
            if not line.lstrip().startswith("#")
        ):
            failures.append(
                f"{path.relative_to(REPO)}: calls poll_cq — the hardware reap "
                "verb belongs to the engine's backend adapters only"
            )
    # 4b. both functional parcelports drive the ONE engine
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.core.lci_parcelport import LCIParcelport
        from repro.core.mpi_parcelport import MPIParcelport
    except Exception as exc:  # pragma: no cover - environment-dependent
        failures.append(f"import failed: {exc}")
        return
    for cls in (LCIParcelport, MPIParcelport):
        if "run_step" not in cls.background_work.__code__.co_names:
            failures.append(
                f"{cls.__name__}.background_work does not call the shared engine "
                "(run_step) — private progress loop re-grown?"
            )
    for fname in ("lci_parcelport.py", "mpi_parcelport.py"):
        text = (core / fname).read_text()
        if "ProgressEngine" not in text:
            failures.append(f"src/repro/core/{fname}: does not import the shared ProgressEngine")
        if ".drain(" in text:
            failures.append(
                f"src/repro/core/{fname}: drains a completion queue directly — "
                "reaping belongs to the engine's reap op"
            )
    # 4c. the DES has no backend-specific background-work generators
    sim_path = src / "amtsim" / "parcelport_sim.py"
    sim = sim_path.read_text()
    if "ProgressEngine" not in sim:
        failures.append("parcelport_sim.py does not import the shared ProgressEngine")
    for forbidden in ("_lci_background_work", "_mpi_background_work", "_progress_device"):
        if forbidden in sim:
            failures.append(
                f"parcelport_sim.py re-grew {forbidden} — the DES must drive the "
                "shared engine, not duplicate its loop"
            )
    # def _handle_completion + exactly one call site (the engine driver);
    # comment lines don't count — the gate polices code, not documentation
    n_handle = sum(
        line.count("_handle_completion(")
        for line in sim.splitlines()
        if not line.lstrip().startswith("#")
    )
    if n_handle > 2:
        failures.append(
            f"parcelport_sim.py calls _handle_completion from {n_handle - 1} sites — "
            "dispatch-by-kind belongs to the engine driver alone"
        )


def check_serving_comm(failures: list) -> None:
    """Gate 5: the serving stack's request/response hand-off goes through
    the shared CommInterface, and private hand-off loops may not re-grow
    in ``serve/``, ``launch/serve.py``, or the executor."""
    src = REPO / "src" / "repro"
    server_path = src / "serve" / "server.py"
    exec_path = src / "core" / "executor.py"
    server = server_path.read_text()
    # 5a. the hand-off is built on the shared abstraction
    for needle, why in (
        ("CommChannel", "requests/responses must ride the comm layer's channel"),
        ("ProgressEngine", "the engine loop must be the ONE shared ProgressEngine"),
        ("ProgressPolicy.for_config", "the policy must come from the shared builder"),
        ("run_step", "the serve loop must drive the engine's canonical step"),
    ):
        if needle not in server:
            failures.append(f"src/repro/serve/server.py: {needle} missing — {why}")
    if "run_step" not in exec_path.read_text():
        failures.append(
            "src/repro/core/executor.py: the idle pump does not drive the shared "
            "engine (run_step) — opaque private pump re-grown?"
        )
    # 5b. no private hand-off machinery beside it (code lines only)
    paths = sorted((src / "serve").glob("*.py")) + [exec_path, src / "launch" / "serve.py"]
    for path in paths:
        code = "\n".join(
            line for line in path.read_text().splitlines()
            if not line.lstrip().startswith("#")
        )
        for forbidden, why in (
            ("LCRQueue(", "completion queues belong behind the comm layer"),
            ("MichaelScottQueue(", "completion queues belong behind the comm layer"),
            ("LockQueue(", "completion queues belong behind the comm layer"),
            (".isend(", "the MPI veneer bypasses the unified interface"),
            (".irecv(", "the MPI veneer bypasses the unified interface"),
            ("_send_loop", "private send loop re-grown"),
            ("_recv_loop", "private recv loop re-grown"),
        ):
            if forbidden in code:
                failures.append(f"{path.relative_to(REPO)}: contains {forbidden} — {why}")


def check_put_capability(failures: list) -> None:
    """Gate 6: one-sided-put path selection rides the advertised
    ``Capabilities`` alone — never the backend's concrete type."""
    src = REPO / "src" / "repro"
    comm_dir = src / "core" / "comm"
    # backends may inspect their own concrete types; everyone else selects
    # by Capabilities
    allowed = {src / "core" / "device.py", src / "core" / "mpi_sim.py"}
    backend_names = ("LCIDevice", "ShmemComm", "ShmemDevice", "CollectiveComm", "MPISim")
    for path in sorted(src.rglob("*.py")):
        if comm_dir in path.parents or path in allowed:
            continue
        code_lines = [
            line for line in path.read_text().splitlines()
            if not line.lstrip().startswith("#")
        ]
        for line in code_lines:
            if "isinstance(" in line and any(n in line for n in backend_names):
                failures.append(
                    f"{path.relative_to(REPO)}: isinstance() against a concrete "
                    f"comm backend ({line.strip()!r}) — select the put path from "
                    "capabilities.one_sided_put, not the backend type"
                )
        code = "\n".join(code_lines)
        if ".post_put_signal(" in code and "one_sided_put" not in code:
            failures.append(
                f"{path.relative_to(REPO)}: posts one-sided puts without "
                "consulting capabilities.one_sided_put — the put path must be "
                "selected by the advertised Capabilities"
            )


def check_membership_thread_ownership(failures: list) -> None:
    """Gate 7: worker threads are spawned/joined only via the membership
    nursery (``core/comm/membership.py``) so the lifecycle census stays
    exact — no stray ``threading.Thread(`` beside it."""
    src = REPO / "src" / "repro"
    nursery = src / "core" / "comm" / "membership.py"
    # the nursery itself owns the primitive; client load generators in
    # launch/serve.py simulate external users, not tracked workers
    exempt = {nursery, src / "launch" / "serve.py"}
    for path in sorted(src.rglob("*.py")):
        if path in exempt:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if line.lstrip().startswith("#"):
                continue
            if "threading.Thread(" in line or "Thread(target=" in line:
                failures.append(
                    f"{path.relative_to(REPO)}:{lineno}: spawns a raw thread — "
                    "worker lifecycle belongs to membership.spawn_worker / "
                    "ProgressWorkerPool (the census must see every worker)"
                )
    # the two biggest thread consumers must actually ride the nursery
    for rel, needle in (
        ("core/executor.py", "spawn_worker"),
        ("core/executor.py", "join_workers"),
        ("core/lci_parcelport.py", "ProgressWorkerPool"),
    ):
        if needle not in (src / rel).read_text():
            failures.append(
                f"src/repro/{rel}: does not use membership.{needle} — "
                "worker threads must go through the one nursery"
            )


def check_no_pickle_wire(failures: list) -> None:
    """Gate 8: wire-path modules carry the versioned binary format from
    ``core/comm/wire.py`` — no pickle imports or calls (AST-based: a
    docstring mentioning pickle is documentation, not a violation)."""
    src = REPO / "src" / "repro"
    wire_paths = (
        [src / "train" / "grad_sync.py"]
        + sorted((src / "core" / "comm").rglob("*.py"))
        + sorted((src / "serve").rglob("*.py"))
    )
    for path in wire_paths:
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError as exc:  # pragma: no cover - tier-1 would fail first
            failures.append(f"{path.relative_to(REPO)}: unparseable ({exc})")
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Import) and any(a.name.split(".")[0] == "pickle" for a in node.names):
                offender = "import pickle"
            elif isinstance(node, ast.ImportFrom) and (node.module or "").split(".")[0] == "pickle":
                offender = "from pickle import"
            elif isinstance(node, ast.Name) and node.id == "pickle":
                offender = "pickle reference"
            else:
                continue
            failures.append(
                f"{path.relative_to(REPO)}:{node.lineno}: {offender} — wire-path "
                "modules must use the versioned binary format in core/comm/wire.py "
                "(encode_msg/decode_msg, grad headers), never pickle"
            )


def main() -> int:
    failures: list = []
    check_api(failures)
    check_progress_engine(failures)
    check_serving_comm(failures)
    check_put_capability(failures)
    check_membership_thread_ownership(failures)
    check_no_pickle_wire(failures)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"check_api: {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
