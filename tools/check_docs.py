#!/usr/bin/env python3
"""Docs smoke checker (the CI docs job, also run as a tier-1 test).

Three checks over README.md, ROADMAP.md, CHANGES.md and docs/*.md:

1. **Intra-repo links** — every relative markdown link target
   (``[text](path)`` where path is not http(s)/mailto/#anchor) must exist
   on disk, resolved against the file that contains it.
2. **Quoted commands parse** — every ```bash``` / ```sh``` fenced block in
   README.md must pass ``bash -n`` (shellcheck-style smoke: catches a
   pasted command that was edited into a syntax error).
3. **Variant table coverage** — every name in
   ``repro.core.variants.variant_names()`` must appear in
   docs/VARIANTS.md, so the documented matrix cannot silently drift from
   the code (skipped with a note if the package import fails, e.g. when
   run without PYTHONPATH=src).

Exit code is nonzero on any failure; failures are listed one per line.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DOC_FILES = [REPO / "README.md", REPO / "ROADMAP.md", REPO / "CHANGES.md"] + sorted(
    (REPO / "docs").glob("*.md")
)

# [text](target) — excluding images is unnecessary; they must exist too.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```(?:bash|sh)\n(.*?)```", re.DOTALL)


def check_links(failures: list) -> None:
    for md in DOC_FILES:
        if not md.exists():
            continue
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]  # strip anchors
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                failures.append(f"{md.relative_to(REPO)}: broken link -> {target}")


def check_readme_commands(failures: list) -> None:
    readme = REPO / "README.md"
    blocks = _FENCE_RE.findall(readme.read_text())
    if not blocks:
        failures.append("README.md: no bash blocks found (install/test commands missing?)")
        return
    for i, block in enumerate(blocks):
        proc = subprocess.run(
            ["bash", "-n"], input=block, capture_output=True, text=True
        )
        if proc.returncode != 0:
            failures.append(
                f"README.md: bash block #{i + 1} does not parse: {proc.stderr.strip()}"
            )


def check_variant_table(failures: list) -> None:
    variants_md = REPO / "docs" / "VARIANTS.md"
    if not variants_md.exists():
        failures.append("docs/VARIANTS.md missing")
        return
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.core.variants import variant_names
    except Exception as exc:  # pragma: no cover - environment-dependent
        print(f"note: skipping variant-table check (import failed: {exc})")
        return
    from repro.core.variants import REGISTRY

    text = variants_md.read_text()
    # Collect the backticked tokens the table documents.  Two kinds of
    # family rows expand:
    #   * enumerated  — lci_d{1,2,4,8,16,32} lists its members;
    #   * grammar     — lci_b{depth} / lci_eager_{k}k: the token IS a
    #     registered family's grammar string, and the row covers exactly
    #     what that family's compiled regex resolves (lci_b4, lci_b8, ...).
    #     The regex comes from the registry (VariantSpec.regex) — ONE
    #     grammar shared between the resolver and this gate, never
    #     re-implemented here.  A {placeholder} token matching no
    #     registered family documents nothing.
    # Bare substring matching would be vacuous ('sync' ⊂ 'sendrecv_sync',
    # 'lci' ⊂ every lci_* row) — deleting a row must actually fail the
    # check, so non-family tokens match exactly.
    specs_by_grammar = {spec.grammar: spec for spec in REGISTRY.families()}
    documented = set()
    family_patterns = []
    for token in re.findall(r"`([^`]+)`", text):
        m = re.fullmatch(r"([\w]+)\{([\d,]+)\}", token)
        if m:
            documented.update(m.group(1) + n for n in m.group(2).split(","))
        elif token in specs_by_grammar:
            family_patterns.append(specs_by_grammar[token].regex)
        else:
            documented.add(token)
    for name in variant_names():
        if name in documented:
            continue
        if any(p.fullmatch(name) for p in family_patterns):
            continue
        failures.append(f"docs/VARIANTS.md: variant {name!r} undocumented")


def main() -> int:
    failures: list = []
    check_links(failures)
    check_readme_commands(failures)
    check_variant_table(failures)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"check_docs: {len(failures)} failure(s) across {len(DOC_FILES)} files")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
