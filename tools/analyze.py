#!/usr/bin/env python3
"""Run the repro.analysis concurrency passes (thin launcher).

Usage mirrors the installed ``repro-analyze`` console script:

    python tools/analyze.py                 # all passes, baseline-aware
    python tools/analyze.py --list
    python tools/analyze.py -p lock-order -p blocking-under-lock
    python tools/analyze.py --strict --json analysis_findings.json
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
