"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the pod axis is
pure data parallelism over DCN in the baseline layout.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from ..sharding.logical import DEFAULT_TABLE, ShardingRules

__all__ = ["make_production_mesh", "make_rules", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2), axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_rules(mesh: Mesh, *, long_context: bool = False, overrides: Optional[dict] = None) -> ShardingRules:
    """Bind the logical table to a mesh.  Axes missing from the mesh are
    dropped; ``long_context`` turns on KV-cache sequence sharding (context
    parallelism for the ``long_500k`` decode cells)."""
    table = dict(DEFAULT_TABLE)
    if long_context:
        table["seq_kv"] = "data"
    if overrides:
        table.update(overrides)
    present = set(mesh.shape)

    def fix(v):
        if v is None:
            return None
        if isinstance(v, (tuple, list)):
            kept = tuple(a for a in v if a in present)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return v if v in present else None

    return ShardingRules({k: fix(v) for k, v in table.items()}, mesh)
