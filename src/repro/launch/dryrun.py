import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods × 256 chips.  For each cell the
appropriate step function (train_step / prefill / decode_step) is jitted
with the derived shardings, lowered from ShapeDtypeStructs (no
allocation), compiled, and its memory/cost/collective profile is written
to ``experiments/dryrun/<cell>.json`` — the roofline layer (§Roofline)
reads these artifacts.

CLI::

    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--cells a:s,b:s2]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import SHAPES, cell_is_applicable, get_config, list_archs
from ..optim import OptHParams
from ..sharding.logical import use_rules
from ..sharding.params import batch_specs, cache_specs, opt_specs, param_specs, tree_shardings
from ..train import TrainConfig, make_train_step
from .mesh import make_production_mesh, make_rules
from .specs import abstract_cache, abstract_params, abstract_train_state, input_specs

__all__ = ["dryrun_cell", "collective_bytes", "main"]

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _parse_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[8,4096,512]'."""
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the (post-SPMD) HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = COLLECTIVE_RE.search(line.split("=")[-1][:120]) if "=" in line else None
        if not m:
            continue
        # only actual op applications: "<shape> <op-name>(" pattern
        rhs = line.split("=", 1)[1].strip()
        op = m.group(1)
        if not re.match(rf"[a-z0-9\[\],() ]*{op}", rhs.split("(")[0]):
            continue
        lhs_type = rhs.split(op)[0].strip()
        b = _parse_bytes(lhs_type)
        if b:
            out[op] = out.get(op, 0) + b
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
    except Exception:
        return {}


def _memory_analysis(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        keys = [
            "generated_code_size_in_bytes",
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
        ]
        return {k: float(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception:
        return {}


def dryrun_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    tcfg: Optional[TrainConfig] = None,
    rules_overrides: Optional[dict] = None,
    save_hlo: bool = False,
    out_dir: Optional[Path] = None,
) -> Dict[str, Any]:
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_applicable(arch, shape)
    if not ok:
        return {"cell": f"{arch_name}×{shape_name}", "status": "skipped", "reason": reason}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    # serving cells shard the KV-cache sequence: over "model" for 32k
    # shapes, over every axis for the single-request 500k cell
    overrides = dict(rules_overrides or {})
    if shape.kind != "train" and "seq_kv" not in overrides:
        if shape.name == "long_500k":
            overrides["seq_kv"] = ("data", "model") if not multi_pod else ("pod", "data", "model")
        else:
            overrides["seq_kv"] = "model"
    # §Perf: head counts that don't divide the model axis would replicate
    # all attention compute/score traffic — switch those cells to
    # sequence-parallel attention (seq_act) instead
    model_size = mesh.shape.get("model", 1)
    if (
        "seq_act" not in overrides
        and shape.kind in ("train", "prefill")
        and arch.n_heads
        and arch.n_heads % model_size != 0
    ):
        overrides.setdefault("seq_act", "model")
        overrides.setdefault("heads", None)
        overrides.setdefault("kv_heads", None)
    rules = make_rules(mesh, long_context=False, overrides=overrides)
    result_overrides = {k: v for k, v in overrides.items()}
    tcfg = tcfg or TrainConfig()
    result: Dict[str, Any] = {
        "cell": f"{arch_name}×{shape_name}",
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "rules_overrides": {k: str(v) for k, v in result_overrides.items()},
        "tcfg": {"microbatches": tcfg.microbatches, "remat": tcfg.remat},
    }
    with use_rules(rules), mesh:
        batch = input_specs(arch, shape)
        b_sh = tree_shardings(mesh, batch_specs(batch, rules), batch)
        if shape.kind == "train":
            state = abstract_train_state(arch, tcfg)
            p_spec = param_specs(state["params"], rules)
            o_spec = opt_specs(state["opt"], state["params"], rules, zero=True, mesh=mesh)
            s_spec: Dict[str, Any] = {"params": p_spec, "opt": o_spec, "step": jax.sharding.PartitionSpec()}
            if "ef" in state:
                s_spec["ef"] = p_spec
            s_sh = tree_shardings(mesh, s_spec, state)
            hp = OptHParams()
            step = make_train_step(arch, hp, tcfg)
            jitted = jax.jit(step, in_shardings=(s_sh, b_sh), donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        else:
            from ..models import decode_step, prefill

            params = abstract_params(arch)
            p_sh = tree_shardings(mesh, param_specs(params, rules), params)
            context = shape.seq_len
            cache = abstract_cache(arch, shape.global_batch, context)
            c_sh = tree_shardings(mesh, cache_specs(cache, rules), cache)
            if shape.kind == "prefill":
                fn = lambda p, b, c: prefill(p, arch, b, c)
                jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,))
                lowered = jitted.lower(params, batch, cache)
            else:
                fn = lambda p, t, pos, c: decode_step(p, arch, t, pos, c)
                jitted = jax.jit(
                    fn,
                    in_shardings=(p_sh, b_sh["tokens"], b_sh["positions"], c_sh),
                    donate_argnums=(3,),
                )
                lowered = jitted.lower(params, batch["tokens"], batch["positions"], cache)
        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)
        result["cost"] = _cost_analysis(compiled)
        result["memory"] = _memory_analysis(compiled)
        hlo = compiled.as_text()
        from ..roofline.hlo_parse import analyze_hlo

        analysis = analyze_hlo(hlo)
        result["collective_bytes"] = analysis.collective_bytes
        result["dot_flops"] = analysis.dot_flops
        result["dot_bytes"] = analysis.dot_bytes
        result["hbm_bytes"] = analysis.hbm_bytes
        result["while_trip_counts"] = analysis.while_trip_counts
        result["hlo_lines"] = hlo.count("\n")
        result["status"] = "ok"
        if save_hlo and out_dir is not None:
            (out_dir / f"{arch_name}__{shape_name}__{result['mesh']}.hlo").write_text(hlo)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cells", default=None, help="comma list arch:shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="dots")
    ap.add_argument(
        "--override",
        action="append",
        default=[],
        help="sharding-rule override key=axis (repeatable), e.g. seq_act=model",
    )
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    tcfg = TrainConfig(microbatches=args.microbatches, remat=args.remat)
    cli_overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        cli_overrides[k] = None if v in ("", "none", "None") else (
            tuple(v.split("+")) if "+" in v else v
        )

    cells = []
    if args.cells:
        for c in args.cells.split(","):
            a, s = c.split(":")
            cells.append((a, s))
    elif args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all or --cells"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_fail = 0
    for arch_name, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_name}__{shape_name}__{'pod2' if mp else 'pod1'}"
            path = out_dir / f"{tag}.json"
            try:
                res = dryrun_cell(
                    arch_name,
                    shape_name,
                    multi_pod=mp,
                    tcfg=tcfg,
                    rules_overrides=cli_overrides or None,
                    save_hlo=args.save_hlo,
                    out_dir=out_dir,
                )
            except Exception as e:  # noqa: BLE001 - reported per cell
                res = {
                    "cell": f"{arch_name}×{shape_name}",
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                n_fail += 1
            path.write_text(json.dumps(res, indent=1))
            status = res["status"]
            extra = ""
            if status == "ok":
                fl = res["cost"].get("flops", 0)
                cb = sum(res["collective_bytes"].values())
                extra = f" lower={res['lower_s']}s compile={res['compile_s']}s flops={fl:.3g} coll={cb/1e9:.2f}GB"
            elif status == "error":
                extra = " " + res["error"][:120]
            print(f"[{status:7s}] {tag}{extra}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
