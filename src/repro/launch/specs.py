"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(arch, shape)`` returns the abstract batch for a cell:
token ids (+ labels) for training, prompt tokens for prefill, one-token
batches + cache for decode.  Modality frontends are stubs per the
assignment: ``frames`` (audio) / ``prefix`` (vision) arrive as precomputed
embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import model as model_lib

__all__ = ["input_specs", "abstract_params", "abstract_cache", "abstract_train_state"]


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(arch.dtype)
    if shape.kind == "train":
        s_text = s - (arch.n_prefix_tokens if arch.frontend == "vision" else 0)
        batch: Dict[str, Any] = {
            "tokens": _sds((b, s_text), jnp.int32),
            "labels": _sds((b, s_text), jnp.int32),
        }
        if arch.frontend == "vision":
            batch["prefix"] = _sds((b, arch.n_prefix_tokens, arch.d_model), dt)
        if arch.is_encdec:
            batch["frames"] = _sds((b, arch.encoder_seq, arch.d_model), dt)
        return batch
    if shape.kind == "prefill":
        s_text = s - (arch.n_prefix_tokens if arch.frontend == "vision" else 0)
        batch = {"tokens": _sds((b, s_text), jnp.int32)}
        if arch.frontend == "vision":
            batch["prefix"] = _sds((b, arch.n_prefix_tokens, arch.d_model), dt)
        if arch.is_encdec:
            batch["frames"] = _sds((b, arch.encoder_seq, arch.d_model), dt)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": _sds((b, 1), jnp.int32),
        "positions": _sds((b,), jnp.int32),
    }


def abstract_params(arch: ArchConfig) -> Any:
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: model_lib.init_params(r, arch), rng)


def abstract_cache(arch: ArchConfig, batch: int, context: int) -> Any:
    return jax.eval_shape(lambda: model_lib.init_cache(arch, batch, context))


def abstract_train_state(arch: ArchConfig, tcfg=None) -> Any:
    from ..train import init_train_state

    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: init_train_state(r, arch, tcfg), rng)
