"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced (smoke) configs end-to-end through
the full production path — executor-prefetched data, jitted train step,
async checkpoints, restart.  On a TPU cluster the same entrypoint binds
the production mesh and sharding rules (``--production``).
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_config, get_smoke_config
from ..optim import OptHParams
from ..sharding.logical import use_rules
from ..train import TrainConfig
from ..train.trainer import Trainer, TrainerConfig
from .mesh import make_production_mesh, make_rules


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-sync", default="auto", choices=["auto", "int8_ef"])
    ap.add_argument("--grad-pack", default="host", choices=["host", "device"],
                    help="explicit-DP wire packer: host reference loop or the "
                         "fused device kernel (bit-identical wire bytes)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production", action="store_true", help="bind the 16x16 production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    hp = OptHParams(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)
    tcfg = TrainConfig(microbatches=args.microbatches, remat=args.remat,
                       grad_sync=args.grad_sync, grad_pack=args.grad_pack)
    run = TrainerConfig(
        batch=args.batch,
        seq=args.seq,
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )

    def go():
        trainer = Trainer(arch, hp, tcfg, run)
        summary = trainer.train()
        print("summary:", summary)
        return 0

    if args.production:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        with use_rules(make_rules(mesh)), mesh:
            return go()
    return go()


if __name__ == "__main__":
    raise SystemExit(main())
