"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the continuous-batching engine on a (smoke) model with a synthetic
request stream submitted from multiple client threads, and prints
latency/throughput stats — the serving-side end-to-end driver.  The
request/response hand-off rides the shared comm layer (``--transport
collective``, the default): requests and token batches cross
``CommInterface`` verbs, driven by the same ``ProgressEngine`` as the
parcelport study; ``--transport inline`` runs the legacy direct path;
``--transport shmem`` rides the one-sided put backend.

``--workers N`` (N > 1) scales the model tier out into the ISSUE 7
fleet: one router, N sharded-KV workers, per-worker channels over one
shared group — same math, same request stream, distributed serving.
``--prefill-chunk C`` turns on chunked prefill (prompts cross the wire
as C-token pieces interleaved with decode).
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from ..configs import get_smoke_config
from ..models import init_params
from ..serve import Fleet, FleetConfig, InferenceServer, ServeConfig


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument(
        "--transport", choices=("collective", "shmem", "inline"), default="collective"
    )
    ap.add_argument(
        "--workers", type=int, default=1,
        help="model workers; >1 runs the router+fleet tier (slots shard across workers)",
    )
    ap.add_argument(
        "--prefill-chunk", type=int, default=0,
        help="chunked prefill: prompt piece size in tokens (0 = single-shot prefill)",
    )
    args = ap.parse_args()

    arch = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), arch)
    if args.workers > 1:
        server = Fleet(
            arch, params,
            FleetConfig(
                workers=args.workers, slots=args.slots, context=256,
                transport=args.transport, prefill_chunk=args.prefill_chunk,
            ),
        )
    else:
        server = InferenceServer(
            arch, params,
            ServeConfig(
                slots=args.slots, context=256, transport=args.transport,
                prefill_chunk=args.prefill_chunk,
            ),
        )
    rng = np.random.default_rng(0)
    reqs = []
    lock = threading.Lock()

    def client(n: int) -> None:
        for _ in range(n):
            prompt = rng.integers(0, arch.vocab_size, size=args.prompt_len).tolist()
            r = server.submit(prompt, max_new=args.max_new)
            with lock:
                reqs.append(r)
            time.sleep(0.001)

    per = args.requests // args.clients
    threads = [threading.Thread(target=client, args=(per,)) for _ in range(args.clients)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    # engine loop = the shared progress engine (paper §3.3.4, explicit
    # driving): each step pumps the comm hand-off and the batched decode
    while any(t.is_alive() for t in threads) or not server.idle():
        if not server.step():
            time.sleep(1e-3)
    for t in threads:
        t.join()
    server.run_until_idle()
    dt = time.monotonic() - t0
    done = [r for r in reqs if r.done_event.is_set()]
    ttft = [r.first_token_at - r.submitted_at for r in done if r.first_token_at]
    tier = f"fleet(workers={args.workers})" if args.workers > 1 else "single-host"
    extra = ""
    if args.workers > 1:
        extra = f" eagain={server.eagain_events}"
        server.close()
    print(
        f"requests={len(done)}/{len(reqs)} engine_steps={server.steps} "
        f"tokens={server.tokens_out} throughput={server.tokens_out/dt:.1f} tok/s "
        f"ttft_p50={np.median(ttft)*1e3:.1f}ms transport={args.transport} "
        f"tier={tier}{extra}"
    )
    return 0 if len(done) == len(reqs) else 1


if __name__ == "__main__":
    raise SystemExit(main())
