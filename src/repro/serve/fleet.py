"""Multi-host serving fleet over the comm layer (ISSUE 7, ROADMAP item 1).

One :class:`Router` owns request admission and response collection; N
:class:`ModelWorker`\\ s each hold a **shard of the KV slot space**
(``slots // workers`` slots, ``init_cache`` per worker) and run the SAME
:class:`~repro.serve.server.DecodeCore` as the single-host server.  The
tiers are connected by per-worker :class:`~repro.core.comm.collective.
CommChannel`\\ s over ONE shared transport group, driven by the one
:class:`~repro.core.comm.progress.ProgressEngine` — scaling out the
serving tier is a backend choice, not a rewrite (the paper's HPX+LCI
move applied to inference serving).

Topology: router = rank 0, worker *w* = rank ``1 + w``.  Every channel
shares the router's landing queue for responses, so on put-capable
backends token batches ride ``post_put_signal`` straight into
**router-owned slots** (rank 0's slab) — selected purely by the
advertised :class:`~repro.core.comm.interface.Capabilities`, exactly the
PR 6 channel path.  Requests stay two-sided (tagged sends to each
worker's rank).

Scheduling:

* **free-slot-load routing** — a new request goes to the worker with the
  most estimated headroom (slot shard + admission queue − outstanding),
  ties to the lowest worker id (deterministic);
* **cache-affinity stickiness** — follow-up prompt chunks always go to
  the worker that admitted the first chunk (its cache holds the prefix);
* **chunked prefill** — prompts longer than ``prefill_chunk`` cross the
  wire split into chunk messages, one per router step, and the worker
  consumes them interleaved with decode (see ``DecodeCore``): prefill
  never stalls decode;
* **typed admission backpressure** — a worker whose admission queue is
  full refuses the request with an ``('eagain', ...)`` response; the
  router RE-QUEUES it (never drops), decrementing that worker's load
  estimate so the retry prefers less-loaded workers.

The headline property (tests/test_fleet.py): for any request trace, the
1-router × N-worker fleet over every backend emits exactly the
per-request token sequences of the single-host reference — the comm
layer and the sharding move the bytes, not the math.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..checkpoint.snapshot import pack_state, unpack_state
from ..configs.base import ArchConfig
from ..core.comm.collective import CollectiveGroup, CommChannel
from ..core.comm.membership import GONE, Membership
from ..core.comm.progress import (
    CompletionRouter,
    CompletionSource,
    ProgressEngine,
    ProgressPolicy,
    run_step,
)
from ..core.comm.resources import ResourceLimits
from ..core.comm.wire import decode_msg, encode_msg
from .server import DecodeCore, Request

__all__ = ["FleetConfig", "ModelWorker", "Router", "Fleet"]


@dataclass
class FleetConfig:
    workers: int = 2
    slots: int = 4  # TOTAL slot space, sharded slots // workers per worker
    context: int = 256
    max_prefill: int = 64
    # 0 = single-shot prefill at admission; N>0 = prompts cross the wire
    # as N-token chunk messages, consumed interleaved with decode
    prefill_chunk: int = 0
    # per-worker admission-queue bound: a "new" request beyond this is
    # refused with a typed EAGAIN response (router re-queues, never drops)
    admission_depth: int = 2
    # Elastic capacity (ISSUE 8): rank slots are pre-provisioned for up to
    # max_workers workers (0 = fixed fleet of `workers`), so add_worker /
    # leave_worker never rebuild the transport group — a departed rank's
    # channel and shmem slab are REUSED by the next join, which is what
    # keeps thread/segment counts flat over join/leave cycles.
    max_workers: int = 0
    transport: str = "collective"  # 'inline' | 'collective' | 'shmem'
    # the ProgressPolicy.for_config axes, same as ServeConfig/LCIPPConfig
    progress_mode: str = "explicit"
    lock_mode: str = "none"
    progress_workers: int = 0
    limits: ResourceLimits = field(default_factory=ResourceLimits)


class ModelWorker:
    """One model shard: a :class:`DecodeCore` over ``slots`` of the fleet's
    slot space plus a bounded admission queue.  Transport-blind — the
    router hands it decoded request messages and collects its emissions."""

    def __init__(
        self,
        wid: int,
        arch: ArchConfig,
        params: Any,
        slots: int,
        context: int,
        max_prefill: int,
        prefill_chunk: int,
        admission_depth: int,
    ):
        self.wid = wid
        self.core = DecodeCore(arch, params, slots, context, max_prefill, prefill_chunk)
        self.admission_depth = admission_depth
        self._pending: deque = deque()  # accepted, awaiting a free slot
        self._reqs: Dict[int, Request] = {}  # rid -> worker-side request
        self._open: Dict[int, bool] = {}  # rid -> more chunks expected
        self._adopt_queue: deque = deque()  # handoff snapshots awaiting a slot
        self._adopt_rids: set = set()  # rids whose snapshot awaits splicing
        self._chunk_stash: Dict[int, List[tuple]] = {}  # chunks that outran an adopt
        self.outbox: List[tuple] = []  # (rid, tok, done) of this step
        self.eagain_refusals = 0
        self.adoptions = 0  # slots adopted from departing workers
        self.rids_seen: List[int] = []  # admission order (stickiness proof)

    # --------------------------------------------------------- request plane
    def handle_request(self, msg: tuple) -> Optional[tuple]:
        """Apply one router→worker message.  Returns a refusal message to
        send back, or None."""
        kind = msg[0]
        if kind == "new":
            _, rid, tokens, last, max_new = msg
            self._chunk_stash.pop(rid, None)  # a re-dispatch replans all chunks
            if len(self._pending) >= self.admission_depth:
                # typed admission backpressure: the worker's EAGAIN — the
                # router re-queues the request, it is NEVER dropped here
                self.eagain_refusals += 1
                return ("eagain", self.wid, rid)
            req = Request(rid=rid, prompt=list(tokens), max_new=max_new)
            self._reqs[rid] = req
            self._open[rid] = not last
            self._pending.append(req)
            self.rids_seen.append(rid)
            return None
        if kind == "adopt":
            # a departing worker's slot, serialized by checkpoint.snapshot;
            # queued (admission takes a free slot) and spliced in _admit —
            # adoption has priority over new admissions: it is mid-stream
            _, rid, payload = msg
            self._adopt_queue.append(payload)
            self._adopt_rids.add(rid)
            return None
        assert kind == "chunk", kind
        _, rid, tokens, last = msg
        req = self._reqs.get(rid)
        if req is None:
            if rid in self._adopt_rids:
                # the chunk outran its slot's adoption (the snapshot waits
                # for a free slot): stash it, applied at the splice
                self._chunk_stash.setdefault(rid, []).append((list(tokens), last))
                return None
            # orphan chunk of a refused request: the channel is FIFO per
            # direction, so these all precede any re-dispatched "new"
            return None
        if self.core.prefilling(rid):
            self.core.feed_chunk(rid, list(tokens), last)
        else:  # still queued: extend the prompt before admission
            req.prompt.extend(tokens)
            if last:
                self._open[rid] = False
        if last:
            self._open[rid] = False
        return None

    # ------------------------------------------------------------ decode plane
    def _adopt(self) -> None:
        while self._adopt_queue and self.core.free_slots():
            state, meta = unpack_state(
                self._adopt_queue.popleft(), abstract=self.core.abstract_slot_state()
            )
            req = Request(rid=meta["rid"], prompt=list(meta["prompt"]), max_new=meta["max_new"])
            self._reqs[req.rid] = req
            self._open[req.rid] = bool(meta.get("prefill_open", False))
            self.core.adopt_slot(state, meta, req)
            self.adoptions += 1
            self._adopt_rids.discard(req.rid)
            for tokens, last in self._chunk_stash.pop(req.rid, ()):
                if self.core.prefilling(req.rid):
                    self.core.feed_chunk(req.rid, list(tokens), last)
                else:
                    req.prompt.extend(tokens)
                if last:
                    self._open[req.rid] = False

    def _admit(self) -> None:
        self._adopt()
        while self._pending and self.core.free_slots():
            req = self._pending[0]
            if self.core.prefill_chunk <= 0 and self._open.get(req.rid):
                return  # single-shot prefill needs the whole prompt first
            self._pending.popleft()
            self.core.admit(req, self._emit, more_chunks=self._open[req.rid])

    def _emit(self, req: Request, tok: int, done: bool) -> None:
        self.outbox.append((req.rid, tok, done))
        if done:
            self._reqs.pop(req.rid, None)
            self._open.pop(req.rid, None)

    def step(self) -> bool:
        self._admit()
        return self.core.step(self._emit)

    def busy(self) -> bool:
        return bool(self._pending) or bool(self._adopt_queue) or self.core.active()


class Router:
    """The admission/collection tier.  ``Router`` owns the client-facing
    request objects, the routing + chunking state machine, and (for comm
    transports) the shared group, the per-worker channels and the ONE
    progress engine.  It is also the engine's op adapter (``execute``),
    exactly like :class:`~repro.serve.server.InferenceServer`."""

    def __init__(self, arch: ArchConfig, params: Any, cfg: Optional[FleetConfig] = None):
        self.cfg = cfg = FleetConfig() if cfg is None else cfg
        assert cfg.workers >= 1 and cfg.slots >= cfg.workers, (cfg.workers, cfg.slots)
        self.arch, self.params = arch, params
        self.max_workers = max(cfg.max_workers, cfg.workers)
        self._per_worker_slots = cfg.slots // cfg.workers
        # lifecycle is owned by the Membership subsystem (ISSUE 8): worker
        # wid == member rank; routing consults the ACTIVE set, racing posts
        # to a DRAINING rank resolve to typed EAGAIN, a worker that dies
        # without leave() is reaped by the finalizer sweep at close()
        self.membership = Membership()
        self.workers: List[Optional[ModelWorker]] = [None] * self.max_workers
        self._rid = itertools.count()
        self._queue: deque = deque()  # un-routed (or re-queued) requests
        self._inflight: Dict[int, Request] = {}  # rid -> client-side request
        self._inflight_lock = threading.Lock()
        self._sticky: Dict[int, int] = {}  # rid -> admitting worker
        self._chunks: Dict[int, deque] = {}  # rid -> unsent chunk messages
        self._orphans: deque = deque()  # handoff snapshots awaiting capacity
        self._outstanding = [0] * self.max_workers  # dispatched - (done|eagain)
        self.eagain_events = 0  # worker refusals observed by the router
        self.requeues = 0
        self.completed = 0
        self.steps = 0
        self.joins = 0
        self.leaves = 0
        self.handoffs = 0
        # ---- transport ----------------------------------------------------
        # Rank slots are provisioned for max_workers up front: joins and
        # leaves re-point routing, they NEVER rebuild the group — a
        # departed rank's channel/slab is reused by the next join.
        self.group: Any = None
        self.channels: List[CommChannel] = []
        self.engine: Optional[ProgressEngine] = None
        if cfg.transport in ("collective", "shmem"):
            if cfg.transport == "shmem":
                from ..core.comm.shmem import ShmemGroup

                self.group = ShmemGroup(
                    1 + self.max_workers, 1, limits=cfg.limits, completion_mode="queue"
                )
            else:
                self.group = CollectiveGroup(1 + self.max_workers, 1, limits=cfg.limits)
            # channel w: router (rank 0, the shared client endpoint) <->
            # worker w (rank 1+w); ALL channels land responses in channel
            # 0's queue — the router-owned landing slots
            for w in range(self.max_workers):
                self.channels.append(
                    CommChannel(
                        limits=cfg.limits,
                        backend=cfg.transport,
                        group=self.group,
                        client_rank=0,
                        server_rank=1 + w,
                        response_cq=self.channels[0].response_cq if w else None,
                    )
                )
            self.engine = ProgressEngine(
                ProgressPolicy.for_config(cfg).variant(step_lock=True),
                CompletionRouter(
                    [CompletionSource(f"request:{w}") for w in range(self.max_workers)]
                    + [CompletionSource("response")],
                    ndevices=1,
                ),
                ndevices=1,
            )
            self._step_lock = threading.Lock()
        else:
            assert cfg.transport == "inline", cfg.transport
        for _ in range(cfg.workers):
            self.add_worker(initial=True)

    # ------------------------------------------------------- elastic lifecycle
    def add_worker(self, initial: bool = False) -> int:
        """Join a worker on a free rank slot (JOINING → ACTIVE); it picks
        up routing share on the next router step.  The transport was
        provisioned for ``max_workers`` at construction, so a join only
        re-points routing — a departed rank's channel is reused."""
        free = [w for w in range(self.max_workers) if self.membership.state(w) in (None, GONE)]
        if not free:
            raise ValueError(f"fleet is at max_workers={self.max_workers}")
        wid = free[0]
        worker = ModelWorker(
            wid, self.arch, self.params, self._per_worker_slots, self.cfg.context,
            self.cfg.max_prefill, self.cfg.prefill_chunk, self.cfg.admission_depth,
        )
        self.workers[wid] = worker
        self.membership.join(wid, owner=worker, on_gone=self._on_worker_gone)
        self.membership.activate(wid)
        if not initial:
            self.joins += 1
        return wid

    def leave_worker(self, wid: int) -> bool:
        """Drain worker ``wid`` out of the live fleet: stop admitting,
        pull its un-admitted requests back to the router queue, hand every
        ACTIVE slot to a successor as a ``checkpoint.snapshot`` payload
        over the existing channel (bit-identical continuation), then
        deregister — the rank returns to the free pool.  Idempotent:
        returns False if already DRAINING/GONE."""
        if not any(w != wid for w in self.membership.active_ranks()):
            raise ValueError("cannot drain the last active worker")
        if not self.membership.begin_drain(wid):
            return False
        worker = self.workers[wid]
        # 0) settle the wire: flush emitted tokens, then pump the channel
        #    until nothing to/from the leaver is in flight — an in-flight
        #    "new"/"chunk" must land in the worker's queues (and be drained
        #    below), never die with the rank
        self._flush_workers()
        if self.channels:
            for _ in range(10_000):
                self._comm_step()
                if not self.channels[wid].pending_work():
                    break
        # 1) drain the admission deque: un-admitted requests re-queue at
        #    the router (they re-route by load — zero drops)
        while worker._pending:
            req = worker._pending.popleft()
            worker._reqs.pop(req.rid, None)
            worker._open.pop(req.rid, None)
            self._outstanding[wid] -= 1
            self._sticky.pop(req.rid, None)
            self._chunks.pop(req.rid, None)  # re-planned on re-dispatch
            with self._inflight_lock:
                client_req = self._inflight.get(req.rid)
            if client_req is not None:
                self.requeues += 1
                self._queue.append(client_req)
        # 2) hand off every mid-decode slot, serialized + validated by the
        #    snapshot codec; sticky routing follows the slot
        for slot in worker.core.active_slots():
            state, meta = worker.core.extract_slot(slot)
            rid = meta["rid"]
            worker._reqs.pop(rid, None)
            worker._open.pop(rid, None)
            self._outstanding[wid] -= 1
            self._handoff(rid, pack_state(state, meta))
        # un-adopted snapshots this worker still held travel onward too,
        # with any chunks that outran them re-queued ahead of the plan
        while worker._adopt_queue:
            payload = worker._adopt_queue.popleft()
            _, meta = unpack_state(payload)
            rid = meta["rid"]
            stash = worker._chunk_stash.pop(rid, None)
            if stash:
                rest = self._chunks.setdefault(rid, deque())
                for tokens, last in reversed(stash):
                    rest.appendleft(("chunk", rid, tokens, last))
            self._outstanding[wid] -= 1
            self._handoff(rid, payload)
        # 3) quiesced: deregister, return the rank to the pool
        self.membership.finish_leave(wid)
        self.leaves += 1
        return True

    def _on_worker_gone(self, member) -> None:
        # the GONE hook (leave OR abandon-sweep): the rank's worker slot
        # returns to the pool; the channel/slab stay provisioned for reuse
        self.workers[member.rank] = None

    def _handoff(self, rid: int, payload: bytes) -> None:
        dst = self._pick_successor()
        if dst is None:
            self._orphans.append((rid, payload))  # placed when capacity frees
            return
        self._sticky[rid] = dst
        self._outstanding[dst] += 1
        self.handoffs += 1
        self._send(dst, ("adopt", rid, payload))

    def _pick_successor(self) -> Optional[int]:
        """The ACTIVE worker with the most genuinely free slots (free
        minus queued admissions/adoptions); None if nobody has room."""
        best, best_free = None, 0
        for w in self.membership.active_ranks():
            worker = self.workers[w]
            free = len(worker.core.free_slots()) - len(worker._pending) - len(worker._adopt_queue)
            if free > best_free:
                best, best_free = w, free
        return best

    def _place_orphans(self) -> None:
        for _ in range(len(self._orphans)):
            rid, payload = self._orphans.popleft()
            dst = self._pick_successor()
            if dst is None:
                self._orphans.appendleft((rid, payload))
                return
            self._sticky[rid] = dst
            self._outstanding[dst] += 1
            self.handoffs += 1
            self._send(dst, ("adopt", rid, payload))

    # ------------------------------------------------------------------ client
    def submit(self, prompt: List[int], max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt), max_new=max_new)
        req.submitted_at = time.monotonic()
        with self._inflight_lock:
            self._inflight[req.rid] = req
        self._queue.append(req)
        return req

    # ------------------------------------------------- routing + chunk plan
    def _plan(self, req: Request) -> tuple:
        """Split a request into its wire messages: the ``new`` message and
        any follow-up ``chunk`` messages (chunked prefill)."""
        prompt = req.prompt[: self.cfg.max_prefill]
        chunk = self.cfg.prefill_chunk
        if chunk <= 0 or len(prompt) <= chunk:
            return ("new", req.rid, prompt, True, req.max_new), deque()
        pieces = [prompt[i : i + chunk] for i in range(chunk, len(prompt), chunk)]
        rest = deque(
            ("chunk", req.rid, piece, i == len(pieces) - 1)
            for i, piece in enumerate(pieces)
        )
        return ("new", req.rid, prompt[:chunk], False, req.max_new), rest

    def _pick_worker(self) -> Optional[int]:
        """Free-slot-load routing over the ACTIVE membership: most
        headroom wins, ties to the lowest worker id.  Dispatch is
        optimistic — the authoritative bound is the worker's own admission
        queue (its EAGAIN, our re-queue)."""
        active = self.membership.active_ranks()
        if not active:
            return None
        per = self._per_worker_slots

        def headroom(w: int) -> int:
            return per + self.cfg.admission_depth - self._outstanding[w]

        return max(active, key=lambda w: (headroom(w), -w))

    def _send(self, wid: int, msg: tuple) -> None:
        if self.channels:
            self.channels[wid].send_request(encode_msg(msg))
        else:  # inline: same messages, no serialization hop
            refusal = self.workers[wid].handle_request(msg)
            if refusal is not None:
                self._handle_response(encode_msg([refusal]))

    def _route(self) -> None:
        # new (and re-queued) requests: route by load, send first chunk.
        # Snapshot the count: an inline-mode refusal re-queues
        # synchronously, and a refused request must wait for the NEXT
        # router step (after workers have stepped), not spin here.
        for _ in range(len(self._queue)):
            req = self._queue.popleft()
            wid = self._pick_worker()
            if wid is None:
                self._queue.append(req)  # no ACTIVE worker: wait, never drop
                break
            new_msg, rest = self._plan(req)
            self._sticky[req.rid] = wid
            self._chunks[req.rid] = rest
            self._outstanding[wid] += 1
            self._send(wid, new_msg)
        # follow-up chunks: ONE per request per router step, to the sticky
        # worker — prefill traffic interleaves with decode, never bursts
        for rid in list(self._chunks):
            rest = self._chunks.get(rid)
            if rest is None or rid not in self._sticky:
                continue  # refused meanwhile: re-planned on re-dispatch
            if not rest:
                del self._chunks[rid]
                continue
            wid = self._sticky[rid]
            if not self.membership.guard_post(wid):
                # typed EAGAIN_DRAINING: the sticky worker is leaving —
                # the chunk stays queued (its prefill state travels in the
                # handoff snapshot, which re-points sticky), never dropped
                continue
            self._send(wid, rest.popleft())

    # -------------------------------------------------------- response plane
    def _handle_response(self, payload: bytes) -> None:
        now = time.monotonic()
        for item in decode_msg(payload):
            if item[0] == "eagain":
                _, wid, rid = item
                self.eagain_events += 1
                self.requeues += 1
                self._outstanding[wid] -= 1
                self._sticky.pop(rid, None)
                self._chunks.pop(rid, None)  # re-plan (and re-send) everything
                with self._inflight_lock:
                    req = self._inflight.get(rid)
                if req is not None:
                    self._queue.append(req)  # re-queued, NEVER dropped
                continue
            rid, tok, done = item
            with self._inflight_lock:
                req = self._inflight.get(rid)
            if req is None:
                continue
            if req.first_token_at is None:
                req.first_token_at = now
            req.out_tokens.append(tok)
            if done:
                req.finished_at = now
                req.done_event.set()
                self.completed += 1
                wid = self._sticky.pop(rid, None)
                if wid is not None:
                    self._outstanding[wid] -= 1
                with self._inflight_lock:
                    self._inflight.pop(rid, None)

    def _flush_workers(self) -> None:
        for w, worker in enumerate(self.workers):
            if worker is None or not worker.outbox:
                continue
            batch, worker.outbox = worker.outbox, []
            if self.channels:
                self.channels[w].send_response(encode_msg(batch))
            else:
                self._handle_response(encode_msg(batch))

    # -------------------------------------------- the engine's op adapter
    def execute(self, op: tuple) -> Any:
        """The fleet's half of the engine contract: one op against the
        per-worker channels (N request sources + the shared response
        source — the engine never interprets the names, this adapter
        does)."""
        kind = op[0]
        if kind == "reap":
            name = op[1].name
            if name == "response":
                return self.channels[0].response_cq.reap()
            return self.channels[int(name.split(":", 1)[1])].request_cq.reap()
        if kind == "dispatch":
            src, rec = op[1].name, op[3]
            if rec.op == "send":
                return True
            if src == "response":
                if rec.ctx == "response":  # two-sided recv consumed a pre-post
                    self.channels[0].repost("response")
                self._handle_response(rec.data)
                return True
            wid = int(src.split(":", 1)[1])
            self.channels[wid].repost("request")
            worker = self.workers[wid]
            if worker is None:
                # raced a completed leave (the drain pump settles the wire,
                # so this only guards against loss becoming a crash)
                return True
            refusal = worker.handle_request(decode_msg(rec.data))
            if refusal is not None:
                self.channels[wid].send_response(encode_msg([refusal]))
            return True
        if kind == "progress":
            moved = False
            for ch in self.channels:
                moved = ch.progress() or moved
            return moved
        if kind == "poll":
            moved = False
            for ch in self.channels:
                moved = ch.poll() or moved
            return moved
        if kind == "drain_retries":
            moved = False
            for ch in self.channels:
                moved = ch.drain_retries() or moved
            return moved
        if kind == "step_trylock":
            return self._step_lock.acquire(blocking=False)
        if kind == "step_unlock":
            self._step_lock.release()
            return True
        if kind == "dev_trylock":
            return True
        return False

    def _comm_step(self) -> bool:
        if self.engine is None:
            return False
        return run_step(self.engine, self, 0)

    # ------------------------------------------------------------------ engine
    def step(self) -> bool:
        """One fleet iteration: pump the channels, route, step every
        worker's decode shard, flush token batches back."""
        self._comm_step()
        self._place_orphans()
        self._route()
        worked = False
        for worker in self.workers:
            if worker is not None:
                worked = worker.step() or worked
        self._flush_workers()
        self._comm_step()
        self.steps += 1
        return worked

    @property
    def tokens_out(self) -> int:
        return sum(w.core.tokens_out for w in self.workers if w is not None)

    def idle(self) -> bool:
        if self._queue or self._chunks or self._orphans:
            return False
        if any(w.busy() for w in self.workers if w is not None):
            return False
        if self._inflight:
            return False
        return not any(ch.pending_work() for ch in self.channels)

    def run_until_idle(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and self.idle():
                return

    # --------------------------------------------------------------- teardown
    def close(self) -> None:
        """Release transport resources (idempotent) — the fleet lifecycle
        leak regression cycles this 50×.  The membership liveness sweep
        runs FIRST (teardown ordering, ISSUE 8): workers that died without
        leave() have their on_gone hooks return their slots while the
        transports are still alive."""
        self.membership.sweep()
        if self.group is not None and hasattr(self.group, "close"):
            self.group.close()
        self.channels = []
        self.engine = None
        self.group = None


# The tentpole's public name: a fleet IS its router plus the workers it
# owns — constructing one wires the whole tier up.
Fleet = Router
