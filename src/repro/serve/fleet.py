"""Multi-host serving fleet over the comm layer (ISSUE 7, ROADMAP item 1).

One :class:`Router` owns request admission and response collection; N
:class:`ModelWorker`\\ s each hold a **shard of the KV slot space**
(``slots // workers`` slots, ``init_cache`` per worker) and run the SAME
:class:`~repro.serve.server.DecodeCore` as the single-host server.  The
tiers are connected by per-worker :class:`~repro.core.comm.collective.
CommChannel`\\ s over ONE shared transport group, driven by the one
:class:`~repro.core.comm.progress.ProgressEngine` — scaling out the
serving tier is a backend choice, not a rewrite (the paper's HPX+LCI
move applied to inference serving).

Topology: router = rank 0, worker *w* = rank ``1 + w``.  Every channel
shares the router's landing queue for responses, so on put-capable
backends token batches ride ``post_put_signal`` straight into
**router-owned slots** (rank 0's slab) — selected purely by the
advertised :class:`~repro.core.comm.interface.Capabilities`, exactly the
PR 6 channel path.  Requests stay two-sided (tagged sends to each
worker's rank).

Scheduling:

* **free-slot-load routing** — a new request goes to the worker with the
  most estimated headroom (slot shard + admission queue − outstanding),
  ties to the lowest worker id (deterministic);
* **cache-affinity stickiness** — follow-up prompt chunks always go to
  the worker that admitted the first chunk (its cache holds the prefix);
* **chunked prefill** — prompts longer than ``prefill_chunk`` cross the
  wire split into chunk messages, one per router step, and the worker
  consumes them interleaved with decode (see ``DecodeCore``): prefill
  never stalls decode;
* **typed admission backpressure** — a worker whose admission queue is
  full refuses the request with an ``('eagain', ...)`` response; the
  router RE-QUEUES it (never drops), decrementing that worker's load
  estimate so the retry prefers less-loaded workers.

The headline property (tests/test_fleet.py): for any request trace, the
1-router × N-worker fleet over every backend emits exactly the
per-request token sequences of the single-host reference — the comm
layer and the sharding move the bytes, not the math.
"""
from __future__ import annotations

import itertools
import pickle
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..configs.base import ArchConfig
from ..core.comm.collective import CollectiveGroup, CommChannel
from ..core.comm.progress import (
    CompletionRouter,
    CompletionSource,
    ProgressEngine,
    ProgressPolicy,
    run_step,
)
from ..core.comm.resources import ResourceLimits
from .server import DecodeCore, Request

__all__ = ["FleetConfig", "ModelWorker", "Router", "Fleet"]


@dataclass
class FleetConfig:
    workers: int = 2
    slots: int = 4  # TOTAL slot space, sharded slots // workers per worker
    context: int = 256
    max_prefill: int = 64
    # 0 = single-shot prefill at admission; N>0 = prompts cross the wire
    # as N-token chunk messages, consumed interleaved with decode
    prefill_chunk: int = 0
    # per-worker admission-queue bound: a "new" request beyond this is
    # refused with a typed EAGAIN response (router re-queues, never drops)
    admission_depth: int = 2
    transport: str = "collective"  # 'inline' | 'collective' | 'shmem'
    # the ProgressPolicy.for_config axes, same as ServeConfig/LCIPPConfig
    progress_mode: str = "explicit"
    lock_mode: str = "none"
    progress_workers: int = 0
    limits: ResourceLimits = field(default_factory=ResourceLimits)


class ModelWorker:
    """One model shard: a :class:`DecodeCore` over ``slots`` of the fleet's
    slot space plus a bounded admission queue.  Transport-blind — the
    router hands it decoded request messages and collects its emissions."""

    def __init__(
        self,
        wid: int,
        arch: ArchConfig,
        params: Any,
        slots: int,
        context: int,
        max_prefill: int,
        prefill_chunk: int,
        admission_depth: int,
    ):
        self.wid = wid
        self.core = DecodeCore(arch, params, slots, context, max_prefill, prefill_chunk)
        self.admission_depth = admission_depth
        self._pending: deque = deque()  # accepted, awaiting a free slot
        self._reqs: Dict[int, Request] = {}  # rid -> worker-side request
        self._open: Dict[int, bool] = {}  # rid -> more chunks expected
        self.outbox: List[tuple] = []  # (rid, tok, done) of this step
        self.eagain_refusals = 0
        self.rids_seen: List[int] = []  # admission order (stickiness proof)

    # --------------------------------------------------------- request plane
    def handle_request(self, msg: tuple) -> Optional[tuple]:
        """Apply one router→worker message.  Returns a refusal message to
        send back, or None."""
        kind = msg[0]
        if kind == "new":
            _, rid, tokens, last, max_new = msg
            if len(self._pending) >= self.admission_depth:
                # typed admission backpressure: the worker's EAGAIN — the
                # router re-queues the request, it is NEVER dropped here
                self.eagain_refusals += 1
                return ("eagain", self.wid, rid)
            req = Request(rid=rid, prompt=list(tokens), max_new=max_new)
            self._reqs[rid] = req
            self._open[rid] = not last
            self._pending.append(req)
            self.rids_seen.append(rid)
            return None
        assert kind == "chunk", kind
        _, rid, tokens, last = msg
        req = self._reqs.get(rid)
        if req is None:
            # orphan chunk of a refused request: the channel is FIFO per
            # direction, so these all precede any re-dispatched "new"
            return None
        if self.core.prefilling(rid):
            self.core.feed_chunk(rid, list(tokens), last)
        else:  # still queued: extend the prompt before admission
            req.prompt.extend(tokens)
            if last:
                self._open[rid] = False
        if last:
            self._open[rid] = False
        return None

    # ------------------------------------------------------------ decode plane
    def _admit(self) -> None:
        while self._pending and self.core.free_slots():
            req = self._pending[0]
            if self.core.prefill_chunk <= 0 and self._open.get(req.rid):
                return  # single-shot prefill needs the whole prompt first
            self._pending.popleft()
            self.core.admit(req, self._emit, more_chunks=self._open[req.rid])

    def _emit(self, req: Request, tok: int, done: bool) -> None:
        self.outbox.append((req.rid, tok, done))
        if done:
            self._reqs.pop(req.rid, None)
            self._open.pop(req.rid, None)

    def step(self) -> bool:
        self._admit()
        return self.core.step(self._emit)

    def busy(self) -> bool:
        return bool(self._pending) or self.core.active()


class Router:
    """The admission/collection tier.  ``Router`` owns the client-facing
    request objects, the routing + chunking state machine, and (for comm
    transports) the shared group, the per-worker channels and the ONE
    progress engine.  It is also the engine's op adapter (``execute``),
    exactly like :class:`~repro.serve.server.InferenceServer`."""

    def __init__(self, arch: ArchConfig, params: Any, cfg: Optional[FleetConfig] = None):
        self.cfg = cfg = FleetConfig() if cfg is None else cfg
        assert cfg.workers >= 1 and cfg.slots >= cfg.workers, (cfg.workers, cfg.slots)
        per_worker = cfg.slots // cfg.workers
        self.workers = [
            ModelWorker(
                w, arch, params, per_worker, cfg.context, cfg.max_prefill,
                cfg.prefill_chunk, cfg.admission_depth,
            )
            for w in range(cfg.workers)
        ]
        self._rid = itertools.count()
        self._queue: deque = deque()  # un-routed (or re-queued) requests
        self._inflight: Dict[int, Request] = {}  # rid -> client-side request
        self._inflight_lock = threading.Lock()
        self._sticky: Dict[int, int] = {}  # rid -> admitting worker
        self._chunks: Dict[int, deque] = {}  # rid -> unsent chunk messages
        self._outstanding = [0] * cfg.workers  # dispatched - (done|eagain)
        self.eagain_events = 0  # worker refusals observed by the router
        self.requeues = 0
        self.completed = 0
        self.steps = 0
        # ---- transport ----------------------------------------------------
        self.group: Any = None
        self.channels: List[CommChannel] = []
        self.engine: Optional[ProgressEngine] = None
        if cfg.transport in ("collective", "shmem"):
            if cfg.transport == "shmem":
                from ..core.comm.shmem import ShmemGroup

                self.group = ShmemGroup(
                    1 + cfg.workers, 1, limits=cfg.limits, completion_mode="queue"
                )
            else:
                self.group = CollectiveGroup(1 + cfg.workers, 1, limits=cfg.limits)
            # channel w: router (rank 0, the shared client endpoint) <->
            # worker w (rank 1+w); ALL channels land responses in channel
            # 0's queue — the router-owned landing slots
            for w in range(cfg.workers):
                self.channels.append(
                    CommChannel(
                        limits=cfg.limits,
                        backend=cfg.transport,
                        group=self.group,
                        client_rank=0,
                        server_rank=1 + w,
                        response_cq=self.channels[0].response_cq if w else None,
                    )
                )
            self.engine = ProgressEngine(
                ProgressPolicy.for_config(cfg).variant(step_lock=True),
                CompletionRouter(
                    [CompletionSource(f"request:{w}") for w in range(cfg.workers)]
                    + [CompletionSource("response")],
                    ndevices=1,
                ),
                ndevices=1,
            )
            self._step_lock = threading.Lock()
        else:
            assert cfg.transport == "inline", cfg.transport

    # ------------------------------------------------------------------ client
    def submit(self, prompt: List[int], max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt), max_new=max_new)
        req.submitted_at = time.monotonic()
        with self._inflight_lock:
            self._inflight[req.rid] = req
        self._queue.append(req)
        return req

    # ------------------------------------------------- routing + chunk plan
    def _plan(self, req: Request) -> tuple:
        """Split a request into its wire messages: the ``new`` message and
        any follow-up ``chunk`` messages (chunked prefill)."""
        prompt = req.prompt[: self.cfg.max_prefill]
        chunk = self.cfg.prefill_chunk
        if chunk <= 0 or len(prompt) <= chunk:
            return ("new", req.rid, prompt, True, req.max_new), deque()
        pieces = [prompt[i : i + chunk] for i in range(chunk, len(prompt), chunk)]
        rest = deque(
            ("chunk", req.rid, piece, i == len(pieces) - 1)
            for i, piece in enumerate(pieces)
        )
        return ("new", req.rid, prompt[:chunk], False, req.max_new), rest

    def _pick_worker(self) -> int:
        """Free-slot-load routing: most headroom wins, ties to the lowest
        worker id.  Dispatch is optimistic — the authoritative bound is
        the worker's own admission queue (its EAGAIN, our re-queue)."""
        per = self.cfg.slots // self.cfg.workers

        def headroom(w: int) -> int:
            return per + self.cfg.admission_depth - self._outstanding[w]

        return max(range(self.cfg.workers), key=lambda w: (headroom(w), -w))

    def _send(self, wid: int, msg: tuple) -> None:
        if self.channels:
            self.channels[wid].send_request(pickle.dumps(msg))
        else:  # inline: same messages, no serialization hop
            refusal = self.workers[wid].handle_request(msg)
            if refusal is not None:
                self._handle_response(pickle.dumps([refusal]))

    def _route(self) -> None:
        # new (and re-queued) requests: route by load, send first chunk.
        # Snapshot the count: an inline-mode refusal re-queues
        # synchronously, and a refused request must wait for the NEXT
        # router step (after workers have stepped), not spin here.
        for _ in range(len(self._queue)):
            req = self._queue.popleft()
            wid = self._pick_worker()
            new_msg, rest = self._plan(req)
            self._sticky[req.rid] = wid
            self._chunks[req.rid] = rest
            self._outstanding[wid] += 1
            self._send(wid, new_msg)
        # follow-up chunks: ONE per request per router step, to the sticky
        # worker — prefill traffic interleaves with decode, never bursts
        for rid in list(self._chunks):
            rest = self._chunks.get(rid)
            if rest is None or rid not in self._sticky:
                continue  # refused meanwhile: re-planned on re-dispatch
            if not rest:
                del self._chunks[rid]
                continue
            self._send(self._sticky[rid], rest.popleft())

    # -------------------------------------------------------- response plane
    def _handle_response(self, payload: bytes) -> None:
        now = time.monotonic()
        for item in pickle.loads(payload):
            if item[0] == "eagain":
                _, wid, rid = item
                self.eagain_events += 1
                self.requeues += 1
                self._outstanding[wid] -= 1
                self._sticky.pop(rid, None)
                self._chunks.pop(rid, None)  # re-plan (and re-send) everything
                with self._inflight_lock:
                    req = self._inflight.get(rid)
                if req is not None:
                    self._queue.append(req)  # re-queued, NEVER dropped
                continue
            rid, tok, done = item
            with self._inflight_lock:
                req = self._inflight.get(rid)
            if req is None:
                continue
            if req.first_token_at is None:
                req.first_token_at = now
            req.out_tokens.append(tok)
            if done:
                req.finished_at = now
                req.done_event.set()
                self.completed += 1
                wid = self._sticky.pop(rid, None)
                if wid is not None:
                    self._outstanding[wid] -= 1
                with self._inflight_lock:
                    self._inflight.pop(rid, None)

    def _flush_workers(self) -> None:
        for w, worker in enumerate(self.workers):
            if not worker.outbox:
                continue
            batch, worker.outbox = worker.outbox, []
            if self.channels:
                self.channels[w].send_response(pickle.dumps(batch))
            else:
                self._handle_response(pickle.dumps(batch))

    # -------------------------------------------- the engine's op adapter
    def execute(self, op: tuple) -> Any:
        """The fleet's half of the engine contract: one op against the
        per-worker channels (N request sources + the shared response
        source — the engine never interprets the names, this adapter
        does)."""
        kind = op[0]
        if kind == "reap":
            name = op[1].name
            if name == "response":
                return self.channels[0].response_cq.reap()
            return self.channels[int(name.split(":", 1)[1])].request_cq.reap()
        if kind == "dispatch":
            src, rec = op[1].name, op[3]
            if rec.op == "send":
                return True
            if src == "response":
                if rec.ctx == "response":  # two-sided recv consumed a pre-post
                    self.channels[0].repost("response")
                self._handle_response(rec.data)
                return True
            wid = int(src.split(":", 1)[1])
            self.channels[wid].repost("request")
            refusal = self.workers[wid].handle_request(pickle.loads(rec.data))
            if refusal is not None:
                self.channels[wid].send_response(pickle.dumps([refusal]))
            return True
        if kind == "progress":
            moved = False
            for ch in self.channels:
                moved = ch.progress() or moved
            return moved
        if kind == "poll":
            moved = False
            for ch in self.channels:
                moved = ch.poll() or moved
            return moved
        if kind == "drain_retries":
            moved = False
            for ch in self.channels:
                moved = ch.drain_retries() or moved
            return moved
        if kind == "step_trylock":
            return self._step_lock.acquire(blocking=False)
        if kind == "step_unlock":
            self._step_lock.release()
            return True
        if kind == "dev_trylock":
            return True
        return False

    def _comm_step(self) -> bool:
        if self.engine is None:
            return False
        return run_step(self.engine, self, 0)

    # ------------------------------------------------------------------ engine
    def step(self) -> bool:
        """One fleet iteration: pump the channels, route, step every
        worker's decode shard, flush token batches back."""
        self._comm_step()
        self._route()
        worked = False
        for worker in self.workers:
            worked = worker.step() or worked
        self._flush_workers()
        self._comm_step()
        self.steps += 1
        return worked

    @property
    def tokens_out(self) -> int:
        return sum(w.core.tokens_out for w in self.workers)

    def idle(self) -> bool:
        if self._queue or self._chunks or any(w.busy() for w in self.workers):
            return False
        if self._inflight:
            return False
        return not any(ch.pending_work() for ch in self.channels)

    def run_until_idle(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and self.idle():
                return

    # --------------------------------------------------------------- teardown
    def close(self) -> None:
        """Release transport resources (idempotent) — the fleet lifecycle
        leak regression cycles this 50×."""
        if self.group is not None and hasattr(self.group, "close"):
            self.group.close()
        self.channels = []
        self.engine = None
        self.group = None


# The tentpole's public name: a fleet IS its router plus the workers it
# owns — constructing one wires the whole tier up.
Fleet = Router
