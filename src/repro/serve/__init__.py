from .fleet import Fleet, FleetConfig, ModelWorker, Router
from .server import DecodeCore, InferenceServer, Request, ServeConfig

__all__ = [
    "DecodeCore",
    "Fleet",
    "FleetConfig",
    "InferenceServer",
    "ModelWorker",
    "Request",
    "Router",
    "ServeConfig",
]
