from .server import InferenceServer, Request, ServeConfig

__all__ = ["InferenceServer", "Request", "ServeConfig"]
