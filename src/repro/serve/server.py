"""Continuous-batching inference server.

vLLM-style slot scheduler on the JAX decode path: a fixed pool of ``slots``
shares one ring KV cache; requests arrive asynchronously (any thread may
submit — the paper's multithreaded-communication model applied to
serving), prefill fills a free slot, and every engine step decodes ALL
active slots in one batched ``decode_step``.  Finished sequences free
their slot immediately; new requests join between steps (continuous
batching, no head-of-line blocking).

The request queue and completion delivery run on the LCRQ completion
queues from :mod:`repro.core` — the serving engine is an AMT consumer of
the paper's runtime, with the engine loop as the progress engine.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.completion import LCRQueue
from ..models import decode_step, init_cache, prefill

__all__ = ["ServeConfig", "Request", "InferenceServer"]


@dataclass
class ServeConfig:
    slots: int = 4  # concurrent sequences (decode batch)
    context: int = 256  # KV slots per sequence
    max_prefill: int = 64  # prompt length bucket (padded)
    greedy: bool = True


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class InferenceServer:
    def __init__(self, arch: ArchConfig, params: Any, cfg: ServeConfig = ServeConfig()):
        self.arch = arch
        self.params = params
        self.cfg = cfg
        self._rid = itertools.count()
        self.queue = LCRQueue()  # incoming requests (MPMC — any thread)
        self._slots: List[Optional[Request]] = [None] * cfg.slots
        self._positions = np.zeros((cfg.slots,), np.int32)
        self._remaining = np.zeros((cfg.slots,), np.int32)
        self._last_tok = np.zeros((cfg.slots,), np.int32)
        # one shared batched cache; per-slot prefill via single-slot caches
        self.cache = init_cache(arch, cfg.slots, cfg.context)
        self._prefill_one = jax.jit(
            lambda p, b, c: prefill(p, arch, b, c), donate_argnums=(2,)
        )
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, arch, t, pos, c), donate_argnums=(3,)
        )
        self.steps = 0
        self.tokens_out = 0

    # ----------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt), max_new=max_new)
        req.submitted_at = time.monotonic()
        self.queue.push(req)
        return req

    # ----------------------------------------------------------------- engine
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            req = self.queue.pop()
            if req is None:
                return
            self._start(slot, req)

    def _start(self, slot: int, req: Request) -> None:
        cfg, arch = self.cfg, self.arch
        prompt = req.prompt[: cfg.max_prefill]
        toks = np.zeros((1, cfg.max_prefill), np.int32)
        toks[0, -len(prompt) :] = prompt  # left-pad; ring positions still 0..n
        # single-sequence prefill on a scratch cache, then splice into slot
        one = init_cache(arch, 1, cfg.context)
        batch = {"tokens": jnp.asarray(toks[:, -len(prompt) :])}
        logits, one = self._prefill_one(self.params, batch, one)

        def splice(full, piece):
            if full.ndim >= 2 and piece.shape[0] == full.shape[0]:
                # stacked leading layer dim, batch at axis 1
                return jax.lax.dynamic_update_slice_in_dim(full, piece, slot, axis=1)
            return full

        self.cache = jax.tree.map(splice, self.cache, one)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(tok)
        req.first_token_at = time.monotonic()
        self._slots[slot] = req
        self._positions[slot] = len(prompt)
        self._remaining[slot] = req.max_new - 1
        self._last_tok[slot] = tok
        self.tokens_out += 1
        if req.max_new <= 1:
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self._slots[slot]
        if req is not None:
            req.finished_at = time.monotonic()
            req.done_event.set()
        self._slots[slot] = None

    def step(self) -> bool:
        """One engine iteration: admit, batched-decode all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return False
        toks = jnp.asarray(self._last_tok[:, None])
        pos = jnp.asarray(self._positions)
        logits, self.cache = self._decode(self.params, toks, pos, self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for i in active:
            self._positions[i] += 1
            self._remaining[i] -= 1
            self._last_tok[i] = nxt[i]
            req = self._slots[i]
            req.out_tokens.append(int(nxt[i]))
            self.tokens_out += 1
            if self._remaining[i] <= 0:
                self._finish(i)
        self.steps += 1
        return True

    def run_until_idle(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and len(self.queue) == 0:
                if all(r is None for r in self._slots):
                    return
