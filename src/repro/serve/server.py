"""Continuous-batching inference server over the shared comm layer.

vLLM-style slot scheduler on the JAX decode path: a fixed pool of ``slots``
shares one ring KV cache; requests arrive asynchronously (any thread may
submit — the paper's multithreaded-communication model applied to
serving), prefill fills a free slot, and every engine step decodes ALL
active slots in one batched ``decode_step``.  Finished sequences free
their slot immediately; new requests join between steps (continuous
batching, no head-of-line blocking).

**The request/response hand-off is the repo's communication abstraction**
(ISSUE 5): with ``transport='collective'`` (the default), requests and
per-token responses travel as bytes through :class:`~repro.core.comm.
interface.CommInterface` verbs on a :class:`~repro.core.comm.collective.
CommChannel` — typed EAGAIN backpressure parks and retries under the
shared :class:`~repro.core.comm.resources.ResourceLimits`, token
completions for all active slots aggregate into ONE response message per
engine step (§2.2.2 applied to serving), and the engine loop drives the
SAME :class:`~repro.core.comm.progress.ProgressEngine` as the parcelports
(policy via ``ProgressPolicy.for_config``, exactly like ``LCIPPConfig`` /
``SimConfig``).  ``transport='inline'`` keeps the legacy direct hand-off
as the round-trip parity reference — both paths produce identical
responses for the same request stream (tests/test_executor_serve.py).

Since ISSUE 7 the slot scheduler + batched decode live in
:class:`DecodeCore`, shared verbatim between this single-host server and
the fleet's :class:`~repro.serve.fleet.ModelWorker` — the fleet shards
the slot space across workers but runs the SAME math, which is what makes
the token-stream equivalence tests exact rather than approximate.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.comm.collective import CommChannel
from ..core.comm.progress import ProgressEngine, ProgressPolicy, run_step
from ..core.comm.resources import ResourceLimits
from ..core.comm.wire import decode_msg, encode_msg
from ..models import decode_step, init_cache, prefill

__all__ = ["ServeConfig", "Request", "DecodeCore", "InferenceServer"]


@dataclass
class ServeConfig:
    slots: int = 4  # concurrent sequences (decode batch)
    context: int = 256  # KV slots per sequence
    max_prefill: int = 64  # prompt length bucket (padded)
    greedy: bool = True
    # Request/response hand-off: 'collective' rides CommInterface verbs on
    # a CollectiveComm pair driven by the shared ProgressEngine; 'shmem'
    # swaps in the true one-sided shared-memory transport (responses ride
    # put into the router-owned response queue whenever the backend's
    # Capabilities advertise one_sided_put — ISSUE 6); 'inline' is the
    # legacy direct hand-off (the parity reference in tests).
    transport: str = "collective"
    # Chunked prefill (ISSUE 7): 0 = classic single-shot prefill at
    # admission; N > 0 = prompts are consumed incrementally, interleaved
    # with decode of the other slots, and cross the fleet transport split
    # into N-token chunk messages — prefill never stalls decode.
    prefill_chunk: int = 0
    # ProgressPolicy.for_config axes — the same fields, by design, as
    # LCIPPConfig and the DES SimConfig: the serving hot path sweeps the
    # §5.3 policy ladder like any parcelport variant.
    progress_mode: str = "explicit"  # 'explicit' | 'implicit'
    lock_mode: str = "none"
    progress_workers: int = 0
    # The shared resource model (§3.3.4) bounding the hand-off channel.
    limits: ResourceLimits = field(default_factory=ResourceLimits)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


# emit(req, token, done) — one generated token leaves the model side.
EmitFn = Callable[[Request, int, bool], None]


class DecodeCore:
    """Slot scheduler + batched decode, independent of any transport.

    Owns the batched ring KV cache (``init_cache(arch, slots, context)``),
    per-slot positions / budgets, and the two jitted entry points.  The
    single-host :class:`InferenceServer` runs ONE core with ``cfg.slots``
    slots; the fleet runs N cores of ``slots // n_workers`` each.  Rows of
    the batched decode are computed independently (verified bit-exact in
    tests/test_fleet.py), so sharding the slot space across cores cannot
    change any request's token stream.

    Two admission modes:

    * **single-shot** (``prefill_chunk == 0``): the whole prompt runs
      through the jitted ``prefill`` on a scratch cache and is spliced
      into the slot — one dispatch, first token emitted at admission.
    * **chunked** (``prefill_chunk > 0``): the slot starts empty and
      consumes ONE prompt token per engine step through the same batched
      ``decode_step`` that serves the decoding slots (teacher forcing).
      Per-step work is one uniform batched decode regardless of prompt
      length — a long prompt can never stall other slots' decode.  Chunk
      arrivals may lag the consumer; a starved slot simply re-feeds its
      last token WITHOUT advancing its position, and the garbage KV row
      is overwritten when the real token arrives (the cache write is
      position-addressed), so stall timing cannot perturb the stream.
    """

    def __init__(
        self,
        arch: ArchConfig,
        params: Any,
        slots: int,
        context: int,
        max_prefill: int = 64,
        prefill_chunk: int = 0,
    ):
        self.arch, self.params = arch, params
        self.slots, self.context = slots, context
        self.max_prefill, self.prefill_chunk = max_prefill, prefill_chunk
        self._slots: List[Optional[Request]] = [None] * slots
        self._positions = np.zeros((slots,), np.int32)
        self._remaining = np.zeros((slots,), np.int32)
        self._last_tok = np.zeros((slots,), np.int32)
        # one shared batched cache; per-slot prefill via single-slot caches
        self.cache = init_cache(arch, slots, context)
        # zeroed single-slot row: splicing it in resets a recycled slot
        # (stale position tags must not leak into a new sequence)
        self._fresh_row = init_cache(arch, 1, context)
        self._prefill_one = jax.jit(
            lambda p, b, c: prefill(p, arch, b, c), donate_argnums=(2,)
        )
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, arch, t, pos, c), donate_argnums=(3,)
        )

        # ONE jitted, donated cache splice (ISSUE 7 satellite): the old
        # per-admission `jax.tree.map(splice, ...)` ran a separate
        # dynamic_update_slice dispatch per cache leaf OUTSIDE jit,
        # copying the full cache each time — admission cost grew with the
        # total slot count.  Donating the full cache lets XLA update the
        # one row in place: admission cost is now flat in `slots`
        # (pinned by test_admission_cost_flat_in_slot_count).
        def _splice(full, piece, slot):
            def leaf(f, pc):
                if f.ndim >= 2 and pc.shape[0] == f.shape[0]:
                    # stacked leading layer dim, batch at axis 1
                    return jax.lax.dynamic_update_slice_in_dim(f, pc, slot, axis=1)
                return f

            return jax.tree.map(leaf, full, piece)

        self._splice = jax.jit(_splice, donate_argnums=(0,))
        self.steps = 0
        self.tokens_out = 0
        self.prefill_calls = 0  # single-shot prefill dispatches (0 when chunked)
        # worst prompt-tokens-of-prefill-work attributed to a single engine
        # step — the burst chunked prefill exists to bound (≤ active slots
        # per step vs a whole prompt per admission single-shot)
        self.max_prefill_burst = 0
        self._pending_burst = 0  # single-shot prefill work since last step
        # chunked-prefill state: slot -> queued prompt tokens / open flag
        self._prefill_queue: Dict[int, deque] = {}
        self._prefill_open: Dict[int, bool] = {}
        self._rid_slot: Dict[int, int] = {}

    # ------------------------------------------------------------- occupancy
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    def active(self) -> bool:
        return any(r is not None for r in self._slots)

    # ------------------------------------------------------------- admission
    def admit(self, req: Request, emit: EmitFn, more_chunks: bool = False) -> int:
        """Place ``req`` into the lowest free slot.  With chunked prefill,
        ``req.prompt`` may hold only the FIRST chunk; ``more_chunks=True``
        keeps the slot in the prefilling state until :meth:`feed_chunk`
        delivers the rest.  Returns the slot index."""
        slot = self.free_slots()[0]
        if self.prefill_chunk > 0:
            prompt = req.prompt if more_chunks else req.prompt[: self.max_prefill]
            # reset the recycled row (zero KV, position tags = -1), then
            # consume the prompt one token per step through decode_step
            self.cache = self._splice(self.cache, self._fresh_row, slot)
            self._slots[slot] = req
            self._positions[slot] = 0
            self._remaining[slot] = req.max_new
            self._prefill_queue[slot] = deque(prompt)
            self._prefill_open[slot] = more_chunks
            self._rid_slot[req.rid] = slot
            return slot
        prompt = req.prompt[: self.max_prefill]
        toks = np.zeros((1, self.max_prefill), np.int32)
        toks[0, -len(prompt) :] = prompt  # left-pad; ring positions still 0..n
        # single-sequence prefill on a scratch cache, then splice into slot
        one = init_cache(self.arch, 1, self.context)
        batch = {"tokens": jnp.asarray(toks[:, -len(prompt) :])}
        logits, one = self._prefill_one(self.params, batch, one)
        self.prefill_calls += 1
        self._pending_burst += len(prompt)
        self.cache = self._splice(self.cache, one, slot)
        tok = int(jnp.argmax(logits[0, -1]))
        done = req.max_new <= 1
        self._slots[slot] = None if done else req
        self._positions[slot] = len(prompt)
        self._remaining[slot] = req.max_new - 1
        self._last_tok[slot] = tok
        self._rid_slot[req.rid] = slot
        if done:
            self._rid_slot.pop(req.rid, None)
        self.tokens_out += 1
        emit(req, tok, done)
        return slot

    def feed_chunk(self, rid: int, tokens: List[int], last: bool) -> None:
        """Append a follow-up prompt chunk for an admitted request."""
        slot = self._rid_slot[rid]
        assert self._prefill_open.get(slot), f"slot {slot} is not expecting chunks"
        self._prefill_queue[slot].extend(tokens)
        if last:
            self._prefill_open[slot] = False

    def prefilling(self, rid: int) -> bool:
        slot = self._rid_slot.get(rid)
        return slot is not None and slot in self._prefill_queue

    # ----------------------------------------------------------------- step
    def step(self, emit: EmitFn) -> bool:
        """One batched decode over all active slots.  Decoding slots
        advance one generated token; prefilling slots consume one prompt
        token (emitting their first token when the prompt is exhausted);
        starved prefilling slots hold position.  Returns False when no
        slot is active (no decode dispatched)."""
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return False
        fed: Dict[int, int] = {}  # slot -> prompt token fed this step
        for i in active:
            q = self._prefill_queue.get(i)
            if q is None:
                continue  # plain decoding slot
            if q:
                fed[i] = self._last_tok_feed(i, q.popleft())
            # else: starved mid-prefill — re-feed last token, hold position
        toks = jnp.asarray(self._last_tok[:, None])
        pos = jnp.asarray(self._positions)
        logits, self.cache = self._decode(self.params, toks, pos, self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for i in active:
            req = self._slots[i]
            if i in self._prefill_queue:
                if i not in fed:
                    continue  # starved: nothing advanced
                self._positions[i] += 1
                if self._prefill_queue[i] or self._prefill_open[i]:
                    continue  # more prompt to consume: no emission yet
                # the LAST prompt token was just fed: its logits give the
                # first generated token — the chunked analogue of the
                # single-shot prefill's argmax(logits[0, -1])
                del self._prefill_queue[i]
                del self._prefill_open[i]
            else:
                self._positions[i] += 1
            self._remaining[i] -= 1
            self._last_tok[i] = nxt[i]
            done = self._remaining[i] <= 0
            self.tokens_out += 1
            emit(req, int(nxt[i]), done)
            if done:
                self._slots[i] = None
                self._rid_slot.pop(req.rid, None)
        self.steps += 1
        burst = self._pending_burst + len(fed)
        if burst > self.max_prefill_burst:
            self.max_prefill_burst = burst
        self._pending_burst = 0
        return True

    def _last_tok_feed(self, slot: int, tok: int) -> int:
        self._last_tok[slot] = tok
        return tok

    # ------------------------------------------------ slot handoff (ISSUE 8)
    def extract_slot(self, slot: int) -> tuple:
        """Snapshot one ACTIVE slot for handoff to another core and free
        it.  Returns ``(state, meta)``: ``state`` is the slot's KV row in
        the same single-slot structure as ``_fresh_row`` (the inverse of
        ``_splice``'s leaf rule), ``meta`` the scalar scheduler state.
        The cache write is position-addressed and batched-decode rows are
        independent, so splicing these exact bits into ANY core's free
        slot continues the token stream bit-identically."""
        req = self._slots[slot]
        assert req is not None, f"slot {slot} is empty"

        def leaf(f, pc):
            if f.ndim >= 2 and pc.shape[0] == f.shape[0]:
                return jax.lax.dynamic_slice_in_dim(f, slot, 1, axis=1)
            return pc

        state = jax.tree.map(leaf, self.cache, self._fresh_row)
        meta = {
            "rid": req.rid,
            "prompt": list(req.prompt),
            "max_new": req.max_new,
            "position": int(self._positions[slot]),
            "remaining": int(self._remaining[slot]),
            "last_tok": int(self._last_tok[slot]),
            "prefill_queue": list(self._prefill_queue[slot]) if slot in self._prefill_queue else None,
            "prefill_open": bool(self._prefill_open.get(slot, False)),
        }
        self._slots[slot] = None
        self._rid_slot.pop(req.rid, None)
        self._prefill_queue.pop(slot, None)
        self._prefill_open.pop(slot, None)
        return state, meta

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is not None]

    def adopt_slot(self, state: Any, meta: Dict[str, Any], req: Optional[Request] = None) -> int:
        """Splice a handed-off slot (from :meth:`extract_slot`, possibly
        round-tripped through ``checkpoint.snapshot``) into the lowest
        free slot and resume its schedule exactly where it stopped.  Pass
        ``req`` when the caller tracks its own request object (the fleet
        worker does); emissions will carry it."""
        slot = self.free_slots()[0]
        self.cache = self._splice(self.cache, state, slot)
        if req is None:
            req = Request(rid=meta["rid"], prompt=list(meta["prompt"]), max_new=meta["max_new"])
        self._slots[slot] = req
        self._positions[slot] = meta["position"]
        self._remaining[slot] = meta["remaining"]
        self._last_tok[slot] = meta["last_tok"]
        self._rid_slot[req.rid] = slot
        if meta.get("prefill_queue") is not None:
            self._prefill_queue[slot] = deque(meta["prefill_queue"])
            self._prefill_open[slot] = meta["prefill_open"]
        return slot

    def abstract_slot_state(self) -> Any:
        """Shape/dtype reference for validating an incoming handoff
        snapshot (``unpack_state(..., abstract=...)``)."""
        return self._fresh_row


class InferenceServer:
    def __init__(self, arch: ArchConfig, params: Any, cfg: Optional[ServeConfig] = None):
        # Per-instance config: a shared mutable default (`cfg=ServeConfig()`
        # evaluated once at import) aliased every no-arg server's state.
        self.cfg = cfg = ServeConfig() if cfg is None else cfg
        self.arch = arch
        self.params = params
        self._rid = itertools.count()
        # Server-side admission queue: requests that have ARRIVED (through
        # the channel, or directly in inline mode) and await a free slot.
        self._pending: deque = deque()
        self.core = DecodeCore(
            arch, params, cfg.slots, cfg.context, cfg.max_prefill, cfg.prefill_chunk
        )
        # The comm hand-off (collective transport): channel + the SAME
        # progress engine as the parcelports, policy from this config.
        self._channel: Optional[CommChannel] = None
        self.engine: Optional[ProgressEngine] = None
        self._inflight: Dict[int, Request] = {}  # rid -> client-side Request
        self._inflight_lock = threading.Lock()
        self._outbox: List[tuple] = []  # (rid, tok, done) batch of one step
        if cfg.transport in ("collective", "shmem"):
            self._channel = CommChannel(limits=cfg.limits, backend=cfg.transport)
            # step_lock=True: the whole engine step runs behind a try-lock
            # (implemented in `execute`), so a second driver — e.g.
            # AMTExecutor(comm=server) pumping from idle workers — can
            # never interleave dispatches with the serve loop's own step.
            self.engine = ProgressEngine(
                ProgressPolicy.for_config(cfg).variant(step_lock=True),
                self._channel.router(),
                ndevices=1,
            )
            self._step_lock = threading.Lock()
        else:
            assert cfg.transport == "inline", cfg.transport

    # backwards-visible counters/state now owned by the core
    @property
    def cache(self):
        return self.core.cache

    @property
    def steps(self) -> int:
        return self.core.steps

    @property
    def tokens_out(self) -> int:
        return self.core.tokens_out

    # ----------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt), max_new=max_new)
        req.submitted_at = time.monotonic()
        if self._channel is None:
            self._pending.append(req)  # legacy direct hand-off
        else:
            with self._inflight_lock:
                self._inflight[req.rid] = req
            # the request crosses the comm layer as bytes; EAGAIN parks it
            # in the channel throttle, retried by the engine step
            self._channel.send_request(encode_msg((req.rid, req.prompt, req.max_new)))
        return req

    # -------------------------------------------- the engine's op adapter
    def execute(self, op: tuple) -> Any:
        """Execute one :class:`ProgressEngine` op against the hand-off
        channel — the serving stack's half of the engine contract (the
        exact analogue of ``LCIParcelport.execute``)."""
        kind = op[0]
        ch = self._channel
        if kind == "reap":
            return ch.reap(op[1].name)
        if kind == "dispatch":
            rec = op[3]
            if rec.op == "send":
                return True  # send completion: slot already recycled
            ch.repost(rec.ctx)  # keep the pre-post depth
            if rec.ctx == "request":
                rid, prompt, max_new = decode_msg(rec.data)
                self._pending.append(Request(rid=rid, prompt=prompt, max_new=max_new))
            else:  # response: a token batch for the client side
                self._apply_response(rec.data)
            return True
        if kind == "progress":
            return ch.progress()
        if kind == "poll":
            return ch.poll()
        if kind == "drain_retries":
            return ch.drain_retries()
        if kind == "step_trylock":
            return self._step_lock.acquire(blocking=False)
        if kind == "step_unlock":
            self._step_lock.release()
            return True
        if kind == "dev_trylock":
            return True
        return False

    def _comm_step(self) -> bool:
        """One canonical engine step over the hand-off channel (drain
        retries → progress → reap → dispatch)."""
        if self.engine is None:
            return False
        return run_step(self.engine, self, 0)

    def _apply_response(self, payload: bytes) -> None:
        """Client side: apply an arrived token batch to its requests.

        A finished request leaves ``_inflight`` only AFTER its final
        token is appended and ``done_event`` is set — ``idle()`` reads
        ``_inflight``, and must never report true while another driver
        thread is still mid-application."""
        now = time.monotonic()
        for rid, tok, done in decode_msg(payload):
            with self._inflight_lock:
                req = self._inflight.get(rid)
            if req is None:
                continue
            if req.first_token_at is None:
                req.first_token_at = now
            req.out_tokens.append(tok)
            if done:
                req.finished_at = now
                req.done_event.set()
                with self._inflight_lock:
                    self._inflight.pop(rid, None)

    def _emit(self, req: Request, tok: int, done: bool) -> None:
        """One generated token leaves the server: directly into the
        client's Request (inline), or into this step's outbound batch —
        token completions for all active slots aggregate into ONE response
        message per engine step (§2.2.2 on the serving hot path)."""
        if self._channel is None:
            now = time.monotonic()
            if req.first_token_at is None:
                req.first_token_at = now
            req.out_tokens.append(tok)
            if done:
                req.finished_at = now
                req.done_event.set()
        else:
            self._outbox.append((req.rid, tok, done))

    def _flush_outbox(self) -> bool:
        if self._channel is None or not self._outbox:
            return False
        batch, self._outbox = self._outbox, []
        self._channel.send_response(encode_msg(batch))
        return True

    # ----------------------------------------------------------------- engine
    def _admit(self) -> None:
        for _ in self.core.free_slots():
            if not self._pending:
                return
            self.core.admit(self._pending.popleft(), self._emit)

    def step(self) -> bool:
        """One engine iteration: pump the comm hand-off, admit, batched-
        decode all active slots, flush the token batch back."""
        self._comm_step()
        self._admit()
        if not self.core.step(self._emit):
            if self._flush_outbox():  # e.g. prefill-only finishes
                self._comm_step()
            return False
        self._flush_outbox()
        self._comm_step()
        return True

    # ------------------------------------------------------------- lifecycle
    def pending_requests(self) -> int:
        """Requests admitted server-side but not yet slotted."""
        return len(self._pending)

    def idle(self) -> bool:
        """Nothing slotted, nothing pending, nothing in flight on the
        hand-off channel."""
        if self.core.active() or self._pending:
            return False
        if self._channel is not None and (self._inflight or self._channel.pending_work()):
            return False
        return True

    def run_until_idle(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and self.idle():
                return
