"""PartitionSpec derivation for parameter / optimizer / cache pytrees.

Leaf specs are matched by leaf *name* on the trailing dimensions (stacked
per-layer params have a leading layer dim that is never sharded), then
resolved through the active :class:`ShardingRules`, so the same table
drives single-pod, multi-pod, and test meshes.

SSM projection matrices stay replicated in the baseline layout (their
fused [z‖x‖B‖C‖dt] output dim does not shard cleanly — see DESIGN.md;
revisited in §Perf).  Optimizer moments optionally ZeRO-shard over the
data axis: the first free dimension divisible by the data-axis size gets
"data" appended to its spec.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .logical import ShardingRules, sanitize_spec

__all__ = ["param_specs", "opt_specs", "batch_specs", "cache_specs", "tree_shardings"]

# leaf name → logical axes of the *trailing* dims
_LEAF_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("vocab", "embed"),
    "lm_head": ("vocab", "embed"),
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", "head_dim"),
    "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    "router": ("embed", "experts"),
    # MLA
    "wq_a": ("embed", "latent"),
    "wq_b": ("latent", "heads", "head_dim"),
    "wkv_a": ("embed", "latent"),
    "wk_b": ("latent", "heads", "head_dim"),
    "wv_b": ("latent", "heads", "head_dim"),
    # SSM (baseline: replicated projections — see module docstring)
    "in_proj": ("embed", None),
    "conv_w": (None, None),
    "conv_b": (None,),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "norm_w": (None,),
    "out_proj": (None, "embed"),
    # norms
    "ln1": ("embed",),
    "ln2": ("embed",),
    "ln": ("embed",),
    "ln_f": ("embed",),
    "enc_ln_f": ("embed",),
}

# MoE expert stacks: (E, D, F)/(E, F, D) keyed by path containing "moe"
_MOE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("experts", "embed", "mlp"),
    "w_up": ("experts", "embed", "mlp"),
    "w_down": ("experts", "mlp", "embed"),
}


def _leaf_spec(path, leaf, rules: ShardingRules) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf_name = names[-1]
    in_moe = "moe" in names and "shared" not in names
    table = _MOE_RULES if (in_moe and leaf_name in _MOE_RULES) else _LEAF_RULES
    logical = table.get(leaf_name)
    if logical is None:
        return P()  # unknown leaf: replicate
    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    pad = ndim - len(logical)
    full = (None,) * pad + tuple(logical)
    return rules.spec(*full)


def param_specs(params: Any, rules: ShardingRules) -> Any:
    return jax.tree_util.tree_map_with_path(lambda p, l: _leaf_spec(p, l, rules), params)


def _zero_extend(spec: P, shape, data_axes, mesh: Mesh) -> P:
    """ZeRO-1: shard the first free, divisible dim of an optimizer moment
    over the data axes."""
    dsize = 1
    for a in data_axes:
        if a in mesh.shape:
            dsize *= mesh.shape[a]
    if dsize <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        for a in e if isinstance(e, tuple) else (e,):
            used.add(a)
    if any(a in used for a in data_axes):
        return spec
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0 and dim > 0:
            entries[i] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
            return P(*entries)
    return spec


def opt_specs(
    opt_state: Any,
    params: Any,
    rules: ShardingRules,
    zero: bool = True,
    mesh: Optional[Mesh] = None,
) -> Any:
    """Moment specs = param specs, optionally ZeRO-extended over data."""
    pspecs = param_specs(params, rules)
    data_axes = rules.table.get("batch") or ()
    if isinstance(data_axes, str):
        data_axes = (data_axes,)

    def mom_specs(moments):
        if not (zero and mesh is not None and data_axes):
            return pspecs
        return jax.tree.map(
            lambda s, l: _zero_extend(s, l.shape, tuple(data_axes), mesh), pspecs, moments
        )

    return {
        "mu": mom_specs(opt_state["mu"]),
        "nu": mom_specs(opt_state["nu"]),
        "count": P(),
    }


def batch_specs(batch: Any, rules: ShardingRules) -> Any:
    def leaf(path, x):
        names = [getattr(k, "key", str(k)) for k in path]
        n = names[-1]
        nd = len(x.shape)
        if n == "positions":
            return rules.spec("batch")
        if n in ("prefix", "frames"):
            return rules.spec("batch", "seq", "embed")
        if nd == 2:
            return rules.spec("batch", "seq")
        if nd == 1:
            return rules.spec("batch")
        return rules.spec(*(["batch"] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(leaf, batch)


def cache_specs(cache: Any, rules: ShardingRules) -> Any:
    """Decode-cache specs: (L, B, S, KV, hd) KV rings, (L, B, H, P, N) SSM
    states, (L, B, K, C) conv states, (L, B, S) position tags."""

    def leaf(path, x):
        names = [getattr(k, "key", str(k)) for k in path]
        nd = len(x.shape)
        last = names[-1]
        # a batch dim of 1 (single-request long-context decode) must not
        # claim the data axes in spec dedup — it cannot shard, and letting
        # it win would starve seq_kv of those axes (the 500k cache would
        # silently replicate: caught by the §Perf HLO audit)
        batch = "batch" if (nd >= 2 and x.shape[1] > 1) else None
        if last in ("k", "v"):
            return rules.spec(None, batch, "seq_kv", "kv_heads", "head_dim")
        if last == "pos":
            return rules.spec(None, batch, "seq_kv")
        if last == "c_kv":
            return rules.spec(None, batch, "seq_kv", "latent")
        if last == "k_rope":
            return rules.spec(None, batch, "seq_kv", None)
        if last == "ssm":
            return rules.spec(None, batch, "ssm_heads", None, None)
        if last == "conv":
            return rules.spec(None, batch, None, None)
        return rules.spec(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf, cache)


def tree_shardings(mesh: Mesh, spec_tree: Any, shape_tree: Any = None) -> Any:
    """Specs → NamedShardings; with ``shape_tree`` each spec is sanitized
    against the leaf shape (input shardings need exact divisibility)."""
    if shape_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda s: isinstance(s, P)
        )
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, sanitize_spec(s, l.shape, mesh)),
        spec_tree,
        shape_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
