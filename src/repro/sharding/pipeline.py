"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Completes the parallelism matrix (DP/TP/EP/SP + **PP**): stage ``s`` on
device ``s`` along ``axis`` holds its slice of the stacked stage params;
microbatches stream through the classic GPipe schedule (stage s computes
microbatch m at step ``t = s + m``), activations hop stage→stage with
``lax.ppermute`` — XLA lowers these to one-sided ICI DMA hand-offs, the
LCI *dynamic put* analogue on the device fabric (DESIGN.md §2.3).

The multi-pod production mesh can run its "pod" axis as pipeline stages
instead of data parallelism when model depth × width exceeds one pod's
HBM: ``gpipe(stage_fn, params, micro_x, mesh, axis="pod")``.

Bubble fraction = (n_stages − 1) / (n_stages + n_micro − 1); choose
``n_micro ≫ n_stages`` as usual.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["gpipe"]


def gpipe(
    stage_fn: Callable,
    stacked_params,
    micro_x: jax.Array,  # (M, ...) microbatches, identical in/out shape
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Apply ``n_stages`` stages sequentially to each of M microbatches.

    ``stacked_params``: pytree with leading dim = n_stages (sharded over
    ``axis``); ``stage_fn(params_slice, x) -> x`` must preserve shape.
    Returns (M, ...) outputs, replicated along ``axis``.
    """
    n = mesh.shape[axis]
    m_count = micro_x.shape[0]
    steps = n + m_count - 1

    p_specs = jax.tree.map(lambda _: P(axis), stacked_params)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(p_specs, P()),
        out_specs=P(),
        check_rep=False,
    )
    def run(params_local, xs):
        # params_local: leading dim 1 (this stage's slice)
        p_here = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(xs[0])

        def step(buf, t):
            m = t - s
            active = (m >= 0) & (m < m_count)
            # stage 0 pulls a fresh microbatch; others use the handed-off buf
            fresh = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(m, 0, m_count - 1), axis=0, keepdims=False
            )
            inp = jnp.where(s == 0, fresh, buf)
            out = stage_fn(p_here, inp)
            out = jnp.where(active, out, zero)
            # hand off to the next stage (one-sided DMA on ICI)
            nxt = jax.lax.ppermute(out, axis, [(i, i + 1) for i in range(n - 1)])
            emit = jnp.where(s == n - 1, out, zero)
            return nxt, emit

        _, emits = jax.lax.scan(step, zero, jnp.arange(steps))
        # the last stage emits microbatch m at step m + n - 1
        outs = jax.lax.dynamic_slice_in_dim(emits, n - 1, m_count, axis=0)
        # replicate the result across stages (only stage n-1 holds it)
        return jax.lax.psum(outs, axis)

    return run(stacked_params, micro_x)
