"""Logical-axis sharding: model code names axes, rules map them to the mesh.

Model code annotates activations/params with *logical* axis names
(``shard(x, "batch", "seq", "embed")``).  A :class:`ShardingRules` table maps
logical names to mesh axes (or None = replicated).  Outside a rules context
(CPU smoke tests) the annotations are no-ops, so the same model code runs
everywhere — the MaxText pattern.

The default rules implement the framework's parallelism layout:

* ``batch``  → (pod, data)   — data parallelism across pods and hosts
* ``heads/kv_heads/mlp/vocab/experts`` → model — tensor/expert parallelism
* ``seq_kv`` → data for long-context decode (context parallelism), else None
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "use_rules",
    "current_rules",
    "shard",
    "logical_spec",
    "named_sharding",
    "sanitize_spec",
    "axis_size",
]


class ShardingRules:
    """Mapping: logical axis name → mesh axis (str/tuple) or None."""

    def __init__(self, table: Dict[str, Optional[object]], mesh: Optional[Mesh] = None):
        self.table = dict(table)
        self.mesh = mesh

    def spec(self, *names: Optional[str]) -> P:
        out = []
        used = set()
        for n in names:
            axis = self.table.get(n) if n is not None else None
            # one mesh axis may shard only one tensor dim
            if axis is not None:
                key = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
                if any(k in used for k in key):
                    axis = None
                else:
                    used.update(key)
            out.append(axis)
        return P(*out)

    def with_overrides(self, **kw) -> "ShardingRules":
        t = dict(self.table)
        t.update(kw)
        return ShardingRules(t, self.mesh)


DEFAULT_TABLE: Dict[str, Optional[object]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_act": None,  # sequence parallelism inside attention (set to
    # "model" when head counts don't divide the TP axis — §Perf)
    "seq_kv": None,  # long-context decode flips this to "data"
    "embed": None,
    "embed_model": "model",  # ffn/attn input dim when 2D-sharding params
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    # §Perf iteration 3 (refuted): co-sharding dispatch slots with experts
    # ("moe_tokens": "model") doubled collective volume — GSPMD inserts
    # all-gathers to undo it.  Kept as an override hook; default off.
    "moe_tokens": None,
    "head_dim": None,
    "state": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "frames": None,
    "latent": None,
    "window": None,
    "conv": None,
    "stage": None,  # pipeline stages (optional PP mode)
}

DEFAULT_RULES = ShardingRules(DEFAULT_TABLE)

_ctx = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def logical_spec(*names: Optional[str]) -> P:
    rules = current_rules()
    if rules is None:
        return P(*([None] * len(names)))
    return rules.spec(*names)


def axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(entry, 1)


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis size does not divide the dim —
    XLA input shardings require exact divisibility; non-divisible dims
    replicate (e.g. 28 query heads or 4 KV heads on a 16-way model axis)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        out.append(e if (e is None or (dim % axis_size(mesh, e) == 0 and dim > 0)) else None)
    return P(*out)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate ``x`` with the mesh sharding for the given logical axes.
    No-op outside a rules context (single-device tests)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = sanitize_spec(rules.spec(*names), x.shape, rules.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def named_sharding(mesh: Mesh, *names: Optional[str], rules: Optional[ShardingRules] = None) -> NamedSharding:
    r = rules or current_rules() or DEFAULT_RULES
    return NamedSharding(mesh, r.spec(*names))
