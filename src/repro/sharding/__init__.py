from .logical import (
    DEFAULT_RULES,
    ShardingRules,
    current_rules,
    logical_spec,
    named_sharding,
    shard,
    use_rules,
)

__all__ = [
    "DEFAULT_RULES",
    "ShardingRules",
    "current_rules",
    "logical_spec",
    "named_sharding",
    "shard",
    "use_rules",
]
