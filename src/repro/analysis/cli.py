"""``repro-analyze`` / ``tools/analyze.py`` — run the analysis passes.

Exit code is 0 iff every finding is covered by the reviewed baseline
(``tools/analysis_baseline.json``).  Stale baseline entries (fingerprint
no longer produced) are *warnings*, not failures — a fixed violation
should not break CI, it should prompt a baseline cleanup.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .registry import AnalysisContext, all_passes, load_baseline, run_passes, split_findings


def _default_root() -> Path:
    # src/repro/analysis/cli.py -> repo root
    return Path(__file__).resolve().parents[3]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-analyze",
        description="concurrency static analysis: lock order, blocking-under-lock, "
        "PostStatus usage, capability dominance, thread ownership, plus the "
        "eight ported check_api gates",
    )
    ap.add_argument("--root", type=Path, default=None, help="repo root (default: autodetect)")
    ap.add_argument("--list", action="store_true", help="list registered passes and exit")
    ap.add_argument(
        "-p", "--pass", dest="passes", action="append", metavar="ID",
        help="run only this pass (repeatable; default: all)",
    )
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write findings as JSON to PATH")
    ap.add_argument("--baseline", type=Path, default=None, metavar="PATH",
                    help="reviewed allowlist (default: <root>/tools/analysis_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything as new)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any non-baselined finding (CI mode; "
                    "this is also the default behavior, the flag documents intent)")
    args = ap.parse_args(argv)

    if args.list:
        for spec in sorted(all_passes().values(), key=lambda s: s.pass_id):
            print(f"{spec.pass_id:24s} {spec.title}")
        return 0

    root = args.root or _default_root()
    ctx = AnalysisContext.for_repo(root)
    try:
        findings = run_passes(ctx, args.passes)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2

    baseline_path = args.baseline or (root / "tools" / "analysis_baseline.json")
    baseline = {} if args.no_baseline else load_baseline(baseline_path)
    new, accepted, stale = split_findings(findings, baseline)

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            {
                "new": [f.to_json() for f in new],
                "baselined": [f.to_json() for f in accepted],
                "stale_baseline": stale,
            },
            indent=2,
        ) + "\n")

    for f in new:
        loc = f"{f.file}:{f.line}" if f.file else "<runtime>"
        print(f"FINDING [{f.pass_id}] {loc}: {f.message}")
        for step in f.witness:
            print(f"    | {step}")
        print(f"    fingerprint: {f.fingerprint}")
    for fp in stale:
        print(f"warning: stale baseline entry (no longer produced): {fp}")
    n_pass = len(args.passes) if args.passes else len(all_passes())
    print(
        f"analyze: {n_pass} pass(es), {len(new)} new finding(s), "
        f"{len(accepted)} baselined, {len(stale)} stale baseline entr(y/ies)"
    )
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
