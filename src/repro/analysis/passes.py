"""The five concurrency passes (ISSUE 10 tentpole).

All five ride the same facts + call graph; lock-order and
blocking-under-lock additionally share ONE inter-procedural lock model
(:class:`LockModel`): a linear held-set walk per function (with-blocks,
``.acquire()``/``.release()``, leak/release summaries for split
acquire/release helpers like ``LCIDevice._acquire``), plus memoized
transitive summaries (which locks a callee may acquire, which blocking
calls it may reach) with witness chains.

Documented under-approximations: unresolved calls (lambdas handed to
throttles, duck-typed ``Any`` receivers) contribute no edges; nested
``def``/``lambda`` bodies are deferred execution and are not walked as
part of the enclosing function; branches merge held-sets by union
(may-hold analysis).
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, List, Optional, Set, Tuple

from .callgraph import CallGraph, callee_name
from .facts import FunctionFacts, ModuleFacts
from .registry import AnalysisContext, Finding, analysis_pass

__all__ = [
    "LockModel",
    "get_lock_model",
    "BLOCKING_CALLS",
    "POST_STATUS_VERBS",
    "LOCK_SCOPE",
]

#: the sub-trees the lock passes police (paper §5.3: the communication
#: layer's progress/completion discipline)
LOCK_SCOPE = (
    "src/repro/core/",
    "src/repro/serve/",
    "src/repro/amtsim/",
)

#: call names that can block or take unbounded library time while the
#: caller sits on a lock (§5.3: "blocking under a lock is catastrophic").
#: ``join``/``wait`` with an explicit timeout argument are exempt.
BLOCKING_CALLS = {
    "sleep",
    "join",
    "wait",
    "device_put",
    "post_send",
    "post_put_signal",
    "post_put",
    "progress",
    "poll",
    "poll_cq",
    "hw_progress",
    "reap",
    "run_step",
}
_TIMEOUT_EXEMPT = {"join", "wait"}

#: CommInterface verbs returning a PostStatus the caller must observe
#: (``post_recv`` returns None and ``progress``/``poll`` return a moved
#: flag, so only the posting verbs carry a refusable EAGAIN)
POST_STATUS_VERBS = {"post_send", "post_put_signal", "post_put"}


def _loc(mod: ModuleFacts, line: int) -> str:
    return f"{mod.path or mod.name}:{line}"


def _timeout_exempt(call: ast.Call, name: str) -> bool:
    if name not in _TIMEOUT_EXEMPT:
        return False
    if call.args:
        return True
    return any(kw.arg == "timeout" for kw in call.keywords)


# ======================================================== lock model walker
class _Direct:
    """Per-function direct summary from one held-set walk."""

    __slots__ = ("acquires", "leaked", "released_extra", "blocking", "calls")

    def __init__(self) -> None:
        self.acquires: Dict[str, int] = {}  # lock id -> first line
        self.leaked: Dict[str, int] = {}  # held at end of linear walk
        self.released_extra: Set[str] = set()  # released without acquiring
        self.blocking: List[Tuple[str, int]] = []  # (name, line)
        self.calls: List[Tuple[int, FunctionFacts]] = []  # resolved only


class LockModel:
    def __init__(self, ctx: AnalysisContext):
        self.ctx = ctx
        self.graph: CallGraph = ctx.graph
        self._direct: Dict[str, _Direct] = {}
        self._building: Set[str] = set()
        self._trans_acq: Dict[str, Dict[str, List[str]]] = {}
        self._trans_blk: Dict[str, List[Tuple[str, List[str]]]] = {}
        self._mod_of: Dict[str, ModuleFacts] = {}
        for mod in ctx.modules.values():
            for ff in mod.functions.values():
                self._mod_of[ff.qualid] = mod

    def module_of(self, ff: FunctionFacts) -> ModuleFacts:
        return self._mod_of[ff.qualid]

    # ----------------------------------------------------------- the walker
    def walk(
        self,
        ff: FunctionFacts,
        on_acquire: Optional[Callable[[str, int, Tuple[Tuple[str, int], ...]], None]] = None,
        on_call: Optional[
            Callable[[ast.Call, List[FunctionFacts], Tuple[Tuple[str, int], ...]], None]
        ] = None,
    ) -> _Direct:
        """One linear held-set walk of ``ff``.  Callbacks see the held
        set *before* the event.  Returns the direct summary of the walk
        (also used to build leak/release effect summaries)."""
        mod = self.module_of(ff)
        graph = self.graph
        direct = _Direct()
        held: List[Tuple[str, int]] = []

        def emit_acquire(lid: str, line: int) -> None:
            if on_acquire:
                on_acquire(lid, line, tuple(held))
            direct.acquires.setdefault(lid, line)
            held.append((lid, line))

        def emit_release(lid: str) -> None:
            for i in range(len(held) - 1, -1, -1):
                if held[i][0] == lid:
                    del held[i]
                    return
            direct.released_extra.add(lid)

        def handle_call(call: ast.Call) -> None:
            func = call.func
            if isinstance(func, ast.Attribute) and func.attr in ("acquire", "release"):
                lid = graph.lock_id(func.value, ff, mod)
                if lid is not None:
                    if func.attr == "acquire":
                        emit_acquire(lid, call.lineno)
                    else:
                        emit_release(lid)
                    return
            name = callee_name(func)
            if name in BLOCKING_CALLS and not _timeout_exempt(call, name):
                direct.blocking.append((name, call.lineno))
            targets = graph.resolve_call(call, ff, mod)
            for t in targets:
                direct.calls.append((call.lineno, t))
            if on_call:
                on_call(call, targets, tuple(held))
            # apply callee leak/release effects (split acquire helpers)
            for t in targets:
                if t.qualid == ff.qualid:
                    continue
                eff = self.direct(t)
                for lid in eff.leaked:
                    emit_acquire(lid, call.lineno)
                for lid in eff.released_extra:
                    emit_release(lid)

        def scan_expr(node: Optional[ast.AST]) -> None:
            if node is None:
                return
            stack: List[ast.AST] = [node]
            calls: List[ast.Call] = []
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    continue  # deferred execution
                if isinstance(n, ast.Call):
                    calls.append(n)
                stack.extend(ast.iter_child_nodes(n))
            for c in reversed(calls):  # roughly source order
                handle_call(c)

        def merge_from(snapshot: List[Tuple[str, int]]) -> None:
            have = {l for l, _ in held}
            for entry in snapshot:
                if entry[0] not in have:
                    held.append(entry)

        def terminates(stmts: List[ast.stmt]) -> bool:
            """Whether control cannot fall off the end of this block — a
            branch that returns must not leak its held-set into the
            fall-through path (the try-acquire-then-return idiom)."""
            return bool(stmts) and isinstance(
                stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue)
            )

        def do_body(stmts: List[ast.stmt]) -> None:
            for s in stmts:
                do_stmt(s)

        def do_stmt(s: ast.stmt) -> None:
            if isinstance(s, (ast.With, ast.AsyncWith)):
                entered = []
                for item in s.items:
                    scan_expr(item.context_expr)
                    lid = graph.lock_id(item.context_expr, ff, mod)
                    if lid is not None:
                        emit_acquire(lid, s.lineno)
                        entered.append(lid)
                do_body(s.body)
                for lid in reversed(entered):
                    emit_release(lid)
            elif isinstance(s, ast.If):
                scan_expr(s.test)
                snap = list(held)
                do_body(s.body)
                after_body = list(held)
                body_term = terminates(s.body)
                held[:] = snap
                do_body(s.orelse)
                orelse_term = bool(s.orelse) and terminates(s.orelse)
                if body_term and not orelse_term:
                    pass  # fall-through comes only from the else path
                elif orelse_term and not body_term:
                    held[:] = after_body
                elif not body_term:
                    merge_from(after_body)
            elif isinstance(s, (ast.For, ast.AsyncFor)):
                scan_expr(s.iter)
                snap = list(held)
                do_body(s.body)
                merge_from(snap)
                do_body(s.orelse)
            elif isinstance(s, ast.While):
                scan_expr(s.test)
                snap = list(held)
                do_body(s.body)
                merge_from(snap)
                do_body(s.orelse)
            elif isinstance(s, ast.Try):
                do_body(s.body)
                for h in s.handlers:
                    do_body(h.body)
                do_body(s.orelse)
                do_body(s.finalbody)
            elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                pass  # nested definitions: deferred execution
            else:
                scan_expr(s)

        do_body(ff.node.body)
        for lid, line in held:
            direct.leaked.setdefault(lid, line)
        return direct

    # ------------------------------------------------------ direct summaries
    def direct(self, ff: FunctionFacts) -> _Direct:
        qid = ff.qualid
        cached = self._direct.get(qid)
        if cached is not None:
            return cached
        if qid in self._building:  # recursion cycle: empty effects
            return _Direct()
        self._building.add(qid)
        try:
            summary = self.walk(ff)
        finally:
            self._building.discard(qid)
        self._direct[qid] = summary
        return summary

    # --------------------------------------------------- transitive closures
    def trans_acquires(self, ff: FunctionFacts, _stack: Optional[Set[str]] = None) -> Dict[str, List[str]]:
        """lock id -> witness chain (``file:line qualname`` steps) for
        every lock ``ff`` may acquire, transitively."""
        qid = ff.qualid
        if qid in self._trans_acq:
            return self._trans_acq[qid]
        stack = _stack if _stack is not None else set()
        if qid in stack:
            return {}
        stack.add(qid)
        mod = self.module_of(ff)
        d = self.direct(ff)
        out: Dict[str, List[str]] = {}
        for lid, line in d.acquires.items():
            out.setdefault(lid, [f"{_loc(mod, line)} {ff.qualname} acquires {lid}"])
        for line, callee in d.calls:
            for lid, chain in self.trans_acquires(callee, stack).items():
                if lid not in out and len(chain) < 6:
                    out[lid] = [f"{_loc(mod, line)} {ff.qualname} calls {callee.qualname}"] + chain
        stack.discard(qid)
        self._trans_acq[qid] = out
        return out

    def trans_blocking(self, ff: FunctionFacts, _stack: Optional[Set[str]] = None) -> List[Tuple[str, List[str]]]:
        """(blocking-call name, witness chain) for every blocking call
        ``ff`` may reach, transitively (one representative per name)."""
        qid = ff.qualid
        if qid in self._trans_blk:
            return self._trans_blk[qid]
        stack = _stack if _stack is not None else set()
        if qid in stack:
            return []
        stack.add(qid)
        mod = self.module_of(ff)
        d = self.direct(ff)
        out: Dict[str, List[str]] = {}
        for name, line in d.blocking:
            out.setdefault(name, [f"{_loc(mod, line)} {ff.qualname} calls {name}()"])
        for line, callee in d.calls:
            for name, chain in self.trans_blocking(callee, stack):
                if name not in out and len(chain) < 6:
                    out[name] = [f"{_loc(mod, line)} {ff.qualname} calls {callee.qualname}"] + chain
        stack.discard(qid)
        result = sorted(out.items())
        self._trans_blk[qid] = result
        return result


def get_lock_model(ctx: AnalysisContext) -> LockModel:
    return ctx.extra("lock_model", lambda: LockModel(ctx))


# ============================================================ pass 1: order
@analysis_pass("lock-order", "inter-procedural lock-acquisition graph: fail on cycles")
def lock_order(ctx: AnalysisContext) -> List[Finding]:
    model = get_lock_model(ctx)
    edges: Dict[Tuple[str, str], Dict[str, object]] = {}

    for mod, ff in ctx.iter_functions(LOCK_SCOPE):

        def on_acquire(lid, line, held, mod=mod, ff=ff):
            for h, _hl in held:
                edges.setdefault(
                    (h, lid),
                    {
                        "file": mod.path or mod.name,
                        "line": line,
                        "witness": f"{_loc(mod, line)} {ff.qualname} acquires {lid} while holding {h}",
                    },
                )

        def on_call(call, targets, held, mod=mod, ff=ff):
            if not held:
                return
            for t in targets:
                for lid, chain in model.trans_acquires(t).items():
                    for h, _hl in held:
                        edges.setdefault(
                            (h, lid),
                            {
                                "file": mod.path or mod.name,
                                "line": call.lineno,
                                "witness": f"{_loc(mod, call.lineno)} {ff.qualname} (holding {h}) -> "
                                + " -> ".join(chain),
                            },
                        )

        model.walk(ff, on_acquire, on_call)

    findings: List[Finding] = []
    # self-loops: re-acquiring a non-reentrant lock identity while held
    adj: Dict[str, Set[str]] = {}
    for (a, b), info in edges.items():
        if a == b:
            findings.append(
                Finding(
                    pass_id="lock-order",
                    file=str(info["file"]),
                    line=int(info["line"]),  # type: ignore[arg-type]
                    message=f"lock {a} re-acquired while already held (non-reentrant; "
                    "or hand-over-hand across instances of one class without a total order)",
                    key=f"self-cycle:{a}",
                    witness=(str(info["witness"]),),
                )
            )
        else:
            adj.setdefault(a, set()).add(b)

    # cycle detection: DFS with colors, report each cycle once
    WHITE, GRAY, BLACK = 0, 1, 2
    color: Dict[str, int] = {n: WHITE for n in set(adj) | {b for bs in adj.values() for b in bs}}
    path: List[str] = []
    reported: Set[Tuple[str, ...]] = set()

    def dfs(n: str) -> None:
        color[n] = GRAY
        path.append(n)
        for m in sorted(adj.get(n, ())):
            if color[m] == GRAY:
                cyc = path[path.index(m) :] + [m]
                locks = cyc[:-1]
                rot = locks.index(min(locks))
                canon = tuple(locks[rot:] + locks[:rot])
                if canon in reported:
                    continue
                reported.add(canon)
                witness = tuple(
                    str(edges[(cyc[i], cyc[i + 1])]["witness"]) for i in range(len(cyc) - 1)
                )
                info = edges[(cyc[0], cyc[1])]
                findings.append(
                    Finding(
                        pass_id="lock-order",
                        file=str(info["file"]),
                        line=int(info["line"]),  # type: ignore[arg-type]
                        message="lock-order cycle (potential deadlock): "
                        + " -> ".join(cyc),
                        key="cycle:" + "->".join(canon),
                        witness=witness,
                    )
                )
            elif color[m] == WHITE:
                dfs(m)
        path.pop()
        color[n] = BLACK

    for n in sorted(color):
        if color[n] == WHITE:
            dfs(n)
    return findings


# ======================================================= pass 2: blocking
@analysis_pass("blocking-under-lock", "no blocking/unbounded call while holding a lock")
def blocking_under_lock(ctx: AnalysisContext) -> List[Finding]:
    model = get_lock_model(ctx)
    findings: List[Finding] = []
    seen: Set[str] = set()

    def add(f: Finding) -> None:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            findings.append(f)

    for mod, ff in ctx.iter_functions(LOCK_SCOPE):

        def on_call(call, targets, held, mod=mod, ff=ff):
            if not held:
                return
            locks = ",".join(sorted({h for h, _ in held}))
            name = callee_name(call.func)
            if name in BLOCKING_CALLS and not _timeout_exempt(call, name):
                add(
                    Finding(
                        pass_id="blocking-under-lock",
                        file=mod.path or mod.name,
                        line=call.lineno,
                        message=f"{ff.qualname} calls {name}() while holding [{locks}] "
                        "— a blocked holder starves every peer on the lock (§5.3)",
                        key=f"{ff.qualname}:{name}:{locks}",
                        witness=(f"{_loc(mod, call.lineno)} {ff.qualname} holds [{locks}]",),
                    )
                )
                return
            for t in targets:
                blk = model.trans_blocking(t)
                if not blk:
                    continue
                bname, chain = blk[0]
                add(
                    Finding(
                        pass_id="blocking-under-lock",
                        file=mod.path or mod.name,
                        line=call.lineno,
                        message=f"{ff.qualname} holds [{locks}] across a call to "
                        f"{t.qualname}, which can reach {bname}()",
                        key=f"{ff.qualname}->{t.qualname}:{bname}:{locks}",
                        witness=tuple(
                            [f"{_loc(mod, call.lineno)} {ff.qualname} holds [{locks}]"] + chain
                        ),
                    )
                )

        model.walk(ff, None, on_call)
    return findings


# =================================================== pass 3: PostStatus
@analysis_pass("unchecked-post-status", "every posting verb's PostStatus must be observed")
def unchecked_post_status(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []

    def visit(body: List[ast.stmt], mod: ModuleFacts, qual: str) -> None:
        for s in body:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                inner = f"{qual}.{s.name}" if qual else s.name
                visit(s.body, mod, inner)
                continue
            if isinstance(s, ast.Expr) and isinstance(s.value, ast.Call):
                name = callee_name(s.value.func)
                if name in POST_STATUS_VERBS:
                    findings.append(
                        Finding(
                            pass_id="unchecked-post-status",
                            file=mod.path or mod.name,
                            line=s.lineno,
                            message=f"{qual or mod.name}: return value of {name}() discarded "
                            "— an unobserved EAGAIN is a silently dropped parcel",
                            key=f"{qual}:{name}",
                        )
                    )
            for sub in ast.iter_child_nodes(s):
                if isinstance(sub, ast.stmt):
                    pass
            # recurse into nested statement bodies (if/for/while/with/try)
            for field_name in ("body", "orelse", "finalbody"):
                nested = getattr(s, field_name, None)
                if isinstance(nested, list) and nested and isinstance(nested[0], ast.stmt):
                    visit(nested, mod, qual)
            for h in getattr(s, "handlers", []) or []:
                visit(h.body, mod, qual)

    for mod in ctx.modules.values():
        visit(mod.tree.body, mod, "")
    return findings


# ============================================== pass 4: capability dominance
_CAP_ALLOW = ("src/repro/core/comm/", "src/repro/core/device.py", "src/repro/core/mpi_sim.py")
_BACKENDS = ("LCIDevice", "ShmemComm", "ShmemDevice", "CollectiveComm", "MPISim")


def _taint_polarity(test: ast.AST, tainted: Set[str]) -> Optional[str]:
    """'pos' if the branch test asserts a capability-derived truth at top
    level, 'neg' if it asserts its negation, None if the test never
    mentions the taint."""

    def mentions(n: ast.AST) -> bool:
        for sub in ast.walk(n):
            if isinstance(sub, ast.Attribute) and (
                sub.attr in tainted or sub.attr == "one_sided_put"
            ):
                return True
            if isinstance(sub, ast.Name) and sub.id in tainted:
                return True
        return False

    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return "neg" if mentions(test.operand) else None
    if isinstance(test, ast.BoolOp):
        for v in test.values:
            p = _taint_polarity(v, tainted)
            if p is not None:
                return p
        return None
    return "pos" if mentions(test) else None


@analysis_pass("capability-dominance", "every put site dominated by a one_sided_put check")
def capability_dominance(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules.values():
        path = mod.path or ""
        if path.startswith("src/repro/") and any(
            path.startswith(a) or path == a for a in _CAP_ALLOW
        ):
            continue
        tainted: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and any(
                isinstance(n, ast.Attribute) and n.attr == "one_sided_put"
                for n in ast.walk(node.value)
            ):
                for tgt in node.targets:
                    if (
                        isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"
                    ):
                        tainted.add(tgt.attr)
                    elif isinstance(tgt, ast.Name):
                        tainted.add(tgt.id)

        put_sites: List[Tuple[ast.Call, bool, str]] = []  # (call, dominated, qual)

        def scan_expr(node: ast.AST, guards: List[Tuple[ast.AST, str]], qual: str) -> None:
            if isinstance(node, ast.IfExp):
                scan_expr(node.test, guards, qual)
                scan_expr(node.body, guards + [(node.test, "body")], qual)
                scan_expr(node.orelse, guards + [(node.test, "orelse")], qual)
                return
            if isinstance(node, ast.Call):
                if callee_name(node.func) == "post_put_signal":
                    dominated = any(
                        (_taint_polarity(t, tainted) == "pos" and br == "body")
                        or (_taint_polarity(t, tainted) == "neg" and br == "orelse")
                        for t, br in guards
                    )
                    put_sites.append((node, dominated, qual))
            for child in ast.iter_child_nodes(node):
                scan_expr(child, guards, qual)

        def visit(body: List[ast.stmt], guards: List[Tuple[ast.AST, str]], qual: str) -> None:
            for s in body:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                    visit(s.body, guards, f"{qual}.{s.name}" if qual else s.name)
                elif isinstance(s, ast.If):
                    scan_expr(s.test, guards, qual)
                    visit(s.body, guards + [(s.test, "body")], qual)
                    visit(s.orelse, guards + [(s.test, "orelse")], qual)
                elif isinstance(s, (ast.For, ast.AsyncFor, ast.While)):
                    for attr in ("iter", "test"):
                        sub = getattr(s, attr, None)
                        if sub is not None:
                            scan_expr(sub, guards, qual)
                    visit(s.body, guards, qual)
                    visit(s.orelse, guards, qual)
                elif isinstance(s, (ast.With, ast.AsyncWith)):
                    for item in s.items:
                        scan_expr(item.context_expr, guards, qual)
                    visit(s.body, guards, qual)
                elif isinstance(s, ast.Try):
                    visit(s.body, guards, qual)
                    for h in s.handlers:
                        visit(h.body, guards, qual)
                    visit(s.orelse, guards, qual)
                    visit(s.finalbody, guards, qual)
                else:
                    scan_expr(s, guards, qual)

        visit(mod.tree.body, [], "")
        for call, dominated, qual in put_sites:
            if not dominated:
                findings.append(
                    Finding(
                        pass_id="capability-dominance",
                        file=mod.path or mod.name,
                        line=call.lineno,
                        message=f"{qual or mod.name}: post_put_signal() not dominated by a "
                        "one_sided_put capability check — the put path must be selected "
                        "from the advertised Capabilities (§2.3)",
                        key=f"{qual}:undominated-put",
                    )
                )
    return findings


# =============================================== pass 5: thread ownership
_THREAD_EXEMPT = ("core/comm/membership.py", "launch/serve.py")


@analysis_pass("thread-ownership", "worker threads spawn only via the membership nursery")
def thread_ownership(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules.values():
        path = mod.path or mod.name
        if any(path.endswith(e) for e in _THREAD_EXEMPT):
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and ctx.graph.resolves_to(node, mod, "threading.Thread"):
                findings.append(
                    Finding(
                        pass_id="thread-ownership",
                        file=path,
                        line=node.lineno,
                        message=f"{path}: spawns a raw threading.Thread — worker lifecycle "
                        "belongs to membership.spawn_worker / ProgressWorkerPool "
                        "(the census must see every worker)",
                        key=f"raw-thread:{node.lineno // 1000}",  # near-stable bucket
                    )
                )

    # callgraph-backed wiring: the big thread consumers must ride the nursery
    def calls_into(mod: ModuleFacts, target_suffix: str) -> bool:
        for ff in mod.functions.values():
            for node in ast.walk(ff.node):
                if isinstance(node, ast.Call):
                    for t in ctx.graph.resolve_call(node, ff, mod):
                        if t.qualid.endswith(target_suffix):
                            return True
        return False

    def references(mod: ModuleFacts, name: str) -> bool:
        if any(t.rsplit(".", 1)[-1] == name for t in mod.import_aliases.values()):
            return True
        return any(
            isinstance(n, (ast.Name,)) and n.id == name for n in ast.walk(mod.tree)
        )

    executor = ctx.module_at("core/executor.py")
    if executor is not None:
        for needle in ("membership:spawn_worker", "membership:join_workers"):
            if not calls_into(executor, needle):
                findings.append(
                    Finding(
                        pass_id="thread-ownership",
                        file=executor.path or executor.name,
                        line=1,
                        message=f"core/executor.py: no resolved call to {needle.split(':')[1]} "
                        "— worker threads must go through the one nursery",
                        key=f"missing:{needle}",
                    )
                )
    lci_pp = ctx.module_at("core/lci_parcelport.py")
    if lci_pp is not None and not references(lci_pp, "ProgressWorkerPool"):
        findings.append(
            Finding(
                pass_id="thread-ownership",
                file=lci_pp.path or lci_pp.name,
                line=1,
                message="core/lci_parcelport.py: does not use membership.ProgressWorkerPool "
                "— dedicated progress threads must come from the pool",
                key="missing:ProgressWorkerPool",
            )
        )
    return findings
