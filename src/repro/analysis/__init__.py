"""repro.analysis — the concurrency static-analysis subsystem (ISSUE 10).

Two halves, one discipline:

* **Static passes** (:mod:`.facts`, :mod:`.callgraph`, :mod:`.passes`,
  :mod:`.gates`, :mod:`.registry`) — one cached AST walk per module feeds
  a pass registry: a lock-order/deadlock analyzer, the blocking-under-lock
  lint (the paper's §5.3 "blocking under a lock is catastrophic" result as
  a machine-checked rule), the unchecked-``PostStatus`` lint (an ignored
  EAGAIN is a silently dropped parcel), a capability-dominance dataflow
  pass, a thread-ownership pass, and AST ports of all eight legacy
  ``tools/check_api.py`` gates.
* **Runtime sanitizer** (:mod:`.sanitizer`) — an Eraser-style lockset
  checker (``REPRO_SANITIZE=1``) that dynamically witnesses what the
  static passes claim: shared structures (completion rings, send rings,
  slab state bytes, the membership table) carry a candidate lockset that
  is intersected on every cross-thread access; an empty lockset on a
  shared mutation is a race report.

This module keeps imports lazy so the hot path — core modules importing
:func:`sanitizer.make_lock` — never pays for the static machinery.
"""
from __future__ import annotations

__all__ = [
    "facts",
    "callgraph",
    "registry",
    "passes",
    "gates",
    "sanitizer",
    "cli",
]


def __getattr__(name: str):
    if name in __all__:
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
