"""Eraser-style lockset race sanitizer (the runtime half of ISSUE 10).

Enabled by ``REPRO_SANITIZE=1`` (or programmatically via :func:`enable`).
When disabled — the default — :func:`make_lock` returns a plain
``threading.Lock`` and every ``note_*`` hook returns immediately, so the
instrumented hot paths pay one truthiness check.

When enabled:

* :func:`make_lock` returns a :class:`SanLock` that records, per thread,
  the set of tracked locks currently held.
* :func:`note_access` runs the classic Eraser state machine per shared
  location (``virgin → exclusive → shared``): the location's *candidate
  lockset* is intersected with the locks held at each access once a
  second thread shows up; an empty candidate set on a shared **write** is
  a race report (the discipline the static passes assume — every shared
  structure has ONE lock that all its writers hold).
* :func:`note_exercise` counts operations on deliberately lock-free
  structures (the LCRQ fast path) without lockset checking — they are
  *exercised*, proving the sanitizer leg actually drove them, but their
  correctness argument is the FAA/tombstone protocol, not a lockset.

Reports carry the structure name, the racing threads, and the access
site (``file:line`` of the caller) so a report is actionable without a
debugger.  :func:`session_report` is the one-call summary the test leg
asserts on.
"""
from __future__ import annotations

import os
import sys
import threading
from typing import Any, Dict, FrozenSet, List, Optional, Set, Tuple

__all__ = [
    "enabled",
    "enable",
    "make_lock",
    "SanLock",
    "note_access",
    "note_exercise",
    "race_reports",
    "exercised_structures",
    "reset",
    "session_report",
]

_ENABLED = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")

_tls = threading.local()


def _held() -> Set["SanLock"]:
    try:
        return _tls.held
    except AttributeError:
        _tls.held = set()
        return _tls.held


def enabled() -> bool:
    return _ENABLED


def enable(on: bool = True) -> None:
    """Flip the sanitizer (tests).  Only structures *constructed after*
    enabling get tracked locks — enable before building the world."""
    global _ENABLED
    _ENABLED = on


class SanLock:
    """A ``threading.Lock`` that maintains the per-thread held set.

    Duck-types the small surface the repo uses: ``acquire`` / ``release``
    / context manager / ``locked``.  Non-reentrant, like the primitive it
    wraps."""

    __slots__ = ("_lock", "name")

    def __init__(self, name: str):
        self._lock = threading.Lock()
        self.name = name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            _held().add(self)
        return ok

    def release(self) -> None:
        _held().discard(self)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "SanLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SanLock({self.name!r})"


def make_lock(name: str):
    """The lock constructor instrumented modules use: a plain
    ``threading.Lock`` normally, a tracked :class:`SanLock` under
    ``REPRO_SANITIZE=1``.  The static passes treat both as lock
    constructors."""
    if _ENABLED:
        return SanLock(name)
    return threading.Lock()


# ------------------------------------------------------------- state machine
_VIRGIN, _EXCLUSIVE, _SHARED = 0, 1, 2


class _Shadow:
    __slots__ = ("state", "owner", "lockset", "threads", "accesses", "reported")

    def __init__(self) -> None:
        self.state = _VIRGIN
        self.owner: Optional[int] = None
        self.lockset: Optional[FrozenSet[str]] = None
        self.threads: Set[int] = set()
        self.accesses = 0
        self.reported = False


_reg_lock = threading.Lock()
_shadows: Dict[Tuple[str, int], _Shadow] = {}
_exercised: Dict[str, int] = {}
_reports: List[Dict[str, Any]] = []


def _caller_site(depth: int = 2) -> str:
    try:
        frame = sys._getframe(depth)
        return f"{os.path.basename(frame.f_code.co_filename)}:{frame.f_lineno}"
    except Exception:  # pragma: no cover - platform without _getframe
        return "<unknown>"


def note_access(struct: str, inst: int = 0, write: bool = True) -> None:
    """Record one access to shared location ``(struct, inst)``.

    ``struct`` is the structure name (aggregation key for reports, e.g.
    ``"Membership._members"``); ``inst`` distinguishes instances (pass
    ``id(self)``)."""
    if not _ENABLED:
        return
    tid = threading.get_ident()
    held = frozenset(l.name for l in _held())
    with _reg_lock:
        sh = _shadows.setdefault((struct, inst), _Shadow())
        sh.accesses += 1
        sh.threads.add(tid)
        if sh.state == _VIRGIN:
            sh.state = _EXCLUSIVE
            sh.owner = tid
            sh.lockset = held
            return
        if sh.state == _EXCLUSIVE and sh.owner == tid:
            # still single-threaded: keep the most recent candidate set
            sh.lockset = held
            return
        sh.state = _SHARED
        sh.lockset = (sh.lockset or frozenset()) & held
        if not sh.lockset and write and not sh.reported:
            sh.reported = True
            _reports.append(
                {
                    "struct": struct,
                    "instance": inst,
                    "threads": sorted(sh.threads),
                    "site": _caller_site(),
                    "message": (
                        f"lockset race: {struct} written by {len(sh.threads)} threads "
                        f"with no common lock (at {_caller_site()})"
                    ),
                }
            )


def note_exercise(struct: str, inst: int = 0) -> None:
    """Count one operation on a deliberately lock-free structure."""
    if not _ENABLED:
        return
    with _reg_lock:
        _exercised[struct] = _exercised.get(struct, 0) + 1


def race_reports() -> List[Dict[str, Any]]:
    with _reg_lock:
        return list(_reports)


def exercised_structures() -> Dict[str, int]:
    """Structures the sanitizer actually saw traffic on: every lockset-
    checked shadow location (by structure name) plus the lock-free
    exercise counters."""
    with _reg_lock:
        out = dict(_exercised)
        for (struct, _inst), sh in _shadows.items():
            out[struct] = out.get(struct, 0) + sh.accesses
        return out


def reset() -> None:
    with _reg_lock:
        _shadows.clear()
        _exercised.clear()
        _reports.clear()


def session_report() -> Dict[str, Any]:
    """The one-call summary the sanitizer test leg asserts on."""
    return {
        "enabled": _ENABLED,
        "races": race_reports(),
        "exercised": exercised_structures(),
    }
