"""Pass registry, analysis context, findings, and the reviewed baseline.

A pass is ``fn(ctx) -> list[Finding]`` registered under a stable id.
Findings carry a **line-number-free fingerprint** (pass id + file +
rule-specific key) so the reviewed baseline in
``tools/analysis_baseline.json`` survives unrelated edits: moving a
function does not invalidate its baseline entry; changing the violation
itself does.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

from .callgraph import CallGraph
from .facts import GLOBAL_CACHE, ModuleFacts

__all__ = [
    "Finding",
    "PassSpec",
    "analysis_pass",
    "all_passes",
    "run_passes",
    "AnalysisContext",
    "load_baseline",
    "split_findings",
]


@dataclass
class Finding:
    pass_id: str
    file: str  # repo-relative posix path ("" for runtime gates)
    line: int
    message: str
    key: str  # stable rule-specific detail (NO line numbers)
    witness: Tuple[str, ...] = ()

    @property
    def fingerprint(self) -> str:
        return f"{self.pass_id}:{self.file}:{self.key}"

    def to_json(self) -> Dict[str, object]:
        return {
            "pass": self.pass_id,
            "file": self.file,
            "line": self.line,
            "message": self.message,
            "fingerprint": self.fingerprint,
            "witness": list(self.witness),
        }


@dataclass
class PassSpec:
    pass_id: str
    title: str
    fn: Callable[["AnalysisContext"], List[Finding]]


_REGISTRY: Dict[str, PassSpec] = {}


def analysis_pass(pass_id: str, title: str):
    """Register an analysis pass (decorator)."""

    def deco(fn: Callable[["AnalysisContext"], List[Finding]]):
        _REGISTRY[pass_id] = PassSpec(pass_id, title, fn)
        return fn

    return deco


def all_passes() -> Dict[str, PassSpec]:
    # importing the pass modules populates the registry
    from . import passes as _passes  # noqa: F401
    from . import gates as _gates  # noqa: F401

    return dict(_REGISTRY)


def run_passes(
    ctx: "AnalysisContext", pass_ids: Optional[Iterable[str]] = None
) -> List[Finding]:
    specs = all_passes()
    if pass_ids is None:
        selected = list(specs.values())
    else:
        unknown = [p for p in pass_ids if p not in specs]
        if unknown:
            raise KeyError(f"unknown pass(es): {', '.join(unknown)}")
        selected = [specs[p] for p in pass_ids]
    findings: List[Finding] = []
    for spec in selected:
        findings.extend(spec.fn(ctx))
    return findings


# ------------------------------------------------------------------ context
class AnalysisContext:
    """Everything a pass needs: the per-module facts (one cached walk
    each), the call graph, and lazily-attached shared models (the lock
    model hangs itself here so lock-order and blocking-under-lock share
    one inter-procedural walk)."""

    def __init__(self, root: Path, modules: Dict[str, ModuleFacts]):
        self.root = root
        self.modules = modules
        self.graph = CallGraph(modules)
        self._extras: Dict[str, object] = {}

    # shared-model slot (used by passes.get_lock_model)
    def extra(self, key: str, build: Callable[[], object]) -> object:
        if key not in self._extras:
            self._extras[key] = build()
        return self._extras[key]

    # ------------------------------------------------------------ builders
    @classmethod
    def for_repo(cls, root: Path) -> "AnalysisContext":
        """All of ``src/repro`` except the analysis package itself (the
        system under analysis, not the analyzer)."""
        src = root / "src"
        modules: Dict[str, ModuleFacts] = {}
        for path in sorted((src / "repro").rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel.startswith("src/repro/analysis/"):
                continue
            name = ".".join(path.relative_to(src).with_suffix("").parts)
            if name.endswith(".__init__"):
                name = name[: -len(".__init__")]
            modules[name] = GLOBAL_CACHE.get(path, name, rel)
        return cls(root, modules)

    @classmethod
    def from_sources(cls, sources: Dict[str, str], root: Optional[Path] = None) -> "AnalysisContext":
        """Fixture contexts for tests: ``{relpath: source}``."""
        modules: Dict[str, ModuleFacts] = {}
        for rel, src in sources.items():
            name = rel[:-3].replace("/", ".") if rel.endswith(".py") else rel.replace("/", ".")
            modules[name] = ModuleFacts.from_source(src, name, rel)
        return cls(root or Path("."), modules)

    # ------------------------------------------------------------- queries
    def module_at(self, path_suffix: str) -> Optional[ModuleFacts]:
        for mod in self.modules.values():
            if mod.path and mod.path.endswith(path_suffix):
                return mod
        return None

    def iter_functions(
        self, path_prefixes: Optional[Tuple[str, ...]] = None
    ) -> Iterator[Tuple[ModuleFacts, "object"]]:
        """(module, FunctionFacts) pairs, optionally restricted to repo
        sub-trees.  Fixture modules (paths outside ``src/repro``) are
        always included so tests can run passes on synthetic trees."""
        for mod in self.modules.values():
            if path_prefixes is not None and mod.path and mod.path.startswith("src/repro/"):
                if not any(mod.path.startswith(p) for p in path_prefixes):
                    continue
            for ff in mod.functions.values():
                yield mod, ff


# ----------------------------------------------------------------- baseline
def load_baseline(path: Optional[Path]) -> Dict[str, str]:
    """``{fingerprint: reason}`` from the reviewed allowlist file."""
    if path is None or not path.exists():
        return {}
    data = json.loads(path.read_text())
    out: Dict[str, str] = {}
    for entry in data.get("entries", []):
        out[entry["fingerprint"]] = entry.get("reason", "")
    return out


def split_findings(
    findings: List[Finding], baseline: Dict[str, str]
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new, baselined, stale-baseline-fingerprints)."""
    new: List[Finding] = []
    accepted: List[Finding] = []
    seen = set()
    for f in findings:
        seen.add(f.fingerprint)
        (accepted if f.fingerprint in baseline else new).append(f)
    stale = [fp for fp in baseline if fp not in seen]
    return new, accepted, stale
