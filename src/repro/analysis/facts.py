"""Shared AST facts: ONE cached tree walk per module (ISSUE 10 tentpole).

Every pass consumes the same :class:`ModuleFacts` — import-alias map,
class table (base names, ``self.attr`` type hints, lock-bearing
attributes), function table (parameter/return annotations, simple local
assignments) — so adding a pass never adds another parse.  Facts are
deliberately *syntactic and resolvable*, not a type system: the
call-graph layer (:mod:`.callgraph`) only follows edges it can resolve
with confidence, and every pass documents what the under-approximation
misses.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

__all__ = [
    "ModuleFacts",
    "ClassFacts",
    "FunctionFacts",
    "FactsCache",
    "LOCK_CONSTRUCTORS",
    "ann_name",
]

#: constructor names whose result is a mutex the passes track.  Both the
#: raw primitives and the sanitizer's :func:`~repro.analysis.sanitizer.
#: make_lock` wrapper count, so instrumenting a module never blinds the
#: static side.
LOCK_CONSTRUCTORS = {"Lock", "RLock", "make_lock"}


def ann_name(node: Optional[ast.AST]) -> Optional[str]:
    """The class name an annotation denotes (best-effort, string
    annotations included): ``Fabric``, ``"Fabric"``, ``Optional[Fabric]``,
    ``mod.Fabric`` all resolve to ``"Fabric"``."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        text = node.value.strip().strip("'\"")
        try:
            return ann_name(ast.parse(text, mode="eval").body)
        except SyntaxError:
            return None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):
        base = ann_name(node.value)
        if base in ("Optional", "optional"):
            return ann_name(node.slice)
        return None
    return None


def _callee_name(func: ast.AST) -> Optional[str]:
    """Terminal name of a call target: ``Lock`` for both ``Lock(...)``
    and ``threading.Lock(...)``."""
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


@dataclass
class FunctionFacts:
    """One function or method: the raw node plus resolution hints."""

    name: str  # bare name
    qualname: str  # "fn" or "Class.fn"
    module: str  # dotted module name
    node: ast.AST = field(repr=False)
    class_name: Optional[str] = None
    param_types: Dict[str, str] = field(default_factory=dict)
    return_type: Optional[str] = None
    #: simple ``name = <expr>`` local assignments (last one wins) — the
    #: callgraph chases these for receiver-type inference
    local_assigns: Dict[str, ast.expr] = field(default_factory=dict, repr=False)

    @property
    def qualid(self) -> str:
        return f"{self.module}:{self.qualname}"


@dataclass
class ClassFacts:
    name: str
    module: str
    base_names: List[str] = field(default_factory=list)
    methods: Dict[str, FunctionFacts] = field(default_factory=dict)
    #: ``self.attr`` → class-name hint (constructor call, annotated
    #: parameter assignment, or annotated attribute)
    attr_types: Dict[str, str] = field(default_factory=dict)
    #: attributes assigned a lock constructor (``threading.Lock()``,
    #: ``make_lock(...)``) anywhere in the class
    lock_attrs: Set[str] = field(default_factory=set)


@dataclass
class ModuleFacts:
    name: str  # dotted, e.g. "repro.core.fabric"
    path: Optional[str]  # repo-relative posix path, None for fixtures
    tree: ast.Module = field(repr=False)
    #: local name → fully dotted import target
    import_aliases: Dict[str, str] = field(default_factory=dict)
    classes: Dict[str, ClassFacts] = field(default_factory=dict)
    functions: Dict[str, FunctionFacts] = field(default_factory=dict)
    #: module-level names assigned a lock constructor
    module_locks: Set[str] = field(default_factory=set)

    # ------------------------------------------------------------- builders
    @classmethod
    def from_source(cls, source: str, name: str, path: Optional[str] = None) -> "ModuleFacts":
        tree = ast.parse(source)
        facts = cls(name=name, path=path, tree=tree)
        facts._collect()
        return facts

    @classmethod
    def from_path(cls, file_path: Path, name: str, rel: str) -> "ModuleFacts":
        return cls.from_source(file_path.read_text(), name, rel)

    # ------------------------------------------------------------ collection
    def _collect(self) -> None:
        self._collect_imports()
        for node in self.tree.body:
            if isinstance(node, ast.ClassDef):
                self._collect_class(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ff = self._function_facts(node, class_name=None)
                self.functions[ff.qualname] = ff
            elif isinstance(node, ast.Assign) and self._is_lock_ctor(node.value):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.module_locks.add(tgt.id)

    def _collect_imports(self) -> None:
        pkg_parts = self.name.split(".")[:-1]
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                    mod = ".".join(base + ([node.module] if node.module else []))
                else:
                    mod = node.module or ""
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.import_aliases[a.asname or a.name] = f"{mod}.{a.name}"

    def _is_lock_ctor(self, value: ast.AST) -> bool:
        if not isinstance(value, ast.Call):
            return False
        return _callee_name(value.func) in LOCK_CONSTRUCTORS

    def _collect_class(self, node: ast.ClassDef) -> None:
        cf = ClassFacts(name=node.name, module=self.name)
        for b in node.bases:
            bname = _callee_name(b) if not isinstance(b, ast.Name) else b.id
            if bname:
                cf.base_names.append(bname)
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ff = self._function_facts(item, class_name=node.name)
                cf.methods[item.name] = ff
                self.functions[ff.qualname] = ff
                self._collect_self_attrs(item, ff, cf)
            elif isinstance(item, ast.AnnAssign) and isinstance(item.target, ast.Name):
                t = ann_name(item.annotation)
                if t:
                    cf.attr_types[item.target.id] = t
        self.classes[node.name] = cf

    def _collect_self_attrs(self, method: ast.AST, ff: FunctionFacts, cf: ClassFacts) -> None:
        for node in ast.walk(method):
            target: Optional[ast.Attribute] = None
            value: Optional[ast.expr] = None
            annotation: Optional[ast.AST] = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                if isinstance(node.targets[0], ast.Attribute):
                    target, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Attribute):
                target, value, annotation = node.target, node.value, node.annotation
            if target is None or not (
                isinstance(target.value, ast.Name) and target.value.id == "self"
            ):
                continue
            attr = target.attr
            if value is not None and self._is_lock_ctor(value):
                cf.lock_attrs.add(attr)
                continue
            hint = ann_name(annotation) if annotation is not None else None
            if hint is None and isinstance(value, ast.Call):
                hint = _callee_name(value.func)
            if hint is None and isinstance(value, ast.Name):
                hint = ff.param_types.get(value.id)
            if hint and attr not in cf.attr_types:
                cf.attr_types[attr] = hint

    def _function_facts(self, node: ast.AST, class_name: Optional[str]) -> FunctionFacts:
        qual = f"{class_name}.{node.name}" if class_name else node.name
        ff = FunctionFacts(
            name=node.name,
            qualname=qual,
            module=self.name,
            node=node,
            class_name=class_name,
        )
        args = node.args
        for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
            t = ann_name(a.annotation)
            if t:
                ff.param_types[a.arg] = t
        ff.return_type = ann_name(node.returns)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                tgt = sub.targets[0]
                if isinstance(tgt, ast.Name):
                    ff.local_assigns[tgt.id] = sub.value
            elif isinstance(sub, ast.AnnAssign) and isinstance(sub.target, ast.Name):
                t = ann_name(sub.annotation)
                if t:
                    ff.param_types.setdefault(sub.target.id, t)
        return ff


class FactsCache:
    """Path-keyed cache: one parse + fact walk per (path, mtime)."""

    def __init__(self) -> None:
        self._cache: Dict[str, Tuple[float, ModuleFacts]] = {}

    def get(self, file_path: Path, name: str, rel: str) -> ModuleFacts:
        key = str(file_path)
        mtime = file_path.stat().st_mtime
        hit = self._cache.get(key)
        if hit is not None and hit[0] == mtime:
            return hit[1]
        facts = ModuleFacts.from_path(file_path, name, rel)
        self._cache[key] = (mtime, facts)
        return facts


#: process-wide cache shared by the CLI, the check_api shim, and tests
GLOBAL_CACHE = FactsCache()
