"""Confident-edge call graph + lock identity over the shared facts.

Resolution follows only edges it can justify (documented
under-approximation — an unresolved call contributes nothing, it never
guesses):

* bare names → same-module functions, alias-resolved imports of analyzed
  modules, class constructors;
* ``self.m()`` → the enclosing class and its resolvable bases;
* ``obj.m()`` → receiver type inferred from parameter/attribute
  annotations, ``self.x = ClassName(...)`` constructor assignments,
  simple local assignments, and annotated return types (all collected in
  one facts walk);
* ``module.fn()`` → alias-resolved module attribute.

Lock identity is class-granular: ``(OwnerClass, attr)`` — ``self._lock``
inside ``Membership`` is ``Membership._lock``.  Two *instances* of the
same class share an identity, which deliberately over-approximates:
nested acquisition across instances of one class is flagged, exactly the
hand-over-hand pattern a non-total order makes deadlock-prone.  An
attribute whose receiver type cannot be inferred resolves only when a
single analyzed class defines that attribute as a lock.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .facts import FunctionFacts, ClassFacts, ModuleFacts, ann_name

__all__ = ["CallGraph", "callee_name"]


def callee_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class CallGraph:
    def __init__(self, modules: Dict[str, ModuleFacts]):
        self.modules = modules
        self.class_index: Dict[str, List[ClassFacts]] = {}
        self.lock_attr_owners: Dict[str, List[ClassFacts]] = {}
        for mod in modules.values():
            for cf in mod.classes.values():
                self.class_index.setdefault(cf.name, []).append(cf)
                for attr in cf.lock_attrs:
                    self.lock_attr_owners.setdefault(attr, []).append(cf)

    # ------------------------------------------------------ class resolution
    def resolve_class(self, name: Optional[str], mod: Optional[ModuleFacts]) -> Optional[ClassFacts]:
        if not name:
            return None
        if mod is not None:
            cf = mod.classes.get(name)
            if cf is not None:
                return cf
            target = mod.import_aliases.get(name)
            if target:
                owner, _, obj = target.rpartition(".")
                owner_mod = self.modules.get(owner)
                if owner_mod is not None:
                    return owner_mod.classes.get(obj)
                name = obj  # fall through to the unique-global lookup
        candidates = self.class_index.get(name, [])
        if len(candidates) == 1:
            return candidates[0]
        return None

    def mro(self, cf: ClassFacts) -> List[ClassFacts]:
        """Linearized base chain (BFS over resolvable bases)."""
        out, seen, frontier = [], set(), [cf]
        while frontier:
            c = frontier.pop(0)
            key = (c.module, c.name)
            if key in seen:
                continue
            seen.add(key)
            out.append(c)
            cmod = self.modules.get(c.module)
            for b in c.base_names:
                bcf = self.resolve_class(b, cmod)
                if bcf is not None:
                    frontier.append(bcf)
        return out

    # -------------------------------------------------------- type inference
    def infer_type(
        self, expr: ast.AST, ff: FunctionFacts, mod: ModuleFacts, depth: int = 0
    ) -> Optional[Tuple[str, ClassFacts]]:
        """Best-effort receiver type: ``("instance", cls)`` for a value of
        that class, ``("class", cls)`` for a reference to the class object
        itself, None when unsure."""
        if depth > 5:
            return None
        if isinstance(expr, ast.Name):
            if expr.id == "self" and ff.class_name:
                cf = self.resolve_class(ff.class_name, mod)
                return ("instance", cf) if cf else None
            t = ff.param_types.get(expr.id)
            if t:
                cf = self.resolve_class(t, mod)
                if cf:
                    return ("instance", cf)
            rhs = ff.local_assigns.get(expr.id)
            if rhs is not None and not (isinstance(rhs, ast.Name) and rhs.id == expr.id):
                inferred = self.infer_type(rhs, ff, mod, depth + 1)
                if inferred:
                    return inferred
            cf = self.resolve_class(expr.id, mod)
            if cf:
                return ("class", cf)
            return None
        if isinstance(expr, ast.Attribute):
            base = self.infer_type(expr.value, ff, mod, depth + 1)
            if base and base[0] == "instance":
                for c in self.mro(base[1]):
                    hint = c.attr_types.get(expr.attr)
                    if hint:
                        cf = self.resolve_class(hint, self.modules.get(c.module))
                        if cf:
                            return ("instance", cf)
                        return None
            return None
        if isinstance(expr, ast.Call):
            if isinstance(expr.func, ast.Name):
                cf = self.resolve_class(expr.func.id, mod)
                if cf:
                    return ("instance", cf)
            for fn in self.resolve_call(expr, ff, mod, depth + 1):
                if fn.return_type:
                    cf = self.resolve_class(fn.return_type, self.modules.get(fn.module))
                    if cf:
                        return ("instance", cf)
            return None
        if isinstance(expr, ast.Await):
            return self.infer_type(expr.value, ff, mod, depth + 1)
        return None

    # -------------------------------------------------------- call resolution
    def resolve_call(
        self, call: ast.Call, ff: FunctionFacts, mod: ModuleFacts, depth: int = 0
    ) -> List[FunctionFacts]:
        if depth > 6:
            return []
        func = call.func
        if isinstance(func, ast.Name):
            n = func.id
            local = mod.functions.get(n)
            if local is not None and local.class_name is None:
                return [local]
            cf = mod.classes.get(n)
            if cf is not None:
                init = cf.methods.get("__init__")
                return [init] if init else []
            target = mod.import_aliases.get(n)
            if target:
                owner, _, obj = target.rpartition(".")
                owner_mod = self.modules.get(owner)
                if owner_mod is not None:
                    f = owner_mod.functions.get(obj)
                    if f is not None:
                        return [f]
                    cf = owner_mod.classes.get(obj)
                    if cf is not None:
                        init = cf.methods.get("__init__")
                        return [init] if init else []
            return []
        if isinstance(func, ast.Attribute):
            m = func.attr
            base = self.infer_type(func.value, ff, mod, depth + 1)
            if base is not None and base[1] is not None:
                for c in self.mro(base[1]):
                    if m in c.methods:
                        return [c.methods[m]]
                return []
            if isinstance(func.value, ast.Name):
                target = mod.import_aliases.get(func.value.id)
                if target:
                    owner_mod = self.modules.get(target)
                    if owner_mod is not None:
                        f = owner_mod.functions.get(m)
                        if f is not None and f.class_name is None:
                            return [f]
            return []
        return []

    # ---------------------------------------------------------- lock identity
    def lock_id(self, expr: ast.AST, ff: FunctionFacts, mod: ModuleFacts) -> Optional[str]:
        """Canonical lock identity for a context-manager / ``.acquire()``
        base expression, or None if it is not a recognized lock."""
        if isinstance(expr, ast.Name):
            if expr.id in mod.module_locks:
                return f"{mod.name.rsplit('.', 1)[-1]}.{expr.id}"
            rhs = ff.local_assigns.get(expr.id)
            if isinstance(rhs, ast.Attribute):
                return self.lock_id(rhs, ff, mod)
            return None
        if not isinstance(expr, ast.Attribute):
            return None
        attr = expr.attr
        base = self.infer_type(expr.value, ff, mod)
        if base is not None and base[1] is not None:
            for c in self.mro(base[1]):
                if attr in c.lock_attrs:
                    return f"{c.name}.{attr}"
            return None
        owners = {c.name for c in self.lock_attr_owners.get(attr, [])}
        if len(owners) == 1:
            return f"{owners.pop()}.{attr}"
        return None

    # --------------------------------------------------------- name targeting
    def resolves_to(self, call: ast.Call, mod: ModuleFacts, full_name: str) -> bool:
        """Whether ``call`` targets the fully-dotted ``full_name`` (e.g.
        ``threading.Thread``), via direct use or any import alias."""
        owner, _, obj = full_name.rpartition(".")
        func = call.func
        if isinstance(func, ast.Name):
            return mod.import_aliases.get(func.id) == full_name
        if isinstance(func, ast.Attribute) and func.attr == obj:
            if isinstance(func.value, ast.Name):
                return mod.import_aliases.get(func.value.id) == owner
        return False
