"""The eight ``check_api`` gates, ported onto the shared analysis
infrastructure (ISSUE 10).

Gates 1–3 stay *runtime* checks (they probe live dataclasses — mirrored
fields, shared ``limits`` identity, delegate wiring — which no AST can
see); they import lazily and skip cleanly on fixture contexts.  Gates
4–8 become AST passes over the shared :class:`ModuleFacts`, which fixes
the two fragilities the old line-greps had:

* **aliased imports** — ``from ..completion import LCRQueue as Q; Q()``
  and ``from ..device import LCIDevice as Dev; isinstance(x, Dev)`` now
  resolve through the per-module import-alias map;
* **multi-line calls** — the AST sees one ``Call`` node no matter how
  the formatter wrapped it, where ``"isinstance(" in line`` looked at
  one physical line and missed the type argument on the next.

``tools/check_api.py`` is now a thin shim over these passes that keeps
its historical function names and output contract.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .facts import ModuleFacts
from .registry import AnalysisContext, Finding, analysis_pass

__all__ = ["BACKEND_NAMES"]

BACKEND_NAMES = ("LCIDevice", "ShmemComm", "ShmemDevice", "CollectiveComm", "MPISim")


# ------------------------------------------------------------------ helpers
def _find(pass_id: str, mod_or_file, line: int, message: str, key: str) -> Finding:
    file = mod_or_file if isinstance(mod_or_file, str) else (mod_or_file.path or mod_or_file.name)
    return Finding(pass_id=pass_id, file=file, line=line, message=message, key=key)


def _identifier_used(mod: ModuleFacts, name: str) -> bool:
    """Whether ``name`` appears as an identifier anywhere in the module:
    a bare name, an attribute, a def, or an import target."""
    if name in mod.import_aliases:
        return True
    if any(t.rsplit(".", 1)[-1] == name for t in mod.import_aliases.values()):
        return True
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Name) and node.id == name:
            return True
        if isinstance(node, ast.Attribute) and node.attr == name:
            return True
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)) and node.name == name:
            return True
    return False


def _resolved_name(expr: ast.AST, mod: ModuleFacts) -> Optional[str]:
    """The terminal class name an expression denotes, chasing import
    aliases: ``Dev`` (``from x import LCIDevice as Dev``) → ``LCIDevice``;
    ``device.LCIDevice`` → ``LCIDevice``."""
    if isinstance(expr, ast.Name):
        target = mod.import_aliases.get(expr.id)
        if target:
            return target.rsplit(".", 1)[-1]
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    return None


def _attr_calls(mod: ModuleFacts, attr: str) -> Iterable[ast.Call]:
    for node in ast.walk(mod.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == attr
        ):
            yield node


def _is_real_repo(ctx: AnalysisContext) -> bool:
    """Runtime gates only make sense against the actual repo (fixture
    contexts built from synthetic sources skip them)."""
    return ctx.module_at("core/comm/resources.py") is not None


def _runtime_api(ctx: AnalysisContext):
    """Import the live config surface once per context (gates 1–3 share
    it).  Returns the module tuple or an error string."""

    def build():
        import sys
        from pathlib import Path

        sys.path.insert(0, str(ctx.root / "src"))
        try:
            from repro.amtsim.parcelport_sim import SimConfig, sim_config_for_variant
            from repro.core.comm.resources import ResourceLimits
            from repro.core.fabric import Fabric
            from repro.core.lci_parcelport import LCIPPConfig
            from repro.core.variants import VARIANTS
        except Exception as exc:  # pragma: no cover - environment-dependent
            return f"import failed: {exc}"
        return (SimConfig, sim_config_for_variant, ResourceLimits, Fabric, LCIPPConfig, VARIANTS)

    return ctx.extra("runtime_api", build)


# ======================================================= gates 1–3 (runtime)
@analysis_pass("gate-resource-mirror", "no config dataclass re-grows ResourceLimits fields")
def gate_resource_mirror(ctx: AnalysisContext) -> List[Finding]:
    if not _is_real_repo(ctx):
        return []
    api = _runtime_api(ctx)
    if isinstance(api, str):
        return [_find("gate-resource-mirror", "", 0, api, "import-failed")]
    SimConfig, _, ResourceLimits, _, LCIPPConfig, _ = api
    out: List[Finding] = []
    limit_fields = {f.name for f in dataclasses.fields(ResourceLimits)}
    for cfg_cls in (SimConfig, LCIPPConfig):
        dup = limit_fields & {f.name for f in dataclasses.fields(cfg_cls)}
        if dup:
            out.append(
                _find(
                    "gate-resource-mirror",
                    "",
                    0,
                    f"{cfg_cls.__name__} duplicates ResourceLimits fields {sorted(dup)} "
                    "(use the shared `limits` object, not mirrored fields)",
                    f"mirror:{cfg_cls.__name__}",
                )
            )
    return out


@analysis_pass("gate-resource-shared", "every layer consumes the ONE ResourceLimits object")
def gate_resource_shared(ctx: AnalysisContext) -> List[Finding]:
    if not _is_real_repo(ctx):
        return []
    api = _runtime_api(ctx)
    if isinstance(api, str):
        return [_find("gate-resource-shared", "", 0, api, "import-failed")]
    SimConfig, sim_config_for_variant, ResourceLimits, Fabric, LCIPPConfig, VARIANTS = api
    out: List[Finding] = []
    for cfg_cls in (SimConfig, LCIPPConfig):
        names = {f.name for f in dataclasses.fields(cfg_cls)}
        if "limits" not in names:
            out.append(
                _find("gate-resource-shared", "", 0,
                      f"{cfg_cls.__name__} has no `limits: ResourceLimits` field",
                      f"no-limits:{cfg_cls.__name__}")
            )
        elif not isinstance(cfg_cls().limits, ResourceLimits):
            out.append(
                _find("gate-resource-shared", "", 0,
                      f"{cfg_cls.__name__}().limits is not a ResourceLimits",
                      f"bad-limits:{cfg_cls.__name__}")
            )
    lim = ResourceLimits(send_queue_depth=3, bounce_buffers=2, bounce_buffer_size=4096)
    fab = Fabric(2, limits=lim)
    if getattr(fab, "limits", None) is not lim:
        out.append(
            _find("gate-resource-shared", "", 0,
                  "Fabric does not expose the ResourceLimits it was built with",
                  "fabric-limits")
        )
    if fab.device(0).send_queue_depth != 3:
        out.append(
            _find("gate-resource-shared", "", 0,
                  "Fabric devices ignore limits.send_queue_depth", "fabric-depth")
        )
    try:
        functional = VARIANTS["lci_b8"].limits
        des = sim_config_for_variant("lci_b8").limits
        if functional != des:
            out.append(
                _find("gate-resource-shared", "", 0,
                      f"lci_b8: functional limits {functional} != DES limits {des} "
                      "(the two layers drifted)", "lci_b8-drift")
            )
    except KeyError:
        out.append(
            _find("gate-resource-shared", "", 0,
                  "parameterized family member lci_b8 failed to resolve", "lci_b8-missing")
        )
    return out


@analysis_pass("gate-resource-delegates", "legacy knob names read through to the shared limits")
def gate_resource_delegates(ctx: AnalysisContext) -> List[Finding]:
    if not _is_real_repo(ctx):
        return []
    api = _runtime_api(ctx)
    if isinstance(api, str):
        return [_find("gate-resource-delegates", "", 0, api, "import-failed")]
    SimConfig, _, ResourceLimits, _, LCIPPConfig, _ = api
    out: List[Finding] = []
    probe = SimConfig(limits=ResourceLimits(send_queue_depth=7, bounce_buffers=5,
                                            bounce_buffer_size=1234, retry_budget=9,
                                            recv_slots=6))
    for knob, want in (("send_queue_depth", 7), ("bounce_buffers", 5),
                       ("bounce_buffer_size", 1234), ("retry_budget", 9),
                       ("recv_slots", 6)):
        if getattr(probe, knob, None) != want:
            out.append(
                _find("gate-resource-delegates", "", 0,
                      f"SimConfig.{knob} does not delegate to limits.{knob}",
                      f"sim-delegate:{knob}")
            )
    if LCIPPConfig(limits=ResourceLimits(retry_budget=3)).retry_budget != 3:
        out.append(
            _find("gate-resource-delegates", "", 0,
                  "LCIPPConfig.retry_budget does not delegate to limits.retry_budget",
                  "lcipp-delegate:retry_budget")
        )
    return out


# ========================================================= gate 4 (engine)
_POLL_CQ_ALLOWED = ("core/fabric.py", "core/device.py")
_DES_FORBIDDEN = ("_lci_background_work", "_mpi_background_work", "_progress_device")


@analysis_pass("gate-progress-engine", "completions reaped only by the ONE ProgressEngine")
def gate_progress_engine(ctx: AnalysisContext) -> List[Finding]:
    out: List[Finding] = []
    pid = "gate-progress-engine"
    # 4a. poll_cq stays behind the CommInterface progress verb
    for mod in ctx.modules.values():
        path = mod.path or mod.name
        if any(path.endswith(a) for a in _POLL_CQ_ALLOWED):
            continue
        for call in _attr_calls(mod, "poll_cq"):
            out.append(
                _find(pid, mod, call.lineno,
                      f"{path}: calls poll_cq — the hardware reap verb belongs to "
                      "the engine's backend adapters only", "poll_cq")
            )
            break  # one finding per module, like the old gate
    # 4b. both functional parcelports drive the ONE engine
    for suffix, cls_name in (("core/lci_parcelport.py", "LCIParcelport"),
                             ("core/mpi_parcelport.py", "MPIParcelport")):
        mod = ctx.module_at(suffix)
        if mod is None:
            continue
        cf = mod.classes.get(cls_name)
        if cf is not None:
            bw = None
            for c in ctx.graph.mro(cf):
                if "background_work" in c.methods:
                    bw = c.methods["background_work"]
                    break
            if bw is not None and not any(
                isinstance(n, ast.Call)
                and (isinstance(n.func, ast.Attribute) and n.func.attr == "run_step"
                     or isinstance(n.func, ast.Name) and n.func.id == "run_step")
                for n in ast.walk(bw.node)
            ):
                out.append(
                    _find(pid, mod, bw.node.lineno,
                          f"{cls_name}.background_work does not call the shared engine "
                          "(run_step) — private progress loop re-grown?",
                          f"thin-bw:{cls_name}")
                )
        if not _identifier_used(mod, "ProgressEngine"):
            out.append(
                _find(pid, mod, 1,
                      f"{mod.path}: does not import the shared ProgressEngine",
                      "no-engine-import")
            )
        for call in _attr_calls(mod, "drain"):
            out.append(
                _find(pid, mod, call.lineno,
                      f"{mod.path}: drains a completion queue directly — reaping "
                      "belongs to the engine's reap op", "drain")
            )
            break
    # 4c. the DES has no backend-specific background-work generators
    sim = ctx.module_at("amtsim/parcelport_sim.py")
    if sim is not None:
        if not _identifier_used(sim, "ProgressEngine"):
            out.append(
                _find(pid, sim, 1,
                      "parcelport_sim.py does not import the shared ProgressEngine",
                      "des-no-engine")
            )
        for forbidden in _DES_FORBIDDEN:
            if _identifier_used(sim, forbidden):
                out.append(
                    _find(pid, sim, 1,
                          f"parcelport_sim.py re-grew {forbidden} — the DES must drive "
                          "the shared engine, not duplicate its loop",
                          f"des-regrown:{forbidden}")
                )
        call_sites = [
            n for n in ast.walk(sim.tree)
            if isinstance(n, ast.Call)
            and (isinstance(n.func, ast.Attribute) and n.func.attr == "_handle_completion"
                 or isinstance(n.func, ast.Name) and n.func.id == "_handle_completion")
        ]
        if len(call_sites) > 1:
            out.append(
                _find(pid, sim, call_sites[1].lineno,
                      f"parcelport_sim.py calls _handle_completion from "
                      f"{len(call_sites)} sites — dispatch-by-kind belongs to the "
                      "engine driver alone", "des-handle-completion")
            )
    return out


# ========================================================= gate 5 (serving)
_QUEUE_CTORS = ("LCRQueue", "MichaelScottQueue", "LockQueue")
_SERVE_SCOPE_SUFFIXES = ("core/executor.py", "launch/serve.py")


@analysis_pass("gate-serving-comm", "serving hand-off rides the shared comm layer")
def gate_serving_comm(ctx: AnalysisContext) -> List[Finding]:
    out: List[Finding] = []
    pid = "gate-serving-comm"
    server = ctx.module_at("serve/server.py")
    if server is not None:
        for needle, why in (
            ("CommChannel", "requests/responses must ride the comm layer's channel"),
            ("ProgressEngine", "the engine loop must be the ONE shared ProgressEngine"),
            ("run_step", "the serve loop must drive the engine's canonical step"),
        ):
            if not _identifier_used(server, needle):
                out.append(
                    _find(pid, server, 1,
                          f"src/repro/serve/server.py: {needle} missing — {why}",
                          f"server-needle:{needle}")
                )
        if not any(
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute) and n.func.attr == "for_config"
            and _resolved_name(n.func.value, server) == "ProgressPolicy"
            for n in ast.walk(server.tree)
        ):
            out.append(
                _find(pid, server, 1,
                      "src/repro/serve/server.py: ProgressPolicy.for_config missing — "
                      "the policy must come from the shared builder",
                      "server-needle:ProgressPolicy.for_config")
            )
    executor = ctx.module_at("core/executor.py")
    if executor is not None and not _identifier_used(executor, "run_step"):
        out.append(
            _find(pid, executor, 1,
                  "src/repro/core/executor.py: the idle pump does not drive the "
                  "shared engine (run_step) — opaque private pump re-grown?",
                  "executor-run_step")
        )
    # 5b. no private hand-off machinery beside it (alias-aware)
    scoped = [
        m for m in ctx.modules.values()
        if (m.path or "").startswith("src/repro/serve/")
        or any((m.path or m.name).endswith(s) for s in _SERVE_SCOPE_SUFFIXES)
    ]
    for mod in scoped:
        path = mod.path or mod.name
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                ctor = _resolved_name(node.func, mod)
                if ctor in _QUEUE_CTORS:
                    out.append(
                        _find(pid, mod, node.lineno,
                              f"{path}: constructs {ctor} — completion queues belong "
                              "behind the comm layer", f"queue-ctor:{ctor}")
                    )
                elif isinstance(node.func, ast.Attribute) and node.func.attr in ("isend", "irecv"):
                    out.append(
                        _find(pid, mod, node.lineno,
                              f"{path}: calls .{node.func.attr}( — the MPI veneer "
                              "bypasses the unified interface",
                              f"mpi-veneer:{node.func.attr}")
                    )
        for pump in ("_send_loop", "_recv_loop"):
            if _identifier_used(mod, pump):
                out.append(
                    _find(pid, mod, 1,
                          f"{path}: contains {pump} — private hand-off loop re-grown",
                          f"pump:{pump}")
                )
    return out


# ===================================================== gate 6 (capability)
_CAP_ALLOW = ("src/repro/core/comm/", "src/repro/core/device.py", "src/repro/core/mpi_sim.py")


@analysis_pass("gate-put-capability", "put-path selection by advertised Capabilities only")
def gate_put_capability(ctx: AnalysisContext) -> List[Finding]:
    out: List[Finding] = []
    pid = "gate-put-capability"
    for mod in ctx.modules.values():
        path = mod.path or ""
        if path.startswith("src/repro/") and any(
            path.startswith(a) or path == a for a in _CAP_ALLOW
        ):
            continue
        for node in ast.walk(mod.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "isinstance"
                and len(node.args) == 2
            ):
                continue
            type_arg = node.args[1]
            candidates = list(type_arg.elts) if isinstance(type_arg, ast.Tuple) else [type_arg]
            hit = next(
                (n for n in (_resolved_name(c, mod) for c in candidates) if n in BACKEND_NAMES),
                None,
            )
            if hit:
                out.append(
                    _find(pid, mod, node.lineno,
                          f"{mod.path or mod.name}: isinstance() against concrete comm "
                          f"backend {hit} — select the put path from "
                          "capabilities.one_sided_put, not the backend type",
                          f"isinstance:{hit}")
                )
        posts_put = any(True for _ in _attr_calls(mod, "post_put_signal"))
        if posts_put and not _identifier_used(mod, "one_sided_put"):
            out.append(
                _find(pid, mod, 1,
                      f"{mod.path or mod.name}: posts one-sided puts without consulting "
                      "capabilities.one_sided_put — the put path must be selected by "
                      "the advertised Capabilities", "put-no-capability")
            )
    return out


# ======================================================= gate 7 (nursery)
@analysis_pass("gate-thread-nursery", "worker threads only via the membership nursery")
def gate_thread_nursery(ctx: AnalysisContext) -> List[Finding]:
    """Gate 7, rebuilt on the call graph: delegates to the
    thread-ownership pass (alias-aware ``threading.Thread`` resolution +
    resolved-call wiring checks) and re-tags the findings so the gate
    keeps its own stable fingerprint namespace."""
    from .passes import thread_ownership

    return [
        Finding(pass_id="gate-thread-nursery", file=f.file, line=f.line,
                message=f.message, key=f.key, witness=f.witness)
        for f in thread_ownership(ctx)
    ]


# ======================================================== gate 8 (pickle)
_WIRE_SCOPE = ("src/repro/train/grad_sync.py", "src/repro/core/comm/", "src/repro/serve/")


@analysis_pass("gate-no-pickle-wire", "wire-path modules never touch pickle")
def gate_no_pickle_wire(ctx: AnalysisContext) -> List[Finding]:
    out: List[Finding] = []
    pid = "gate-no-pickle-wire"
    for mod in ctx.modules.values():
        path = mod.path or ""
        if path.startswith("src/repro/") and not any(
            path.startswith(s) or path == s for s in _WIRE_SCOPE
        ):
            continue
        if not path:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import) and any(
                a.name.split(".")[0] == "pickle" for a in node.names
            ):
                offender = "import pickle"
            elif isinstance(node, ast.ImportFrom) and (node.module or "").split(".")[0] == "pickle":
                offender = "from pickle import"
            elif isinstance(node, ast.Name) and node.id == "pickle":
                offender = "pickle reference"
            else:
                continue
            out.append(
                _find(pid, mod, node.lineno,
                      f"{path}:{node.lineno}: {offender} — wire-path modules must use "
                      "the versioned binary format in core/comm/wire.py "
                      "(encode_msg/decode_msg, grad headers), never pickle",
                      f"pickle:{offender}")
            )
    return out
