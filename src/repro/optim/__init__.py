from .adamw import OptHParams, adamw_init, adamw_update, global_norm, warmup_cosine

__all__ = ["OptHParams", "adamw_init", "adamw_update", "global_norm", "warmup_cosine"]
