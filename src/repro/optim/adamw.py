"""AdamW + schedules, pure-pytree (no optax dependency).

Optimizer state: fp32 first/second moments per parameter leaf.  With
``zero=True`` sharding rules the moments shard over the data axis (ZeRO-1)
— see :mod:`repro.sharding.params`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptHParams", "adamw_init", "adamw_update", "warmup_cosine", "global_norm"]


@dataclass(frozen=True)
class OptHParams:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 1000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def variant(self, **kw) -> "OptHParams":
        return dataclasses.replace(self, **kw)


def warmup_cosine(step: jax.Array, hp: OptHParams) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = hp.lr_peak * step / max(hp.warmup_steps, 1)
    frac = jnp.clip(
        (step - hp.warmup_steps) / max(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0
    )
    cos = hp.lr_min + 0.5 * (hp.lr_peak - hp.lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < hp.warmup_steps, warm, cos)


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any,
    opt_state: Dict[str, Any],
    params: Any,
    hp: OptHParams,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = warmup_cosine(count, hp)
    b1, b2 = hp.beta1, hp.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + hp.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            step = step + hp.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "count": count}, metrics
