"""The paper's three workloads on the DES parcelport model.

* :func:`flood`   — message-rate microbenchmark (paper Fig 3a): ``nchains``
  very large, ``nsteps = 1`` → one rank floods the other.
* :func:`chains`  — latency microbenchmark (paper Fig 3b): ``nsteps`` large,
  ``nchains`` concurrent ping-pong chains.
* :func:`octotiger` — an octree-structured task graph with Octo-Tiger's
  communication profile (paper Fig 1: frequent small messages, occasional
  large zero-copy transfers, no phases) for the application studies
  (Figs 4, 8, 9).

All return plain dicts so benchmarks can render paper-style tables.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from .costs import DEFAULT_MECHANISMS, EXPANSE, Mechanisms, Platform
from .parcelport_sim import ParcelOp, SimConfig, SimWorld, Task, sim_config_for_variant

__all__ = ["flood", "chains", "octotiger", "MicroResult", "AppResult"]


@dataclass
class MicroResult:
    variant: str
    msg_size: int
    nthreads: int
    elapsed: float
    messages: int
    # bounded-injection/receive counters (zero under the classic unbounded
    # model): EAGAIN refusals, RNR arrival refusals (plus storm-mode
    # retransmission attempts), and the send-ring / bounce-pool /
    # retry-queue occupancy high waters
    backpressure_events: int = 0
    rnr_events: int = 0
    rnr_retries: int = 0
    send_queue_hw: int = 0
    bounce_in_use_hw: int = 0
    retry_queue_hw: int = 0
    # hardware-CQ residency (ISSUE 8): time completions sat un-reaped —
    # the elastic controller's signal — plus its resize count (zero for
    # fixed variants)
    reap_ewma: float = 0.0
    reap_high: float = 0.0
    reap_p99: float = 0.0
    resizes: int = 0

    @property
    def rate(self) -> float:
        """Delivered parcels per second."""
        return self.messages / self.elapsed if self.elapsed > 0 else 0.0


@dataclass
class AppResult:
    variant: str
    n_nodes: int
    elapsed: float
    tasks: int
    messages: int
    bytes: int
    # bounded-injection/receive counters (zero under the unbounded model)
    backpressure_events: int = 0
    rnr_events: int = 0
    rnr_retries: int = 0
    send_queue_hw: int = 0
    bounce_in_use_hw: int = 0
    retry_queue_hw: int = 0
    reap_ewma: float = 0.0
    reap_high: float = 0.0
    reap_p99: float = 0.0
    resizes: int = 0


def _reap_kwargs(world: SimWorld) -> dict:
    """Reap-latency + elastic telemetry shared by every result type."""
    return {
        "reap_ewma": world.reap_lat_ewma,
        "reap_high": world.reap_lat_high,
        "reap_p99": world.reap_p99(),
        "resizes": world.resizes,
    }


def _world(variant: str, n_ranks: int, workers: int, platform: Platform, mech: Mechanisms) -> SimWorld:
    cfg = sim_config_for_variant(variant) if isinstance(variant, str) else variant
    return SimWorld(n_ranks, workers, cfg, platform=platform, mech=mech)


# --------------------------------------------------------------------- flood
def flood(
    variant: str,
    msg_size: int = 8,
    nthreads: int = 16,
    nmsgs: int = 20_000,
    platform: Platform = EXPANSE,
    mech: Mechanisms = DEFAULT_MECHANISMS,
    max_seconds: float = 5.0,
) -> MicroResult:
    """Rank 0 (nthreads workers) floods rank 1; rate measured at delivery."""
    world = _world(variant, 2, nthreads, platform, mech)
    state = {"delivered": 0, "t_done": None}

    def on_delivered() -> None:
        state["delivered"] += 1
        if state["delivered"] >= nmsgs:
            state["t_done"] = world.env.now
            world.stop()

    def sender_action(worker):
        if world.stopped:
            return None
        op = world.make_parcel(0, 1, msg_size, on_delivered)
        return world.send_parcel(worker, op)

    # one task per message — the paper's benchmark is a task graph with
    # nchains single-send tasks, not a tight per-thread send loop
    for _ in range(nmsgs):
        world.spawn(0, Task(action=sender_action))
    world.run(until=max_seconds)
    elapsed = state["t_done"] if state["t_done"] is not None else world.env.now
    inj = world.injection_stats()
    return MicroResult(
        variant=variant if isinstance(variant, str) else variant.name,
        msg_size=msg_size,
        nthreads=nthreads,
        elapsed=max(elapsed, 1e-12),
        messages=state["delivered"],
        backpressure_events=inj["backpressure_events"],
        rnr_events=inj["rnr_events"],
        rnr_retries=inj["rnr_retries"],
        send_queue_hw=inj["send_queue_hw"],
        bounce_in_use_hw=inj["bounce_in_use_hw"],
        retry_queue_hw=inj["retry_queue_hw"],
        **_reap_kwargs(world),
    )


# -------------------------------------------------------------------- chains
def chains(
    variant: str,
    msg_size: int = 8,
    nchains: int = 64,
    nsteps: int = 50,
    nthreads: int = 16,
    platform: Platform = EXPANSE,
    mech: Mechanisms = DEFAULT_MECHANISMS,
    max_seconds: float = 10.0,
) -> MicroResult:
    """``nchains`` ping-pong chains alternating rank 0 ↔ rank 1;
    reported ``elapsed`` is the mean one-way hop latency."""
    world = _world(variant, 2, nthreads, platform, mech)
    remaining = {"chains": nchains}
    total_steps = nchains * nsteps

    def make_hop(chain: int, step: int):
        """Delivery of step `step` spawns the task that sends step+1."""

        def on_delivered() -> None:
            src = (step + 1) % 2
            if step + 1 >= nsteps:
                remaining["chains"] -= 1
                if remaining["chains"] == 0:
                    world.stop()
                return

            def action(worker):
                op = ParcelOp(src=src, dst=1 - src, size=msg_size, on_delivered=make_hop(chain, step + 1))
                return world.send_parcel(worker, op)

            world.spawn(src, Task(action=action))

        return on_delivered

    def first_send(chain: int):
        def action(worker):
            op = ParcelOp(src=0, dst=1, size=msg_size, on_delivered=make_hop(chain, 0))
            return world.send_parcel(worker, op)

        return action

    for c in range(nchains):
        world.spawn(0, Task(action=first_send(c)))
    world.run(until=max_seconds)
    hops = total_steps if remaining["chains"] == 0 else max(1, total_steps - remaining["chains"] * nsteps)
    inj = world.injection_stats()
    return MicroResult(
        variant=variant if isinstance(variant, str) else variant.name,
        msg_size=msg_size,
        nthreads=nthreads,
        elapsed=world.env.now / hops * nchains,  # per-hop latency per chain
        messages=hops,
        backpressure_events=inj["backpressure_events"],
        rnr_events=inj["rnr_events"],
        rnr_retries=inj["rnr_retries"],
        send_queue_hw=inj["send_queue_hw"],
        bounce_in_use_hw=inj["bounce_in_use_hw"],
        retry_queue_hw=inj["retry_queue_hw"],
        **_reap_kwargs(world),
    )


# ----------------------------------------------------------------- octotiger
def octotiger(
    variant: str,
    n_nodes: int = 8,
    workers: int = 16,
    total_subgrids: int = 512,
    timesteps: int = 5,
    task_compute: float = 25e-6,
    small_msg: int = 1024,
    large_msg: int = 65536,
    large_every: int = 16,
    neighbors_per_task: int = 3,
    platform: Platform = EXPANSE,
    mech: Mechanisms = DEFAULT_MECHANISMS,
    max_seconds: float = 60.0,
    seed: int = 0,
) -> AppResult:
    """Strong-scaling octree task graph with Octo-Tiger's message profile.

    ``total_subgrids`` octants are distributed over ``n_nodes`` ranks
    (over-decomposed: subgrids ≫ workers).  Each timestep, every subgrid
    runs one compute task, then sends boundary data to ``neighbors_per_task``
    neighbor subgrids (mostly small control/boundary messages, every
    ``large_every``-th a large zero-copy transfer — Fig 1's distribution).
    A subgrid's next-step task becomes runnable once it received all its
    neighbor messages for the current step — dependency-driven, no global
    barrier.  Strong scaling: per-rank work shrinks with ``n_nodes`` while
    the communication surface grows, exactly the regime where parcelport
    efficiency dominates (paper Fig 4).
    """
    rng = _LCG(seed)
    world = _world(variant, n_nodes, workers, platform, mech)
    per_rank = max(1, total_subgrids // n_nodes)
    n_sub = per_rank * n_nodes

    # neighbor map: octree siblings + across-rank faces (deterministic)
    owner = lambda g: g // per_rank  # noqa: E731
    neighbors: List[List[int]] = []
    for g in range(n_sub):
        nb = set()
        base = (g // 8) * 8
        for k in range(1, neighbors_per_task + 1):
            nb.add(base + (g + k) % 8)  # octree siblings (often same rank)
        nb.add((g + per_rank) % n_sub)  # face neighbor on the next rank
        nb.discard(g)
        neighbors.append(sorted(nb))

    # dependency bookkeeping: arrivals[g][step] counts received messages
    need: List[int] = [0] * n_sub
    for g in range(n_sub):
        for nb in neighbors[g]:
            need[nb] += 1
    arrivals: Dict[int, int] = {}
    done_tasks = {"n": 0, "target": n_sub * timesteps}
    msg_serial = {"n": 0}

    def run_subgrid(g: int, step: int) -> None:
        def action(worker):
            def gen():
                for nb in neighbors[g]:
                    dst = owner(nb)
                    msg_serial["n"] += 1
                    big = msg_serial["n"] % large_every == 0
                    size = large_msg if big else small_msg
                    if dst == owner(g):
                        # local delivery: scheduler hand-off, no parcelport
                        on_msg(nb, step)
                        continue
                    op = world.make_parcel(owner(g), dst, size, _mk_on_msg(nb, step))
                    yield from world.send_parcel(worker, op)
                done_tasks["n"] += 1
                if done_tasks["n"] >= done_tasks["target"]:
                    world.stop()

            return gen()

        world.spawn(owner(g), Task(compute=task_compute, action=action))

    def _mk_on_msg(g: int, step: int):
        return lambda: on_msg(g, step)

    def on_msg(g: int, step: int) -> None:
        key = g * timesteps + step
        arrivals[key] = arrivals.get(key, 0) + 1
        if arrivals[key] == need[g] and step + 1 < timesteps:
            run_subgrid(g, step + 1)

    for g in range(n_sub):
        run_subgrid(g, 0)
    world.run(until=max_seconds)
    inj = world.injection_stats()
    return AppResult(
        variant=variant if isinstance(variant, str) else variant.name,
        n_nodes=n_nodes,
        elapsed=world.env.now,
        tasks=done_tasks["n"],
        messages=world.msg_count,
        bytes=world.byte_count,
        backpressure_events=inj["backpressure_events"],
        rnr_events=inj["rnr_events"],
        rnr_retries=inj["rnr_retries"],
        send_queue_hw=inj["send_queue_hw"],
        bounce_in_use_hw=inj["bounce_in_use_hw"],
        retry_queue_hw=inj["retry_queue_hw"],
        **_reap_kwargs(world),
    )


class _LCG:
    """Deterministic tiny RNG (no global random state)."""

    def __init__(self, seed: int):
        self.state = (seed * 6364136223846793005 + 1442695040888963407) & (2**64 - 1)

    def next(self, n: int) -> int:
        self.state = (self.state * 6364136223846793005 + 1442695040888963407) & (2**64 - 1)
        return (self.state >> 33) % n
