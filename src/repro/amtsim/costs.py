"""Cost models for the parcelport simulation.

All times in **seconds**.  The mechanism constants below were calibrated once
against the paper's Expanse results (§4.2, Figs 3-4): the calibration targets
are the *relative* claims (≈3× short-message rate vs best MPI variant, ≈20×
16KiB rate, ≈50× vs ``mpi_a`` on large messages, ≈4× LCI thread scaling,
≈2× Octo-Tiger at scale) plus sane absolute magnitudes (µs-scale software
overheads, HDR-IB wire rates).  EXPERIMENTS.md records the validation.

Platform constants model the NIC/wire; mechanism constants model the
software stack the paper varies.

**Modeled:** per-operation software costs (posting, matching, completion
objects, locks with per-waiter contention penalties, MPI_Test serialization,
aggregation merge, serialization per byte) and the injection-side costs of
resource exhaustion (``t_post_eagain`` — a refused post under the bounded
fabric of :mod:`repro.amtsim.parcelport_sim`).  **Abstracted away:** cache
geometry, NUMA, and instruction-level behaviour — every such effect is
folded into one calibrated scalar per mechanism.  Changing a constant here
re-calibrates every benchmark claim downstream; EXPERIMENTS.md records the
validation runs that anchor the current values.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

US = 1e-6  # microsecond


@dataclass(frozen=True)
class Platform:
    name: str
    wire_latency: float = 1.2 * US  # one-way, HDR InfiniBand
    # per-device injection: a message occupies the device for
    # max(inj_overhead, bytes / bandwidth)
    inj_overhead: float = 0.22 * US  # ≈4.5 M msg/s per device peak
    bandwidth: float = 12.5e9  # 2x50 Gb/s HDR ≈ 12.5 GB/s
    # Delta/Slingshot-11: libfabric wraps its CQ poll in a pthread spin lock
    # (§4.2.3 — 85% of time on 32 nodes spent in that lock).
    libfabric_cq_lock: bool = False
    progress_lock_cost: float = 0.0  # extra serialized time per progress


EXPANSE = Platform(name="expanse")
FRONTERA = Platform(name="frontera", inj_overhead=0.25 * US)
# Slingshot-11: faster wire on paper, but the shared libfabric CQ lock
# serializes polling (modeled as a mandatory coarse lock around progress).
DELTA = Platform(
    name="delta",
    wire_latency=1.1 * US,
    bandwidth=25.0e9,
    inj_overhead=0.30 * US,
    libfabric_cq_lock=True,
    progress_lock_cost=0.25 * US,
)

PLATFORMS = {"expanse": EXPANSE, "frontera": FRONTERA, "delta": DELTA}


@dataclass(frozen=True)
class Mechanisms:
    """Software costs for each mechanism the paper studies."""

    # posting operations
    t_post_send: float = 0.15 * US
    t_post_recv: float = 0.15 * US
    t_tag_match: float = 0.25 * US  # two-sided receive path (§3.3.1)
    t_put_deliver: float = 0.08 * US  # dynamic put: hand buffer to user
    # put-signal completion (§3.3.1, the middle capability-ladder rung):
    # the receiver discovers a completed put by testing raised per-slot
    # signal flags — cheaper than tag matching, but the scan is a
    # serialized sweep (charged under the match lock), unlike the
    # lock-free queue-completion path above
    t_put_signal: float = 0.05 * US

    # progress engine
    t_progress_poll: float = 0.12 * US  # one CQ poll sweep
    t_per_completion: float = 0.06 * US

    # completion objects (§5.2)
    t_cq_push: dict = field(
        default_factory=lambda: {"lcrq": 0.05 * US, "ms": 0.14 * US, "lock": 0.30 * US}
    )
    t_cq_pop: dict = field(
        default_factory=lambda: {"lcrq": 0.05 * US, "ms": 0.14 * US, "lock": 0.30 * US}
    )
    # contention penalty per concurrent accessor beyond the first
    cq_contention: dict = field(
        default_factory=lambda: {"lcrq": 0.004 * US, "ms": 0.08 * US, "lock": 0.25 * US}
    )
    t_sync_signal: float = 0.02 * US  # synchronizer = single 4B store
    t_sync_test: float = 0.05 * US  # one request test (no match)

    # MPI-specific (§3.3.2, §3.3.4)
    t_mpi_test: float = 0.60 * US  # MPI_Test incl. implicit progress entry
    t_mpi_big_lock: float = 0.10 * US  # serialized section per MPI call

    # bounded injection (§3.3.4): a post refused by a full send ring or an
    # exhausted bounce-buffer pool still costs the failed descriptor write /
    # pool probe before the library parks the post for retry
    t_post_eagain: float = 0.03 * US

    # RNR retry storms (§3.1): with ``SimConfig.rnr_storm`` set, a
    # receiver-not-ready arrival is retransmitted by the NIC after this
    # base backoff, doubling per failed attempt (capped at 64x) — instead
    # of the free redelivery-on-reap of the default model.  Real HDR-IB
    # RNR timers are far larger; this value is scaled to the simulated
    # µs regime so storms visibly collapse throughput without freezing
    # the event loop.
    t_rnr_retry: float = 2.0 * US

    # locks (§5.3).  Beyond FIFO serialization, every blocking acquisition
    # pays a penalty per waiter queued behind the lock — cache-line
    # bouncing / futex wakeups scale with the contender count, which is the
    # paper's "most crucial factor" (thread contention on coarse locks).
    t_lock_uncontended: float = 0.04 * US
    t_lock_contention: float = 0.08 * US
    t_try_fail: float = 0.02 * US

    # upper layer
    t_serialize_per_byte: float = 1.0 / 12e9  # memcpy-bound
    # eager shipment beyond the header-piggyback limit copies the payload
    # into a pre-registered bounce buffer (§3.3.4) — a distinct, separately
    # calibrated memcpy from serialization, so experiments can vary
    # registered-memory bandwidth without touching the serializer (the two
    # coincide on the calibrated platforms, hence the equal default)
    t_bounce_copy_per_byte: float = 1.0 / 12e9
    t_handle_parcel: float = 0.5 * US  # spawn the task, bookkeeping
    t_aggregate: float = 0.3 * US  # parcel queue lock + merge per parcel

    # Elastic membership (ISSUE 8): control-plane costs of resizing a
    # live worker pool.  Joining spawns/registers a worker (thread start +
    # endpoint re-wire); draining quiesces in-flight work before the slot
    # is released; a state handoff streams the departing worker's shard to
    # its successor at registered-memory copy bandwidth.
    t_worker_join: float = 5.0 * US
    t_worker_drain: float = 3.0 * US
    t_handoff_per_byte: float = 1.0 / 12e9

    def variant(self, **kw) -> "Mechanisms":
        return replace(self, **kw)


DEFAULT_MECHANISMS = Mechanisms()
