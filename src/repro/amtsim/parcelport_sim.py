"""Discrete-event model of the HPX parcelport stack (quantitative repro).

The functional layer (:mod:`repro.core`) proves the *interfaces*; this layer
carries the *performance* claims, which a 1-core GIL-bound container cannot
measure in wall time.  Every mechanism the paper varies is modeled with a
calibrated cost (:mod:`repro.amtsim.costs`) on a discrete-event kernel
(:mod:`repro.amtsim.des`):

* worker threads are DES processes — they genuinely overlap in simulated
  time except where locks serialize them, reproducing the paper's central
  contention dynamics (§5.3);
* coarse locks additionally charge a **contention penalty per waiter**
  (cache-line bouncing / futex cost — the paper's "most crucial factor");
* devices are injection channels: a message occupies its device for
  ``max(inj_overhead, bytes/bandwidth)`` and lands in the destination
  device's completion queue after ``wire_latency``;
* completion queues (LCRQ/MS/lock, shared or per-device via ``cq_scope``),
  synchronizer pools, tag matching, MPI_Test-only implicit progress, parcel
  aggregation, and the Slingshot-11 libfabric CQ lock (§4.2.3) are explicit
  costs or DES locks.

**The progress engine is not re-implemented here.**  ``background_work``
drives the SAME :class:`~repro.core.comm.progress.ProgressEngine` the
functional parcelports run — one canonical step loop (drain retries →
progress device(s) → reap completions → dispatch by kind), parameterized
by a :class:`~repro.core.comm.progress.ProgressPolicy` — through a
clock/cost adapter: each engine op charges its calibrated
:class:`~repro.amtsim.costs.Mechanisms` cost, and lock ops acquire real DES
locks so contention is *simulated*, never re-coded.  Because both layers
replay one decision sequence, their protocol-path and completion-dispatch
choices cannot drift (tests/test_progress_engine.py compares ordered
decision traces).  ``SimConfig.progress_workers`` reserves cores that only
drive the engine (§3.3.4's omitted experiment, the ``lci_prg{n}`` family).

Follow-up (zero-copy) chunks use a rendezvous: the receiver processes the
header, allocates buffers, posts the receive, and only then does the wire
carry the payload — the same extra round both real parcelports pay for
unexpected large transfers, applied to both families equally.

**Bounded injection** (paper §3.3.4) consumes the *same*
:class:`repro.core.comm.resources.ResourceLimits` object as the functional
fabric — ``SimConfig.limits`` — so the DES and the functional experiments
can never drift field by field: each device may have a finite send ring
(``limits.send_queue_depth``) and a finite pool of registered bounce
buffers for eager messages (``bounce_buffers`` × ``bounce_buffer_size``).
A post that finds the ring full or the pool empty is refused EAGAIN-style
(cost ``t_post_eagain``), counted in ``SimWorld.backpressure_events``, and
parked in a per-device retry queue that background work drains under a
``retry_budget`` — the sender-side throttle the paper credits for LCI's
small-message robustness.  A ring slot stays occupied from post until the
*send completion is reaped* by the progress engine, so a rank that stops
polling its own CQ throttles its own injection, exactly like real hardware.
With ``limits.recv_slots`` set, the *receive* side is bounded the same way
:mod:`repro.core.fabric` bounds it: an arrival that finds every posted
receive descriptor still un-reaped is an **RNR** (receiver-not-ready)
event — counted in ``SimWorld.rnr_events``, parked on the destination
device, and redelivered once the receiver's progress engine reaps backlog
(hardware retransmission, not message loss).  ``SimConfig.rnr_storm``
upgrades that free redelivery to the paper's §3.1 storm model: each RNR'd
arrival is retransmitted after an exponential backoff charged on
``Mechanisms.t_rnr_retry`` (doubling per failed attempt, capped), every
retransmission counted in ``SimWorld.rnr_retries`` — retry storms now cost
wire time, which is how they collapse throughput on real hardware.
Occupancy high-water marks (send ring, bounce pool, retry queue) and the
RNR counters are reported by :meth:`SimWorld.injection_stats`.  All limits
default to 0 (unbounded): the classic model is bit-identical unless a
config opts in, and send completions are only materialized as CQ traffic
in bounded mode.

**Modeled:** thread overlap/contention, per-mechanism software costs, wire
serialization, protocol round trips, aggregation (optionally packed up to
the eager threshold via ``agg_eager`` so an aggregate never spills from
eager into rendezvous), and injection-resource exhaustion.  **Abstracted
away:** real payload bytes (sizes are integers; serialization is a per-byte
cost), wire-level framing overhead, NIC descriptor formats, and memory
registration (a bounce buffer is a counter, not memory).

Variant names match :mod:`repro.core.variants`, so benchmarks sweep the same
configuration space as the paper's Figs 3-9.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Generator, List, Optional, Tuple

from ..core.comm.progress import (
    ROLE_PROGRESS,
    ROLE_TASK,
    CompletionRouter,
    CompletionSource,
    ProgressEngine,
    ProgressPolicy,
)
from ..core.comm.resources import ResourceLimits
from ..core.device import LockMode
from ..core.lci_parcelport import LCIPPConfig
from ..core.variants import VARIANTS
from .costs import DEFAULT_MECHANISMS, EXPANSE, Mechanisms, Platform
from .des import Acquire, Env, Lock, Store, Timeout

__all__ = ["SimWorld", "SimConfig", "Task", "sim_config_for_variant", "HEADER_BYTES", "PIGGYBACK_LIMIT"]

HEADER_BYTES = 64
PIGGYBACK_LIMIT = 8192  # nzc chunks up to this ride on the header (paper §4.2.2)


@dataclass
class SimConfig:
    """Variant knobs (mirrors LCIPPConfig) + which library family it is."""

    name: str = "lci"
    mpi: bool = False
    aggregation: bool = False
    header_mode: str = "put"  # 'put' | 'sendrecv'
    header_comp: str = "queue"  # 'queue' | 'sync'
    followup_comp: str = "queue"  # 'queue' | 'sync'
    cq_kind: str = "lcrq"
    # Completion-queue topology (§3.3.3, mirrors LCIPPConfig.cq_scope):
    # 'shared' = one queue per rank (contention pools across devices);
    # 'device' = one per device (contention scoped to each device).
    cq_scope: str = "shared"
    ndevices: int = 2
    lock_mode: str = LockMode.NONE
    progress_mode: str = "explicit"  # 'explicit' | 'implicit'
    # paper §3.3.4's omitted experiment: reserve n cores that ONLY drive
    # the progress engine (never execute tasks) — the lci_prg{n} family
    progress_workers: int = 0
    # Elastic progress bounds (ISSUE 8, the lci_eprg{lo}_{hi} family): the
    # dedicated pool starts at lo and an elastic controller grows/shrinks
    # it between (lo, hi) from sampled reap occupancy, charging
    # Mechanisms.t_worker_join / t_worker_drain per resize.
    elastic_progress: Optional[Tuple[int, int]] = None
    # DES-only: disable hysteresis + cooldown on the elastic controller —
    # the naive oscillating baseline elasticity_study compares against.
    elastic_hysteresis: bool = True
    # Protocol engine: payloads up to this size ship as ONE eager message
    # (bounce-buffer copy cost, no rendezvous round trip); 0 disables the
    # eager path beyond plain header piggybacking.
    eager_threshold: int = PIGGYBACK_LIMIT
    # Threshold-aware aggregation (mirrors LCIPPConfig.agg_eager): the
    # aggregation drain packs parcels into batches of at most
    # eager_threshold bytes, so each aggregate still ships eager.
    agg_eager: bool = False
    # RNR retry storms (§3.1, the ROADMAP follow-up): RNR'd arrivals are
    # retransmitted under exponential backoff charged on t_rnr_retry
    # instead of redelivered free on reap.  Only meaningful with
    # limits.recv_slots > 0; the default keeps the model bit-identical.
    rnr_storm: bool = False
    # Bounded injection/receive (§3.3.4): the SAME ResourceLimits object
    # the functional fabric consumes — never per-field mirrors (gated by
    # tools/check_api.py).  A refused post costs t_post_eagain and parks in
    # a per-device retry queue drained by background work; with recv_slots
    # set, over-backlogged arrivals are RNR events redelivered on reap.
    limits: ResourceLimits = field(default_factory=ResourceLimits)

    # read-only delegates into the shared resource model ---------------------
    @property
    def send_queue_depth(self) -> int:
        return self.limits.send_queue_depth

    @property
    def bounce_buffers(self) -> int:
        return self.limits.bounce_buffers

    @property
    def bounce_buffer_size(self) -> int:
        return self.limits.bounce_buffer_size

    @property
    def retry_budget(self) -> int:
        return self.limits.retry_budget

    @property
    def recv_slots(self) -> int:
        return self.limits.recv_slots

    @property
    def bounded_injection(self) -> bool:
        return self.limits.bounded


#: LCIPPConfig fields copied verbatim into SimConfig — the shared variant
#: axes.  Exhaustive by construction: tests/test_progress_engine.py fails
#: if LCIPPConfig grows a shared knob that is not mapped here.
SHARED_CONFIG_FIELDS = (
    "aggregation",
    "header_mode",
    "header_comp",
    "followup_comp",
    "cq_kind",
    "cq_scope",
    "ndevices",
    "lock_mode",
    "progress_mode",
    "progress_workers",
    "elastic_progress",
    "eager_threshold",
    "agg_eager",
    "limits",
)


def sim_config_for_variant(name: str) -> SimConfig:
    """Translate ANY :mod:`repro.core.variants` name into a SimConfig.

    Resolution goes through the registry view, so parameterized family
    members (``lci_b8``, ``lci_prg2``, ``lci_eager_32k``) resolve on demand
    exactly like fixed names; the field mapping covers every shared axis,
    and ``limits`` is the SAME object the functional variant resolves to —
    the two layers cannot drift (gated by tools/check_api.py)."""
    if name == "mpi":
        return SimConfig(name="mpi", mpi=True, ndevices=1, lock_mode=LockMode.BLOCK)
    if name == "mpi_a":
        return SimConfig(name="mpi_a", mpi=True, aggregation=True, ndevices=1, lock_mode=LockMode.BLOCK)
    cfg: LCIPPConfig = VARIANTS[name]
    return SimConfig(name=name, **{f: getattr(cfg, f) for f in SHARED_CONFIG_FIELDS})


@dataclass
class Task:
    """An AMT task: optional compute burn, then an action callback.

    ``action(worker)`` may return a generator, in which case the worker
    executes it inline (it can yield DES commands, e.g. to send parcels).
    """

    compute: float = 0.0
    action: Optional[Callable[["SimWorker"], Any]] = None


@dataclass
class _Message:
    kind: str  # 'header' | 'followup'
    size: int
    parcel: "ParcelOp"
    # eager messages travel through a registered bounce buffer: under
    # bounded injection they hold one pool buffer from post until the send
    # completion is reaped.
    eager: bool = False


@dataclass
class _MPIReq:
    """One MPI_Request in the parcelport's shared pool (§3.3.2): completion
    is only *noticed* when this request's turn comes up in the round-robin
    single-request MPI_Test."""

    kind: str  # 'send' | 'recv'
    op: "ParcelOp"
    done: bool = False


@dataclass
class ParcelOp:
    """One in-flight parcel (or aggregate of parcels)."""

    src: int
    dst: int
    size: int  # piggyback-eligible payload bytes
    on_delivered: Optional[Callable[[], None]] = None
    send_time: float = 0.0
    nparcels: int = 1
    # zero-copy chunks transfer *sequentially* (paper §3.2: the receiver
    # starts receiving a new chunk only after the prior one completed)
    followup_chunks: List[int] = None  # type: ignore[assignment]
    chunk_idx: int = 0
    src_dev_idx: int = 0
    total_app_bytes: int = 0
    mpi_recv_req: Any = None  # the in-flight follow-up _MPIReq (MPI path)

    def __post_init__(self) -> None:
        if self.followup_chunks is None:
            self.followup_chunks = []


class _SimDevice:
    """One set of communication resources: injection channel + hardware CQ.

    Under bounded injection the device mirrors the functional fabric's
    :class:`~repro.core.fabric.NetDevice`: a finite send ring (``inflight``
    slots, freed when the send completion is reaped from this device's CQ)
    and a finite bounce-buffer pool for eager messages.  Refused posts park
    in ``parked`` until background work retries them.  With
    ``limits.recv_slots`` set the receive side is bounded too: an arrival
    beyond the posted-receive depth is RNR'd into ``rnr_parked`` and
    redelivered once progress reaps backlog — or, under ``rnr_storm``,
    retransmitted with exponential backoff and counted per retry."""

    __slots__ = (
        "env",
        "rank",
        "index",
        "inj_lock",
        "coarse",
        "cq",
        "cq_times",
        "cq_accessors",
        "stats_injected",
        "inflight",
        "inflight_hw",
        "bounce_free",
        "bounce_in_use_hw",
        "parked",
        "parked_hw",
        "stats_backpressure",
        "recv_backlog",
        "rnr_parked",
        "stats_rnr",
        "stats_rnr_retries",
    )

    def __init__(self, env: Env, rank: "SimRank", index: int):
        self.env = env
        self.rank = rank
        self.index = index
        self.inj_lock = Lock(env)  # fine-grained send-queue lock (always present)
        self.coarse = Lock(env)  # coarse library lock (block/try variants)
        self.cq: List[Tuple[str, _Message]] = []
        self.cq_times: List[float] = []  # enqueue stamps, parallel to cq
        self.cq_accessors = 0  # per-device CQ users (cq_scope='device')
        self.stats_injected = 0
        # bounded-injection state (§3.3.4)
        self.inflight = 0  # occupied send-ring slots
        self.inflight_hw = 0  # send-queue occupancy high-water mark
        self.bounce_free = rank.world.cfg.bounce_buffers  # free pool buffers
        self.bounce_in_use_hw = 0  # bounce-pool occupancy high-water mark
        self.parked: Deque[_Message] = deque()  # EAGAIN'd posts awaiting retry
        self.parked_hw = 0  # retry-queue depth high-water mark
        self.stats_backpressure = 0
        # bounded-receive (RNR) state: arrivals occupying posted receives
        # until reaped, and arrivals refused for want of one
        self.recv_backlog = 0
        self.rnr_parked: Deque[Tuple[str, _Message]] = deque()
        self.stats_rnr = 0
        self.stats_rnr_retries = 0


class SimRank:
    """One locality: devices, run queue, completion structures."""

    def __init__(self, world: "SimWorld", rank: int):
        self.world = world
        self.env = world.env
        self.rank = rank
        cfg = world.cfg
        self.devices = [_SimDevice(self.env, self, i) for i in range(cfg.ndevices)]
        self.runq: Store = Store(self.env)  # scheduler run queue
        self.wire_busy_until = 0.0  # shared NIC wire: bandwidth is per rank
        self.cq_accessors = 0  # concurrent LCI-CQ users (contention penalty)
        self.pool_lock = Lock(self.env)  # MPI request pool / synchronizer pool
        # two-sided receive path: "only one thread can proceed along the
        # code path from tag matching to completion signaling" (§3.3.1)
        self.match_lock = Lock(self.env)
        self.lf_lock = Lock(self.env)  # Slingshot-11 libfabric CQ lock (§4.2.3)
        self.agg_queues: Dict[int, List[ParcelOp]] = {}
        self.agg_draining: Dict[int, bool] = {}
        self.agg_lock = Lock(self.env)
        self.handled = 0
        self.sent = 0
        # --- MPI request-pool state (§3.3.2) ---
        # one pre-posted any-source header recv at a time (§3.3.1)
        self.mpi_header_req: Optional[_Message] = None  # completed header, if any
        self.mpi_header_backlog: List[_Message] = []  # unexpected headers
        self.mpi_pool: List["_MPIReq"] = []  # shared request pool, round-robin

    def device_for_worker(self, wid: int) -> _SimDevice:
        return self.devices[wid % len(self.devices)]


class SimWorker:
    """One HPX worker thread (a DES process)."""

    __slots__ = ("rank", "wid", "env", "executed", "role")

    def __init__(self, rank: SimRank, wid: int, role: str = ROLE_TASK):
        self.rank = rank
        self.wid = wid
        self.env = rank.env
        self.executed = 0
        self.role = role

    def run(self) -> Generator:
        world = self.rank.world
        base_sleep = 0.3e-6
        idle_streak = 0
        tasks_since_bg = 0
        while not world.stopped:
            task = self.rank.runq.get_nowait()
            if task is not None:
                idle_streak = 0
                if task.compute > 0:
                    yield Timeout(task.compute)
                if task.action is not None:
                    r = task.action(self)
                    if r is not None:
                        yield from r
                self.executed += 1
                tasks_since_bg += 1
                if tasks_since_bg >= world.bg_interval_tasks:
                    # HPX schedules parcelport background work periodically
                    # even under load, not only on idle cores
                    tasks_since_bg = 0
                    yield from world.background_work(self)
                continue
            tasks_since_bg = 0
            progressed = yield from world.background_work(self)
            if progressed:
                idle_streak = 0
            else:
                # exponential backoff caps DES event volume; progress
                # frequency stays high while traffic flows
                idle_streak += 1
                yield Timeout(min(base_sleep * (1 + idle_streak // 8), 3e-6))


def _build_engine(cfg: SimConfig) -> ProgressEngine:
    """The DES half of the shared-engine contract: the SAME policy builder
    the functional parcelports use, with this layer's completion sources.

    The DES fuses hardware CQ and client completion delivery into one
    queue, so its router has two sources: the cost-only client-side poll
    (``client_poll``) and the per-device hardware CQ (``dev_cq``) — the
    latter reaped under the policy's coarse lock and owned by the progress
    side, which is what dedicated ``ROLE_PROGRESS`` workers sweep (on
    every device).  The MPI family reaps its request pools instead, one
    round-robin MPI_Test each per step (§3.3.2)."""
    policy = ProgressPolicy.for_config(cfg)
    if cfg.mpi:
        router = CompletionRouter(
            [
                CompletionSource("mpi_header", batch=1),
                CompletionSource("mpi_pool", batch=1),
            ],
            ndevices=1,
        )
        return ProgressEngine(policy, router, ndevices=1)
    router = CompletionRouter(
        [
            CompletionSource("client_poll", batch=1),
            CompletionSource(
                "dev_cq", batch=16, per_device=True, sweep="own", locked=True, progress_side=True
            ),
        ],
        ndevices=cfg.ndevices,
    )
    return ProgressEngine(policy, router, ndevices=cfg.ndevices)


class SimWorld:
    """The simulated cluster running one parcelport variant."""

    def __init__(
        self,
        n_ranks: int,
        workers_per_rank: int,
        cfg: SimConfig,
        platform: Platform = EXPANSE,
        mech: Mechanisms = DEFAULT_MECHANISMS,
        bg_interval_tasks: int = 8,
    ):
        self.env = Env()
        self.cfg = cfg
        self.platform = platform
        self.mech = mech
        self.bg_interval_tasks = bg_interval_tasks
        self.ranks = [SimRank(self, r) for r in range(n_ranks)]
        self.workers: List[SimWorker] = []
        self.stopped = False
        self.msg_count = 0
        self.byte_count = 0
        self.backpressure_events = 0  # EAGAIN-style post refusals (§3.3.4)
        self.rnr_events = 0  # receiver-not-ready arrival refusals
        self.rnr_retries = 0  # storm-mode retransmission attempts (§3.1)
        # hardware-CQ residency (ISSUE 8): time each completion sat
        # un-reaped — the elastic controller's feedback signal
        self.reap_samples: List[float] = []
        self.reap_lat_ewma = 0.0
        self.reap_lat_high = 0.0
        # elastic-pool telemetry
        self.elastic_size = 0  # current dedicated workers per rank (elastic)
        self.grows = 0
        self.shrinks = 0
        if cfg.progress_workers >= workers_per_rank:
            # every core reserved for the engine leaves nobody to pop the
            # run queue: tasks would sit forever and the workload would
            # silently spin to its time cap — fail fast instead
            raise ValueError(
                f"progress_workers={cfg.progress_workers} must be < "
                f"workers_per_rank={workers_per_rank} (no task workers left)"
            )
        # ONE progress engine for the whole world: pure decision logic —
        # per-rank state stays on the ranks; this driver charges the costs.
        self._engine = _build_engine(cfg)
        for r in self.ranks:
            for w in range(workers_per_rank):
                if w < cfg.progress_workers:
                    wk = SimWorker(r, w, role=ROLE_PROGRESS)
                    self.workers.append(wk)
                    self.env.process(self._progress_worker(wk))
                else:
                    wk = SimWorker(r, w)
                    self.workers.append(wk)
                    self.env.process(wk.run())
        # Elastic dedicated-worker pool (ISSUE 8, lci_eprg{lo}_{hi}): an
        # ADDITIVE per-rank pool of progress-role workers the controller
        # grows/shrinks between (lo, hi), charging Mechanisms costs per
        # resize.  The static progress_workers/progress_mode policy above
        # is untouched — elasticity rides on top of any base variant.
        self._elastic_stops: List[Dict[str, bool]] = []
        if cfg.elastic_progress is not None:
            lo, hi = cfg.elastic_progress
            if not 0 <= lo <= hi:
                raise ValueError(f"elastic_progress bounds must satisfy 0 <= lo <= hi, got {(lo, hi)}")
            for _ in range(lo):
                self._grow_elastic()
            self.env.process(self._elastic_controller(lo, hi))

    @property
    def engine(self) -> ProgressEngine:
        """The shared progress engine (decision-trace hub for parity tests)."""
        return self._engine

    def _progress_worker(self, wk: SimWorker) -> Generator:
        """A core dedicated to the progress engine (§3.3.4, ``lci_prg{n}``):
        the same engine step in its progress role — hardware-CQ sweep on
        EVERY device plus the retry drain of its own mapped device, no
        client-side completion objects.  (Other devices' parked posts are
        drained by the task workers mapped to them, each step.)"""
        while not self.stopped:
            progressed = yield from self.background_work(wk, role=ROLE_PROGRESS)
            if not progressed:
                yield Timeout(0.3e-6)

    # -- elastic dedicated-worker pool (ISSUE 8) ----------------------------
    def _grow_elastic(self) -> None:
        """Add ONE progress-role worker to every rank, with a shared stop
        flag so a later shrink retires exactly this cohort."""
        stop = {"stopped": False}
        self._elastic_stops.append(stop)
        self.elastic_size += 1
        for r in self.ranks:
            wk = SimWorker(r, len(r.devices) + self.elastic_size, role=ROLE_PROGRESS)
            self.env.process(self._elastic_worker(wk, stop))

    def _elastic_worker(self, wk: SimWorker, stop: Dict[str, bool]) -> Generator:
        while not self.stopped and not stop["stopped"]:
            progressed = yield from self.background_work(wk, role=ROLE_PROGRESS)
            if not progressed:
                yield Timeout(0.3e-6)

    def _elastic_controller(self, lo: int, hi: int) -> Generator:
        """Sample hardware-CQ occupancy and resize the elastic pool between
        (lo, hi).  With hysteresis the grow/shrink thresholds are split and
        a cooldown separates consecutive resizes; the naive controller
        (``elastic_hysteresis=False``) uses one threshold and no cooldown —
        the oscillating baseline the study quantifies.  Each resize charges
        the control-plane cost (t_worker_join / t_worker_drain)."""
        interval = 10e-6
        grow_at, shrink_at = 4.0, 1.0
        cooldown = 50e-6
        if not self.cfg.elastic_hysteresis:
            shrink_at = grow_at
            cooldown = 0.0
        occ_ewma = 0.0
        last_resize = -cooldown
        while not self.stopped:
            yield Timeout(interval)
            occ = sum(len(d.cq) for r in self.ranks for d in r.devices) / max(len(self.ranks), 1)
            occ_ewma += 0.3 * (occ - occ_ewma)
            if self.env.now - last_resize < cooldown:
                continue
            if occ_ewma >= grow_at and self.elastic_size < hi:
                yield Timeout(self.mech.t_worker_join)
                self._grow_elastic()
                self.grows += 1
                last_resize = self.env.now
            elif occ_ewma <= shrink_at and self.elastic_size > lo:
                yield Timeout(self.mech.t_worker_drain)
                self._elastic_stops.pop()["stopped"] = True
                self.elastic_size -= 1
                self.shrinks += 1
                last_resize = self.env.now

    @property
    def resizes(self) -> int:
        return self.grows + self.shrinks

    def reap_p99(self) -> float:
        """p99 of hardware-CQ residency over the whole run (seconds)."""
        if not self.reap_samples:
            return 0.0
        s = sorted(self.reap_samples)
        return s[min(len(s) - 1, int(0.99 * len(s)))]

    # --------------------------------------------------------------- helpers
    def injection_stats(self) -> Dict[str, int]:
        """Aggregate bounded-injection/receive counters across every
        device: EAGAIN refusal, RNR and RNR-retransmission counts plus
        occupancy high-water marks for the send ring, the bounce pool, and
        the parked-post retry queue."""
        stats = {
            "backpressure_events": self.backpressure_events,
            "rnr_events": self.rnr_events,
            "rnr_retries": self.rnr_retries,
            "send_queue_hw": 0,
            "bounce_in_use_hw": 0,
            "retry_queue_hw": 0,
        }
        for rank in self.ranks:
            for dev in rank.devices:
                stats["send_queue_hw"] = max(stats["send_queue_hw"], dev.inflight_hw)
                stats["bounce_in_use_hw"] = max(stats["bounce_in_use_hw"], dev.bounce_in_use_hw)
                stats["retry_queue_hw"] = max(stats["retry_queue_hw"], dev.parked_hw)
        return stats

    def _lock_with_contention(self, lock: Lock) -> Generator:
        """Blocking acquire + per-waiter contention penalty (cache-line
        bouncing / futex wake cost grows with the number of contenders)."""
        waiters = len(lock._waiters) + (1 if lock.held else 0)
        yield Acquire(lock)
        penalty = self.mech.t_lock_contention * min(waiters, 32)
        yield Timeout(self.mech.t_lock_uncontended + penalty)

    # ------------------------------------------------------------------ send
    def send_parcel(self, worker: SimWorker, op: ParcelOp) -> Generator:
        """Worker-side send path (generator: burns worker time)."""
        mech, cfg = self.mech, self.cfg
        rank = self.ranks[op.src]
        op.send_time = self.env.now
        op.total_app_bytes = op.size
        if not cfg.aggregation:
            yield from self._send_one(worker, op)
            return
        # HPX parcel aggregation (paper §2.2.2): enqueue, then drain unless
        # another worker's drain is already in flight for this destination.
        # The per-destination parcel queue is itself a point of thread
        # contention (§4.2: "additional thread contention on the parcel
        # queues") — the enqueue cost is paid inside the critical section.
        yield Acquire(rank.agg_lock)
        yield Timeout(mech.t_aggregate)
        q = rank.agg_queues.setdefault(op.dst, [])
        q.append(op)
        if rank.agg_draining.get(op.dst):
            rank.agg_lock.release()
            return  # an in-progress drain cycle will pick this parcel up
        rank.agg_draining[op.dst] = True
        while q:
            drained = list(q)
            q.clear()
            rank.agg_lock.release()
            for batch in self._agg_batches(drained):
                yield from self._send_aggregate(worker, batch)
            yield Acquire(rank.agg_lock)
        rank.agg_draining[op.dst] = False
        rank.agg_lock.release()

    def _agg_batches(self, drained: List[ParcelOp]) -> List[List[ParcelOp]]:
        """Threshold-aware drain (mirrors ``Parcelport._agg_batches``):
        with ``agg_eager`` the drained queue packs greedily into batches of
        at most ``eager_threshold`` payload bytes, so each aggregate still
        ships as one eager message instead of spilling into rendezvous.
        An op alone over the budget gets its own batch (rendezvous
        regardless).  Classic mode: one batch, unbounded merge."""
        cfg = self.cfg
        if not (cfg.agg_eager and cfg.eager_threshold > 0):
            return [drained]
        budget = cfg.eager_threshold
        batches: List[List[ParcelOp]] = []
        cur: List[ParcelOp] = []
        cur_bytes = 0
        for op in drained:
            if cur and cur_bytes + op.size > budget:
                batches.append(cur)
                cur, cur_bytes = [], 0
            cur.append(op)
            cur_bytes += op.size
        if cur:
            batches.append(cur)
        return batches

    def _send_aggregate(self, worker: SimWorker, ops: List[ParcelOp]) -> Generator:
        """Small (piggyback-eligible) parts merge into one nzc chunk;
        zero-copy chunks cannot merge (paper §4.2.2) and stay follow-ups.
        Under ``agg_eager`` the merge eligibility extends to the eager
        threshold: anything the protocol engine would ship eager on its own
        may coalesce into the one bounce-buffered eager message."""
        cfg = self.cfg
        merge_limit = (
            cfg.eager_threshold if (cfg.agg_eager and cfg.eager_threshold > 0) else PIGGYBACK_LIMIT
        )
        first = ops[0]
        small = sum(op.size for op in ops if op.size <= merge_limit)
        big = [op.size for op in ops if op.size > merge_limit]
        agg = ParcelOp(src=first.src, dst=first.dst, size=small, nparcels=len(ops))
        agg.send_time = min(op.send_time for op in ops)
        agg.followup_chunks = big  # zc chunks cannot merge — stay separate
        agg.total_app_bytes = small + sum(big)
        cbs = [op.on_delivered for op in ops if op.on_delivered]

        def deliver_all() -> None:
            for cb in cbs:
                cb()

        agg.on_delivered = deliver_all
        # serialization/merge cost is proportional to merged bytes
        yield Timeout(self.mech.t_serialize_per_byte * small)
        yield from self._send_one(worker, agg)

    def _send_one(self, worker: SimWorker, op: ParcelOp) -> Generator:
        mech, cfg = self.mech, self.cfg
        dev = self.ranks[op.src].device_for_worker(worker.wid)
        op.src_dev_idx = dev.index
        # Protocol selection: one-message limit is the piggyback limit, or
        # the eager threshold when the eager path extends past it.  Eager
        # shipment beyond the plain piggyback limit pays the bounce-buffer
        # copy (memcpy-bound) instead of the rendezvous round trip.  With a
        # finite bounce pool the eager message must also FIT a bounce
        # buffer, or the post could never succeed (mirrors
        # ``LCIParcelport._use_eager``'s capacity check).
        one_msg_limit = max(PIGGYBACK_LIMIT, cfg.eager_threshold) if cfg.eager_threshold > 0 else PIGGYBACK_LIMIT
        if cfg.bounce_buffers > 0:
            one_msg_limit = min(one_msg_limit, cfg.bounce_buffer_size - HEADER_BYTES)
        if op.size > one_msg_limit:
            op.followup_chunks = [op.size] + op.followup_chunks
            piggy = 0
        else:
            piggy = op.size
            if op.size > PIGGYBACK_LIMIT:
                # eager beyond the plain piggyback limit: the payload (a
                # single parcel's, or a whole eager aggregate's) is copied
                # into a pre-registered bounce buffer — charged on the
                # dedicated calibrated mechanism.  This charge has always
                # modeled the COPY, never send-side serialization (plain
                # parcels charge no serializer on the send path in this
                # model — deserialization is charged at delivery; aggregates
                # pay t_serialize_per_byte at merge time, in
                # _send_aggregate) — it just used to borrow the
                # serializer's constant.
                yield Timeout(mech.t_bounce_copy_per_byte * op.size)
        # an eager message (whole parcel in one shot, no follow-ups) draws a
        # registered bounce buffer while in flight
        eager = cfg.eager_threshold > 0 and piggy == op.size and not op.followup_chunks
        # normalized protocol-path decision (the engine-parity trace): the
        # MPI family has no eager path, whatever its threshold default says
        self._engine.record(
            "send", "eager" if (eager and not cfg.mpi) else "rdv", len(op.followup_chunks)
        )
        # Lock discipline.  Sends take the coarse lock *blocking* even in the
        # 'try' variants — paper footnote 1: only progress can use try locks.
        locked = cfg.mpi or cfg.lock_mode in (LockMode.BLOCK, LockMode.TRY)
        if locked:
            yield from self._lock_with_contention(dev.coarse)
            if cfg.mpi:
                yield Timeout(mech.t_mpi_big_lock)
        yield Timeout(mech.t_post_send)
        yield from self._inject(dev, _Message("header", HEADER_BYTES + piggy, op, eager=eager))
        if locked:
            dev.coarse.release()
        if cfg.mpi:
            # the send request joins the shared pool; a background_work must
            # round-robin to it before its buffers are released (§3.3.2)
            self.ranks[op.src].mpi_pool.append(_MPIReq("send", op, done=True))
        self.ranks[op.src].sent += op.nparcels

    # -- bounded injection (§3.3.4) ----------------------------------------
    def _claim_slot(self, dev: _SimDevice, msg: _Message) -> bool:
        """Reserve a send-ring slot (+ bounce buffer for eager messages).
        A refusal is an EAGAIN-style backpressure event, counted on the
        device and the world."""
        cfg = self.cfg
        if cfg.send_queue_depth and dev.inflight >= cfg.send_queue_depth:
            dev.stats_backpressure += 1
            self.backpressure_events += 1
            return False
        if msg.eager and cfg.bounce_buffers > 0:
            if dev.bounce_free <= 0:
                dev.stats_backpressure += 1
                self.backpressure_events += 1
                return False
            dev.bounce_free -= 1
            dev.bounce_in_use_hw = max(dev.bounce_in_use_hw, cfg.bounce_buffers - dev.bounce_free)
        dev.inflight += 1
        dev.inflight_hw = max(dev.inflight_hw, dev.inflight)
        return True

    def _release_slot(self, dev: _SimDevice, msg: _Message) -> None:
        """Reap one send completion: free the ring slot and recycle the
        bounce buffer (the moment new injection capacity appears)."""
        dev.inflight -= 1
        if msg.eager and self.cfg.bounce_buffers > 0:
            dev.bounce_free += 1

    def _park(self, dev: _SimDevice, msg: _Message) -> None:
        dev.parked.append(msg)
        dev.parked_hw = max(dev.parked_hw, len(dev.parked))

    def _drain_parked(self, dev: _SimDevice) -> Generator:
        """Retry up to ``retry_budget`` parked posts, oldest first; stop at
        the first refusal (the fabric freed nothing — throttle instead of
        hammering, mirroring ``ParcelportBase._drain_retries``)."""
        moved = False
        for _ in range(self.cfg.retry_budget):
            if not dev.parked:
                break
            msg = dev.parked[0]
            if not self._claim_slot(dev, msg):
                yield Timeout(self.mech.t_post_eagain)
                break
            dev.parked.popleft()
            yield from self._inject_claimed(dev, msg)
            moved = True
        return moved

    def _inject(self, dev: _SimDevice, msg: _Message) -> Generator:
        """Post one message.  Unbounded devices always accept (the classic
        model).  Bounded devices may refuse EAGAIN-style: the post costs
        the failed attempt (``t_post_eagain``) and parks in the device's
        retry queue for background work to drain once completions free
        ring slots or bounce buffers."""
        if self.cfg.bounded_injection and not self._claim_slot(dev, msg):
            yield Timeout(self.mech.t_post_eagain)
            self._park(dev, msg)
            return
        yield from self._inject_claimed(dev, msg)

    def _inject_claimed(self, dev: _SimDevice, msg: _Message) -> Generator:
        """Occupy the injection channel (per-device descriptor/doorbell
        cost), queue the payload on the rank's shared wire (bandwidth is a
        per-NIC resource even with many devices), schedule the arrival."""
        plat = self.platform
        rank = dev.rank
        yield Acquire(dev.inj_lock)
        yield Timeout(plat.inj_overhead)
        dev.inj_lock.release()
        dev.stats_injected += 1
        self.msg_count += 1
        self.byte_count += msg.size
        # shared-wire DMA: the worker does not wait, the wire serializes
        now = self.env.now
        start = max(now, rank.wire_busy_until)
        done = start + msg.size / plat.bandwidth
        rank.wire_busy_until = done
        dst_rank = self.ranks[msg.parcel.dst]
        dst_dev = dst_rank.devices[msg.parcel.src_dev_idx % len(dst_rank.devices)]
        self.env.process(self._arrive_later(dst_dev, msg, done - now + plat.wire_latency))
        if self.cfg.bounded_injection:
            # the send completion lands in OUR hardware CQ once the DMA
            # drains off the ring; the slot stays occupied until progress
            # reaps it — not polling your own CQ throttles your injection,
            # exactly like the functional fabric (the engine's progress op).
            self.env.process(self._send_done_later(dev, msg, done - now))

    def _arrive_later(self, dst_dev: _SimDevice, msg: _Message, delay: float) -> Generator:
        yield Timeout(delay)
        self._admit_arrival(dst_dev, msg.kind, msg)

    # -- bounded receive: RNR (§3.1, mirrors core.fabric) -------------------
    def _admit_arrival(self, dst_dev: _SimDevice, kind: str, msg: _Message) -> None:
        """Land an arrival in the destination device's hardware CQ.  With
        ``limits.recv_slots`` set, each un-reaped arrival occupies one
        posted receive descriptor; an arrival that finds none free is a
        **receiver-not-ready** event.  Default model: parked for free
        redelivery once the receiver's progress engine reaps backlog (the
        fabric's ``_pending_sends`` + ``hw_progress`` retransmission, as
        one queue on the receiver).  ``rnr_storm`` model: retransmitted
        after an exponential backoff charged on ``t_rnr_retry`` — retry
        storms burn wire time (§3.1)."""
        rs = self.cfg.recv_slots
        if rs > 0 and dst_dev.recv_backlog >= rs:
            dst_dev.stats_rnr += 1
            self.rnr_events += 1
            if self.cfg.rnr_storm:
                self.env.process(self._rnr_retransmit(dst_dev, kind, msg, attempt=1))
            else:
                dst_dev.rnr_parked.append((kind, msg))
            return
        if rs > 0:
            dst_dev.recv_backlog += 1
        self._cq_push(dst_dev, kind, msg)

    def _rnr_retransmit(self, dst_dev: _SimDevice, kind: str, msg: _Message, attempt: int) -> Generator:
        """Storm-mode RNR retransmission: back off ``t_rnr_retry * 2^(n-1)``
        (capped at 64x), then retry admission; every attempt is counted in
        ``rnr_retries`` and every further refusal in ``rnr_events``."""
        yield Timeout(self.mech.t_rnr_retry * min(2 ** (attempt - 1), 64))
        self.rnr_retries += 1
        dst_dev.stats_rnr_retries += 1
        rs = self.cfg.recv_slots
        if dst_dev.recv_backlog >= rs:
            dst_dev.stats_rnr += 1
            self.rnr_events += 1
            self.env.process(self._rnr_retransmit(dst_dev, kind, msg, attempt + 1))
            return
        dst_dev.recv_backlog += 1
        self._cq_push(dst_dev, kind, msg)

    def _reap_arrival(self, dev: _SimDevice, kind: str) -> None:
        """Bookkeeping when a CQ entry is reaped: a consumed arrival frees
        its receive descriptor (send_done entries never held one), letting
        RNR-parked arrivals redeliver in order (default model; storm mode
        redelivers through timed retransmission instead)."""
        rs = self.cfg.recv_slots
        if rs <= 0:
            return
        if kind != "send_done":
            dev.recv_backlog -= 1
        while dev.rnr_parked and dev.recv_backlog < rs:
            pkind, pmsg = dev.rnr_parked.popleft()
            dev.recv_backlog += 1
            self._cq_push(dev, pkind, pmsg)

    def _send_done_later(self, dev: _SimDevice, msg: _Message, delay: float) -> Generator:
        yield Timeout(delay)
        self._cq_push(dev, "send_done", msg)

    # -- hardware-CQ residency: the reap-latency signal (ISSUE 8) -----------
    def _cq_push(self, dev: _SimDevice, kind: str, msg: _Message) -> None:
        """Every CQ entry is enqueue-stamped so the pop side can measure
        how long completions sat un-reaped — the latency the elastic
        controller reacts to and ``elasticity_study`` claims against."""
        dev.cq.append((kind, msg))
        dev.cq_times.append(self.env.now)

    def _cq_pop(self, dev: _SimDevice) -> Tuple[str, _Message]:
        entry = dev.cq.pop(0)
        lat = self.env.now - dev.cq_times.pop(0)
        self.reap_samples.append(lat)
        self.reap_lat_ewma += 0.2 * (lat - self.reap_lat_ewma)
        if lat > self.reap_lat_high:
            self.reap_lat_high = lat
        return entry

    # -------------------------------------------------------------- progress
    def background_work(self, worker: SimWorker, role: str = ROLE_TASK) -> Generator:
        """Drive ONE step of the shared :class:`ProgressEngine` through the
        clock/cost adapter: the engine decides the op sequence (the same
        sequence the functional parcelports execute); this driver charges
        each op's calibrated cost and simulates its lock contention.  It is
        the only place completions are reaped or dispatched — gated by
        tools/check_api.py against re-grown private loops."""
        mech, cfg, plat = self.mech, self.cfg, self.platform
        rank = worker.rank
        gen = self._engine.step(worker.wid, role)
        to_deliver: List[ParcelOp] = []
        result: Any = None
        while True:
            try:
                op = gen.send(result)
            except StopIteration as stop:
                return bool(stop.value)
            kind = op[0]
            result = None
            if kind == "reap":
                name = op[1].name
                if name == "dev_cq":
                    dev = rank.devices[op[2]]
                    if dev.cq:
                        ckind, msg = self._cq_pop(dev)
                        self._reap_arrival(dev, ckind)
                        yield Timeout(mech.t_per_completion)
                        result = (ckind, msg)
                elif name == "client_poll":
                    # client-side completion poll: queue pop is cheap; the
                    # synchronizer pool is MPI-ish (cost only — delivery is
                    # fused into the dev_cq reaps in this layer)
                    yield from self._poll_completion_objects(worker)
                elif name == "mpi_header":
                    # test the pre-posted any-source header request
                    yield Timeout(mech.t_mpi_test)
                    msg = rank.mpi_header_req
                    if msg is not None:
                        rank.mpi_header_req = (
                            rank.mpi_header_backlog.pop(0) if rank.mpi_header_backlog else None
                        )
                        result = msg
                else:  # mpi_pool: ONE request, round-robin (§3.3.2)
                    yield Timeout(mech.t_mpi_test)
                    if rank.mpi_pool:
                        req = rank.mpi_pool.pop(0)
                        if req.done:
                            result = req
                        else:
                            rank.mpi_pool.append(req)
            elif kind == "dispatch":
                name, item = op[1].name, op[3]
                if name == "dev_cq":
                    ckind, msg = item
                    yield from self._handle_completion(worker, rank.devices[op[2]], ckind, msg)
                    result = True
                elif name == "mpi_header":
                    yield Timeout(mech.t_tag_match + mech.t_post_recv)  # match + re-post
                    self._engine.record("header", "rdv")
                    pop = item.parcel
                    if pop.followup_chunks:
                        req = _MPIReq("recv", pop)
                        pop.mpi_recv_req = req
                        rank.mpi_pool.append(req)
                        yield Timeout(mech.t_post_recv)
                        self._spawn_followup(pop)
                    else:
                        to_deliver.append(pop)
                    result = True
                else:  # mpi_pool
                    req = item
                    if req.kind == "followup_gate":
                        self.env.process(self._mpi_rts(req.op))
                    elif req.kind == "cts_gate":
                        self.env.process(self._mpi_cts(req.op))
                    elif req.kind == "recv":
                        self._engine.record("chunk")
                        pop = req.op
                        pop.chunk_idx += 1
                        if pop.chunk_idx < len(pop.followup_chunks):
                            nreq = _MPIReq("recv", pop)
                            pop.mpi_recv_req = nreq
                            rank.mpi_pool.append(nreq)
                            yield Timeout(mech.t_post_recv)
                            self._spawn_followup(pop)
                        else:
                            to_deliver.append(pop)
                    result = True
            elif kind == "reap_begin":
                if op[1].name == "dev_cq":
                    if plat.libfabric_cq_lock:
                        # Slingshot-11: libfabric serializes CQ polling on a
                        # spin lock — 85% of Octo-Tiger time on Delta/32
                        # nodes (paper §4.2.3).
                        yield from self._lock_with_contention(rank.lf_lock)
                        yield Timeout(plat.progress_lock_cost)
                    yield Timeout(mech.t_progress_poll)
            elif kind == "reap_end":
                if op[1].name == "dev_cq" and plat.libfabric_cq_lock:
                    rank.lf_lock.release()
            elif kind == "drain_retries":
                dev = rank.device_for_worker(worker.wid)
                if dev.parked:
                    result = yield from self._drain_parked(dev)
            elif kind == "progress":
                # LCI: the hardware CQ *is* the completion source, so the
                # explicit-progress op is fused into the dev_cq reaps; the
                # MPI library drains hardware arrivals into MPI-internal
                # state here (noticed later, one MPI_Test at a time).
                if cfg.mpi:
                    result = yield from self._mpi_drain_hw(rank.devices[op[1]])
            elif kind == "implicit_tax":
                # implicit progress rides on a (possibly failed) completion
                # test: charge one test per step (progress at reduced rate)
                yield Timeout(mech.t_sync_test)
            elif kind == "dev_lock":
                yield from self._lock_with_contention(rank.devices[op[1]].coarse)
            elif kind == "dev_trylock":
                if rank.devices[op[1]].coarse.try_acquire():
                    result = True
                else:
                    yield Timeout(mech.t_try_fail)
            elif kind == "dev_unlock":
                rank.devices[op[1]].coarse.release()
            elif kind == "step_trylock":
                # MPI request-pool discipline: concurrent testing of a
                # shared request is disallowed (MPI 4.1 §12.6.2)
                if rank.pool_lock.try_acquire():
                    result = True
                else:
                    yield Timeout(mech.t_try_fail)
            elif kind == "step_unlock":
                rank.pool_lock.release()
            elif kind == "big_lock":
                yield from self._lock_with_contention(rank.devices[0].coarse)
            elif kind == "big_unlock":
                rank.devices[0].coarse.release()
            elif kind == "flush":
                # handle_parcel runs outside the library locks (MPI)
                for pop in to_deliver:
                    yield from self._deliver(worker, pop)
                to_deliver.clear()
            # "poll": nothing to charge — LCI's completion-test-driven
            # progress is the dev_cq reap itself (taxed by implicit_tax)

    def _mpi_drain_hw(self, dev: _SimDevice) -> Generator:
        """The MPI library's implicit progress (the engine's ``progress``
        op, §3.3.4): drain hardware arrivals into MPI-internal completion
        state.  Completion of a specific request is only *noticed* later,
        when its turn comes up in the round-robin MPI_Test (§3.3.2)."""
        mech = self.mech
        rank = dev.rank
        while dev.cq:
            ckind, msg = self._cq_pop(dev)
            self._reap_arrival(dev, ckind)
            yield Timeout(mech.t_per_completion)
            if ckind == "send_done":
                self._release_slot(dev, msg)
            elif ckind == "header":
                if rank.mpi_header_req is None:
                    rank.mpi_header_req = msg  # matches the pre-posted recv
                else:
                    rank.mpi_header_backlog.append(msg)  # unexpected queue
            else:
                msg.parcel.mpi_recv_req.done = True
        return False

    def _handle_completion(self, worker: SimWorker, dev: _SimDevice, kind: str, msg: _Message) -> Generator:
        """Dispatch-by-kind for one reaped completion — called ONLY from
        the engine driver (`background_work`), never from private loops."""
        mech, cfg = self.mech, self.cfg
        op = msg.parcel
        rank = worker.rank
        if kind == "send_done":
            # reaping the send completion frees the ring slot / bounce
            # buffer (bounded-injection mode only; t_per_completion already
            # charged by the engine's reap op)
            self._release_slot(dev, msg)
            return
        if kind == "header":
            self._engine.record("header", "eager" if (msg.eager and not cfg.mpi) else "rdv")
            if cfg.header_mode == "put":
                if cfg.header_comp == "sync":
                    # put-signal (§3.3.1, the middle capability-ladder
                    # rung): the receiver discovers the put by scanning
                    # raised per-slot signal flags — no queue machinery,
                    # but the scan is a serialized sweep (one discoverer
                    # at a time), like the functional ShmemSegment's
                    # claim_signals under the slab lock
                    yield Acquire(rank.match_lock)
                    yield Timeout(mech.t_put_signal + mech.t_sync_signal + mech.t_sync_test)
                    rank.match_lock.release()
                else:
                    # put + queue-completion: no matching; the descriptor
                    # goes straight into the client's completion ring
                    yield Timeout(mech.t_put_deliver)
                    yield from self._cq_cost(rank, "push", dev)
                    yield from self._cq_cost(rank, "pop", dev)
            else:
                # two-sided: the matching→signaling path is a sequential
                # bottleneck (§3.3.1) — serialized, but with no futex storm
                yield Acquire(rank.match_lock)
                yield Timeout(mech.t_tag_match + mech.t_post_recv)  # match + re-post
                if cfg.header_comp == "sync":
                    # one pre-posted receive at a time; cheap 4 B signal
                    yield Timeout(mech.t_sync_signal + mech.t_sync_test)
                else:
                    yield from self._cq_cost(rank, "push", dev)
                    yield from self._cq_cost(rank, "pop", dev)
                rank.match_lock.release()
            if op.followup_chunks:
                # rendezvous: allocate zc buffers, post the receive for the
                # *first* chunk, then the sender streams it (chunks of one
                # parcel are strictly sequential, §3.2)
                yield Timeout(mech.t_post_recv)
                self._spawn_followup(op)
            else:
                yield from self._deliver(worker, op)
        else:  # followup chunk op.chunk_idx completed at the receiver
            self._engine.record("chunk")
            yield Timeout(mech.t_tag_match)
            if cfg.followup_comp == "sync":
                # request-pool detection: the completion is only *noticed*
                # by round-robin testing under the pool try-lock (§3.3.2) —
                # serialized, with the wasted tests on not-yet-ready
                # requests (~pool length/2 per detection) amortized in
                yield Acquire(rank.pool_lock)
                yield Timeout(mech.t_sync_signal + 32 * mech.t_sync_test)
                rank.pool_lock.release()
            else:
                yield from self._cq_cost(rank, "push", dev)
                yield from self._cq_cost(rank, "pop", dev)
            op.chunk_idx += 1
            if op.chunk_idx < len(op.followup_chunks):
                yield Timeout(mech.t_post_recv)
                self._spawn_followup(op)
            else:
                yield from self._deliver(worker, op)

    def _spawn_followup(self, op: ParcelOp) -> None:
        if self.cfg.mpi:
            # MPI large-message rendezvous, progress-gated at every step
            # (§3.3.2/§3.3.4): the sender notices the prior chunk's send
            # completion through its round-robin pool ('followup_gate'),
            # sends RTS; the receiver's progress engine matches it and
            # answers CTS ('cts_gate' on the receiver pool); only then does
            # the data move.  Every hop costs a serialized MPI_Test slot.
            self.ranks[op.src].mpi_pool.append(_MPIReq("followup_gate", op, done=True))
            return
        sdev = self.ranks[op.src].devices[op.src_dev_idx % self.cfg.ndevices]
        self.env.process(self._send_followup(sdev, op))

    def _mpi_rts(self, op: ParcelOp) -> Generator:
        """RTS wire hop, then the CTS gate joins the receiver's pool."""
        yield Timeout(self.platform.wire_latency)
        self.ranks[op.dst].mpi_pool.append(_MPIReq("cts_gate", op, done=True))

    def _mpi_cts(self, op: ParcelOp) -> Generator:
        """CTS wire hop, then the sender's NIC streams the chunk."""
        yield Timeout(self.platform.wire_latency)
        sdev = self.ranks[op.src].devices[0]
        yield from self._send_followup(sdev, op)

    def _send_followup(self, sdev: _SimDevice, op: ParcelOp) -> Generator:
        yield Timeout(self.mech.t_post_send)
        yield from self._inject(sdev, _Message("followup", op.followup_chunks[op.chunk_idx], op))

    def _deliver(self, worker: SimWorker, op: ParcelOp) -> Generator:
        """handle_parcel: deserialize + hand the task(s) to the scheduler."""
        mech = self.mech
        self._engine.record("deliver", op.nparcels)
        yield Timeout(mech.t_handle_parcel * op.nparcels + mech.t_serialize_per_byte * op.total_app_bytes)
        worker.rank.handled += op.nparcels
        if op.on_delivered is not None:
            op.on_delivered()

    def _cq_cost(self, rank: SimRank, what: str, dev: Optional[_SimDevice] = None) -> Generator:
        """LCI completion-queue op cost + concurrency penalty (§5.2).

        The contention pool follows the router's topology (§3.3.3):
        ``cq_scope='shared'`` counts accessors per rank (one queue across
        devices); ``'device'`` scopes them to the device's own queue."""
        mech, kind = self.mech, self.cfg.cq_kind
        base = (mech.t_cq_push if what == "push" else mech.t_cq_pop)[kind]
        holder = dev if (dev is not None and self.cfg.cq_scope == "device") else rank
        holder.cq_accessors += 1
        penalty = mech.cq_contention[kind] * max(0, holder.cq_accessors - 1)
        yield Timeout(base + penalty)
        holder.cq_accessors -= 1

    def _poll_completion_objects(self, worker: SimWorker) -> Generator:
        mech, cfg = self.mech, self.cfg
        if cfg.followup_comp == "queue":
            yield from self._cq_cost(worker.rank, "pop", worker.rank.device_for_worker(worker.wid))
            return
        # synchronizer pool: try-lock + one round-robin test (§3.3.2)
        if not worker.rank.pool_lock.try_acquire():
            yield Timeout(mech.t_try_fail)
            return
        yield Timeout(mech.t_sync_test)
        worker.rank.pool_lock.release()

    # ------------------------------------------------------------------ API
    def spawn(self, rank: int, task: Task) -> None:
        self.ranks[rank].runq.put(task)

    def make_parcel(
        self,
        src: int,
        dst: int,
        size: int,
        on_delivered: Optional[Callable[[], None]] = None,
    ) -> ParcelOp:
        return ParcelOp(src=src, dst=dst, size=size, on_delivered=on_delivered)

    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> None:
        self.env.run(until=until, max_events=max_events)

    def stop(self) -> None:
        self.stopped = True
