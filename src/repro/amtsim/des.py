"""A minimal process-based discrete-event simulation kernel.

Generator processes yield simulation primitives:

* ``Timeout(dt)``       — resume after ``dt`` simulated seconds,
* ``Acquire(lock)``     — resume once the FIFO lock is held,
* ``Get(store)``        — resume with the next item from a store,
* ``Wait(event)``       — resume once the event fires.

Locks also expose ``try_acquire()`` (immediate, no yield) for try-lock
modeling.  The kernel is deliberately tiny — just enough to model thread
contention, queue service, and message timing for the parcelport study.

**What is modeled:** virtual time, deterministic event ordering (ties break
by schedule order), FIFO lock hand-off with a contention counter, and
queue-occupancy high-water marks on stores (the observability hook the
bounded-injection model reports through).  **What is abstracted away:**
preemption (a process runs until it yields), memory hierarchy, and real OS
scheduling — their *costs* are charged explicitly by the layer above
(:mod:`repro.amtsim.costs`), never inferred here.

Determinism is a contract: two runs of the same workload produce identical
event sequences, which the test suite asserts and the benchmark claims rely
on.  Resource *boundedness* is likewise not this kernel's job — finite send
rings and bounce pools live in :mod:`repro.amtsim.parcelport_sim`, which
models refusal/park/retry with plain state plus ``Timeout`` charges.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Any, Callable, Deque, Generator, List, Optional, Tuple

__all__ = ["Env", "Timeout", "Acquire", "Get", "Wait", "Event", "Lock", "Store"]


class Timeout:
    __slots__ = ("dt",)

    def __init__(self, dt: float):
        self.dt = dt


class Event:
    """One-shot event; processes may Wait() on it, a value rides along."""

    __slots__ = ("fired", "value", "_waiters", "env")

    def __init__(self, env: "Env"):
        self.env = env
        self.fired = False
        self.value: Any = None
        self._waiters: List[Generator] = []

    def fire(self, value: Any = None) -> None:
        if self.fired:
            return
        self.fired = True
        self.value = value
        for proc in self._waiters:
            self.env._resume(proc, value)
        self._waiters.clear()


class Wait:
    __slots__ = ("event",)

    def __init__(self, event: Event):
        self.event = event


class Lock:
    """FIFO mutex."""

    __slots__ = ("env", "held", "_waiters", "contentions", "acquisitions")

    def __init__(self, env: "Env"):
        self.env = env
        self.held = False
        self._waiters: Deque[Generator] = deque()
        self.contentions = 0
        self.acquisitions = 0

    def try_acquire(self) -> bool:
        if self.held:
            self.contentions += 1
            return False
        self.held = True
        self.acquisitions += 1
        return True

    def release(self) -> None:
        assert self.held
        if self._waiters:
            proc = self._waiters.popleft()
            self.acquisitions += 1
            self.env._resume(proc, None)
        else:
            self.held = False


class Acquire:
    __slots__ = ("lock",)

    def __init__(self, lock: Lock):
        self.lock = lock


class Store:
    """Unbounded FIFO store; Get blocks until an item arrives.

    Tracks its occupancy high-water mark (``max_depth``) so models built on
    top can report queue-depth statistics — e.g. run-queue backlog or the
    parcelport's aggregation queues — without instrumenting every put."""

    __slots__ = ("env", "items", "_getters", "max_depth")

    def __init__(self, env: "Env"):
        self.env = env
        self.items: Deque[Any] = deque()
        self._getters: Deque[Generator] = deque()
        self.max_depth = 0

    def put(self, item: Any) -> None:
        if self._getters:
            proc = self._getters.popleft()
            self.env._resume(proc, item)
        else:
            self.items.append(item)
            if len(self.items) > self.max_depth:
                self.max_depth = len(self.items)

    def get_nowait(self) -> Optional[Any]:
        if self.items:
            return self.items.popleft()
        return None

    def __len__(self) -> int:
        return len(self.items)


class Get:
    __slots__ = ("store",)

    def __init__(self, store: Store):
        self.store = store


class Env:
    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[Tuple[float, int, Generator, Any]] = []
        self._ids = itertools.count()
        self._nproc = 0

    # -- process management ---------------------------------------------------
    def process(self, gen: Generator) -> Generator:
        self._nproc += 1
        self._schedule(0.0, gen, None)
        return gen

    def _schedule(self, delay: float, gen: Generator, value: Any) -> None:
        heapq.heappush(self._heap, (self.now + delay, next(self._ids), gen, value))

    def _resume(self, gen: Generator, value: Any) -> None:
        self._schedule(0.0, gen, value)

    def timeout_event(self, dt: float) -> Event:
        ev = Event(self)
        dummy = self._fire_later(ev)
        self._schedule(dt, dummy, None)
        return ev

    @staticmethod
    def _fire_later(ev: Event) -> Generator:
        def g():
            ev.fire()
            return
            yield  # pragma: no cover - makes this a generator

        return g()

    # -- main loop --------------------------------------------------------------
    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> None:
        n = 0
        while self._heap and n < max_events:
            t, _i, gen, value = heapq.heappop(self._heap)
            if t > until:
                self.now = until
                return
            self.now = t
            n += 1
            try:
                cmd = gen.send(value)
            except StopIteration:
                continue
            self._dispatch(gen, cmd)
        if n >= max_events:
            raise RuntimeError("DES event budget exceeded (livelock?)")

    def _dispatch(self, gen: Generator, cmd: Any) -> None:
        if isinstance(cmd, Timeout):
            self._schedule(cmd.dt, gen, None)
        elif isinstance(cmd, Acquire):
            lock = cmd.lock
            if lock.held:
                lock.contentions += 1
                lock._waiters.append(gen)
            else:
                lock.held = True
                lock.acquisitions += 1
                self._resume(gen, None)
        elif isinstance(cmd, Get):
            store = cmd.store
            if store.items:
                self._resume(gen, store.items.popleft())
            else:
                store._getters.append(gen)
        elif isinstance(cmd, Wait):
            ev = cmd.event
            if ev.fired:
                self._resume(gen, ev.value)
            else:
                ev._waiters.append(gen)
        else:  # pragma: no cover - defensive
            raise TypeError(f"process yielded unknown command {cmd!r}")
