from .analysis import HW, RooflineCell, analyze_cell, format_table, load_cells, model_flops
from .hlo_parse import HLOAnalysis, analyze_hlo

__all__ = [
    "HW",
    "RooflineCell",
    "analyze_cell",
    "format_table",
    "load_cells",
    "model_flops",
    "HLOAnalysis",
    "analyze_hlo",
]
