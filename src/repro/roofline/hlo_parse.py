"""Post-SPMD HLO analysis: collective bytes and matmul FLOPs, loop-aware.

``compiled.as_text()`` represents ``lax.scan`` as a ``while`` op whose body
is a separate computation; naive text scans (and XLA's own cost analysis on
CPU) count such bodies ONCE, undercounting a 28-layer model by ~28×.  This
parser:

1. splits the HLO module into computations,
2. recovers each while loop's trip count from its condition computation
   (induction variable compared against a constant),
3. propagates execution multipliers through the while-body call graph
   (nested scans multiply),
4. sums collective operand bytes and dot FLOPs per computation ×
   multiplier.

Used by the dry-run to produce the §Roofline terms.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HLOAnalysis", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_info(type_str: str) -> Tuple[int, int]:
    """(total bytes, total elements) across every dtype[dims] in the string."""
    total_b = 0
    total_n = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_n += n
    return total_b, total_n


@dataclass
class _Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # op name → type string


@dataclass
class HLOAnalysis:
    collective_bytes: Dict[str, int]
    dot_flops: float
    dot_bytes: float  # operand+output bytes of dots
    hbm_bytes: float  # Σ output bytes of materializing ops ×2 (write+read)
    while_trip_counts: Dict[str, int]
    n_collectives: int

    @property
    def total_collective_bytes(self) -> int:
        return sum(self.collective_bytes.values())


# ops that do not materialize a new HBM buffer
_NO_MATERIALIZE = (
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
)


def _split_computations(hlo: str) -> List[_Computation]:
    comps: List[_Computation] = []
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m:
                cur = _Computation(m.group(1))
            continue
        if s == "}" or s.startswith("} "):
            comps.append(cur)
            cur = None
            continue
        cur.lines.append(s)
        if "=" in s and s.startswith("%"):
            name = s.split("=", 1)[0].strip().lstrip("%").rstrip()
            typ = s.split("=", 1)[1].strip()
            # type string is everything before the op name token
            cur.shapes[name] = typ
    if cur is not None:
        comps.append(cur)
    return comps


def _trip_count(cond: _Computation) -> Optional[int]:
    """Recover the loop bound: a compare against an integer constant."""
    consts: Dict[str, int] = {}
    for ln in cond.lines:
        m = re.match(r"%([\w\.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond.lines:
        if "compare(" in ln and ("direction=LT" in ln or "direction=GT" in ln):
            args = re.findall(r"%([\w\.\-]+)", ln.split("compare(", 1)[1])
            for a in args:
                if a in consts:
                    return consts[a]
    # fallback: any constant in the condition
    if consts:
        return max(consts.values())
    return None


def _op_type_of(comp: _Computation, opname: str) -> str:
    t = comp.shapes.get(opname, "")
    return t


def analyze_hlo(hlo: str) -> HLOAnalysis:
    comps = _split_computations(hlo)
    by_name = {c.name: c for c in comps}

    # 1. find while loops: (owner computation, cond, body) + trip counts —
    #    preferring XLA's own known_trip_count backend_config
    whiles: List[Tuple[str, str, str]] = []
    trip: Dict[str, int] = {}
    for c in comps:
        for ln in c.lines:
            if not re.search(r"\bwhile\(", ln):
                continue
            m = _WHILE_RE.search(ln)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            whiles.append((c.name, cond, body))
            tm = _TRIP_RE.search(ln)
            if tm:
                trip[body] = int(tm.group(1))
            else:
                tc = _trip_count(by_name[cond]) if cond in by_name else None
                trip[body] = tc if tc is not None else 1

    # 2. multipliers: body multiplier = owner multiplier × trip count
    mult: Dict[str, float] = {c.name: 1.0 for c in comps}
    # iterate to fixpoint (nesting depth is tiny)
    for _ in range(8):
        changed = False
        for owner, _cond, body in whiles:
            want = mult.get(owner, 1.0) * trip.get(body, 1)
            if mult.get(body) != want:
                mult[body] = want
                changed = True
        if not changed:
            break

    # computations reachable only via fusion/call inherit the caller's
    # multiplier; collectives/dots never hide inside fusions, and calls
    # are rare — skipped deliberately (documented methodology).

    coll: Dict[str, int] = {}
    n_coll = 0
    dot_flops = 0.0
    dot_bytes = 0.0
    hbm_bytes = 0.0
    # fusion/call bodies execute with their caller; approximate by giving
    # non-while computations the max multiplier of any while body that
    # (transitively) references them — conservative and cheap: collectives
    # and dots never hide inside fusions, so only hbm_bytes is affected.
    for c in comps:
        m = mult.get(c.name, 1.0)
        for ln in c.lines:
            if "=" not in ln:
                continue
            rhs = ln.split("=", 1)[1].strip()
            opm = re.match(r"(.+?)\s+([a-z][a-z0-9\-]*)\(", rhs)
            if opm and opm.group(2) not in _NO_MATERIALIZE:
                b, _ = _shape_info(opm.group(1))
                hbm_bytes += 2.0 * b * m  # written once, read ~once
            # --- collectives ---
            for op in _COLLECTIVES:
                if re.search(rf"\s{op}(?:-start)?\(", " " + rhs):
                    tstr = rhs.split(op)[0]
                    b, _ = _shape_info(tstr)
                    if b:
                        coll[op] = coll.get(op, 0) + int(b * m)
                        n_coll += 1
                    break
            # --- dots ---
            dm = re.search(r"\sdot\(([^)]*)\)", " " + rhs)
            if dm:
                out_t = rhs.split("dot(")[0]
                _, out_n = _shape_info(out_t)
                ob, _ = _shape_info(out_t)
                args = [a.strip().lstrip("%") for a in dm.group(1).split(",")][:2]
                # contraction size: lhs elements / (out elements / rhs free)
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                lhs_t = _op_type_of(c, args[0]) if args else ""
                rhs_t = _op_type_of(c, args[1]) if len(args) > 1 else ""
                lb, ln_ = _shape_info(lhs_t)
                rb, _ = _shape_info(rhs_t)
                k = 1
                if cdims is not None and lhs_t:
                    dims_m = _SHAPE_RE.search(lhs_t)
                    if dims_m and dims_m.group(2):
                        lhs_dims = [int(d) for d in dims_m.group(2).split(",")]
                        for di in cdims.group(1).split(","):
                            if di != "" and int(di) < len(lhs_dims):
                                k *= lhs_dims[int(di)]
                dot_flops += 2.0 * out_n * k * m
                dot_bytes += (ob + lb + rb) * m
    return HLOAnalysis(
        collective_bytes=coll,
        dot_flops=dot_flops,
        dot_bytes=dot_bytes,
        hbm_bytes=hbm_bytes,
        while_trip_counts=trip,
        n_collectives=n_coll,
    )
