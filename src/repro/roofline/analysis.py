"""Three-term roofline from dry-run artifacts (TPU v5e constants).

For every compiled (arch × shape × mesh) cell::

    compute    = HLO_dot_FLOPs_per_device / PEAK_FLOPS          [s]
    memory     = HLO_dot_bytes_per_device / HBM_BW              [s]
    collective = collective_bytes_per_device / ICI_LINK_BW      [s]

Methodology notes (documented, consistent across cells):

* FLOPs/bytes come from the loop-aware HLO parse
  (:mod:`repro.roofline.hlo_parse`) — XLA's own ``cost_analysis`` counts
  ``lax.scan`` bodies once and undercounts deep models by ~n_layers×.
* ``dot`` operand+output bytes are the memory-traffic proxy: matmul
  traffic dominates and fused elementwise rides along; this makes the
  memory term a *floor*.
* collective bytes are per-device program bytes (each op's result shape),
  divided by one ICI link — a deliberately conservative single-link model;
  multi-link speedup is an optimization the §Perf log must earn by
  splitting traffic across mesh axes.
* MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (inference)
  — the "useful work" yardstick; ``flops_ratio`` = MODEL/HLO catches
  remat and padding waste; ``roofline_fraction`` = ideal-compute-time /
  dominant-term-time is the headline score.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, List, Optional

from ..configs import SHAPES, get_config

__all__ = ["HW", "RooflineCell", "analyze_cell", "load_cells", "format_table"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_link_bw: float = 50e9  # bytes/s per link


DEFAULT_HW = HW()


@dataclass
class RooflineCell:
    cell: str
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    hlo_flops_global: float
    flops_ratio: float  # MODEL / HLO (useful fraction of compiled compute)
    roofline_fraction: float  # ideal compute time / dominant term
    note: str = ""

    @property
    def dominant_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops(arch_name: str, shape_name: str) -> float:
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    n = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per request


def decode_min_bytes(arch_name: str, shape_name: str) -> float:
    """Bandwidth floor for one decode step: every active parameter and the
    live KV/state cache must stream from HBM at least once (global bytes)."""
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    param_bytes = 2.0 * arch.active_param_count()
    cache = 0.0
    L = arch.n_layers
    if arch.has_ssm:
        d_in = arch.ssm_expand * arch.d_model
        heads = d_in // arch.ssm_head_dim
        cache += L * b * heads * arch.ssm_head_dim * arch.ssm_state * 2  # SSM state
        n_attn = (L + arch.attn_every - 1) // arch.attn_every if arch.attn_every else 0
    else:
        n_attn = L
    if arch.attn_kind == "mla":
        cache += n_attn * b * s * (arch.kv_lora_rank + arch.rope_head_dim) * 2
    elif n_attn:
        slots = s
        if arch.attn_kind in ("swa", "chunked") and arch.window and not arch.global_every:
            slots = min(s, arch.window)
        if arch.global_every:  # mixed: local layers bounded, global layers full
            n_local = n_attn - n_attn // arch.global_every
            n_glob = n_attn // arch.global_every
            cache += (n_local * min(s, arch.window) + n_glob * s) * b * arch.n_kv_heads * arch.resolved_head_dim * 2 * 2
        else:
            cache += n_attn * b * slots * arch.n_kv_heads * arch.resolved_head_dim * 2 * 2
    return param_bytes + cache


def analyze_cell(rec: Dict, hw: HW = DEFAULT_HW) -> Optional[RooflineCell]:
    if rec.get("status") != "ok":
        return None
    nd = rec["n_devices"]
    dot_flops = rec.get("dot_flops", 0.0)  # per device
    # memory term: loop-aware materialized-op bytes when available (reflects
    # XLA fusion decisions); dot operand/output bytes as the fallback floor
    mem_bytes = rec.get("hbm_bytes") or rec.get("dot_bytes", 0.0)
    coll = sum(rec.get("collective_bytes", {}).values())
    compute_s = dot_flops / hw.peak_flops
    memory_s = mem_bytes / hw.hbm_bw
    collective_s = coll / hw.ici_link_bw
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = dot_flops * nd
    dom = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda t: t[1],
    )[0]
    # ideal time: compute floor, plus the bandwidth floor for decode
    ideal = mf / nd / hw.peak_flops
    if rec["kind"] == "decode":
        ideal = max(ideal, decode_min_bytes(rec["arch"], rec["shape"]) / nd / hw.hbm_bw)
    dominant = max(compute_s, memory_s, collective_s)
    return RooflineCell(
        cell=rec["cell"],
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        kind=rec["kind"],
        n_devices=nd,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dom,
        model_flops_global=mf,
        hlo_flops_global=hlo_global,
        flops_ratio=mf / hlo_global if hlo_global else 0.0,
        roofline_fraction=ideal / dominant if dominant else 0.0,
    )


def load_cells(dry_dir: str, mesh_filter: Optional[str] = None, hw: HW = DEFAULT_HW) -> List[RooflineCell]:
    out = []
    for p in sorted(Path(dry_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        c = analyze_cell(rec, hw)
        if c is not None:
            out.append(c)
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:6.1f}ms"
    return f"{x*1e6:6.0f}µs"


def format_table(cells: List[RooflineCell]) -> str:
    hdr = (
        "| cell | mesh | compute | memory | collective | dominant | MODEL/HLO | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for c in cells:
        rows.append(
            f"| {c.arch}×{c.shape} | {c.mesh} | {_fmt_s(c.compute_s)} | {_fmt_s(c.memory_s)} "
            f"| {_fmt_s(c.collective_s)} | **{c.dominant}** | {c.flops_ratio:.2f} | {c.roofline_fraction:.2%} |"
        )
    return hdr + "\n".join(rows) + "\n"
