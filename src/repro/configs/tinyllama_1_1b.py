"""TinyLlama-1.1B — llama2-architecture small model [arXiv:2401.02385; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    source="[arXiv:2401.02385; hf]",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    attn_kind="full",
    rope_theta=1e4,
)

SMOKE = CONFIG.variant(
    name="tinyllama-1.1b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
)
