"""Zamba2-1.2B — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="[arXiv:2411.15242; hf]",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=64,
    attn_every=6,  # the shared attention+MLP block fires every 6th layer
    attn_kind="swa",  # serving: the shared block keeps a bounded SWA cache
    window=4096,
)

SMOKE = CONFIG.variant(
    name="zamba2-1.2b-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
    attn_every=2,
    window=16,
)
