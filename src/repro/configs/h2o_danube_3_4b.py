"""H2O-Danube-3-4B — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="[arXiv:2401.16818; unverified]",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    attn_kind="swa",
    window=4096,
    rope_theta=1e4,
)

SMOKE = CONFIG.variant(
    name="h2o-danube-3-4b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    window=16,
)
