"""MiniCPM3-4B — Multi-head Latent Attention (MLA)
[hf:openbmb/MiniCPM3-4B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    source="[hf:openbmb/MiniCPM3-4B; hf]",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,  # MLA: per-head K/V reconstructed from the latent
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    nope_head_dim=64,
    v_head_dim=64,
    head_dim=96,  # nope + rope
    rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = CONFIG.variant(
    name="minicpm3-4b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    q_lora_rank=32,
    kv_lora_rank=16,
    rope_head_dim=8,
    nope_head_dim=16,
    v_head_dim=16,
    head_dim=24,
)
