"""Qwen2-7B — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    source="[arXiv:2407.10671; hf]",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    attn_kind="full",
)

SMOKE = CONFIG.variant(
    name="qwen2-7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
)
