"""Registry: ``--arch <id>`` → ArchConfig (full or smoke-reduced)."""
from __future__ import annotations

from typing import Dict, List

from .base import ArchConfig, SHAPES, ShapeConfig, cell_is_applicable
from . import (
    qwen2_7b,
    h2o_danube_3_4b,
    minicpm3_4b,
    tinyllama_1_1b,
    whisper_large_v3,
    mamba2_130m,
    zamba2_1_2b,
    internvl2_76b,
    deepseek_moe_16b,
    llama4_scout_17b_a16e,
)

__all__ = ["ARCHS", "get_config", "get_smoke_config", "list_archs", "all_cells"]

_MODULES = [
    qwen2_7b,
    h2o_danube_3_4b,
    minicpm3_4b,
    tinyllama_1_1b,
    whisper_large_v3,
    mamba2_130m,
    zamba2_1_2b,
    internvl2_76b,
    deepseek_moe_16b,
    llama4_scout_17b_a16e,
]

ARCHS: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES: Dict[str, ArchConfig] = {m.CONFIG.name: m.SMOKE for m in _MODULES}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke_config(name: str) -> ArchConfig:
    if name not in SMOKES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(SMOKES)}")
    return SMOKES[name]


def list_archs() -> List[str]:
    return list(ARCHS)


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape, applicable, reason) for the 40 assigned cells."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = cell_is_applicable(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, reason
