"""Mamba2-130M — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    source="[arXiv:2405.21060; unverified]",
    n_layers=24,
    d_model=768,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=64,
    tie_embeddings=True,
)

SMOKE = CONFIG.variant(
    name="mamba2-130m-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
)
