from .base import ArchConfig, ShapeConfig, SHAPES, cell_is_applicable
from .registry import ARCHS, SMOKES, get_config, get_smoke_config, list_archs, all_cells

__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "cell_is_applicable",
    "ARCHS",
    "SMOKES",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "all_cells",
]
