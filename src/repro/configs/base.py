"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` in its own module
(``repro/configs/<id>.py``) exposing ``CONFIG`` (the exact public config)
and ``SMOKE`` (a reduced same-family config for CPU tests).  The registry
(:mod:`repro.configs.registry`) resolves ``--arch <id>`` strings.

Shapes are global (:data:`SHAPES`): each assigned architecture runs the
same four shape cells, with per-family skips resolved by
:func:`cell_is_applicable` (documented in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "cell_is_applicable"]


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str  # 'dense' | 'audio' | 'ssm' | 'hybrid' | 'vlm' | 'moe'
    source: str = ""  # provenance note "[arXiv:...; tier]"

    # trunk dimensions
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 → d_model // n_heads

    # attention flavour
    attn_kind: str = "full"  # 'full' | 'swa' | 'chunked' | 'mla'
    window: int = 0  # SWA window / chunk length
    global_every: int = 0  # chunked: every k-th layer is full attention
    qkv_bias: bool = False
    rope_theta: float = 1e4

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64  # SSD chunk length
    attn_every: int = 0  # hybrid: shared attention block every k layers

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed frame count from the (stubbed) frontend

    # modality frontend stub ('none' | 'audio' | 'vision')
    frontend: str = "none"
    n_prefix_tokens: int = 0  # vision: patch tokens prepended to the text

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    gated_ffn: bool = True  # SwiGLU (3 mats) vs classic GELU (2 mats)
    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this architecture hold a 500k-token context?  True for SSM,
        hybrid (bounded attention cache), SWA, and chunked attention."""
        return self.has_ssm or self.attn_kind in ("swa", "chunked")

    def param_count(self) -> int:
        """Total parameters (embedding + trunk), for MODEL_FLOPS."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed)."""
        return _param_count(self, active_only=True)

    def variant(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def _attn_params(cfg: ArchConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.attn_kind == "mla":
        p = d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
        p += d * (cfg.kv_lora_rank + cfg.rope_head_dim)
        p += cfg.kv_lora_rank * cfg.n_heads * (cfg.nope_head_dim + cfg.v_head_dim)
        p += cfg.n_heads * cfg.v_head_dim * d
        return p
    q = d * cfg.n_heads * hd
    kv = 2 * d * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * d
    bias = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + bias


def _ffn_params(cfg: ArchConfig, d_ff: int) -> int:
    mats = 3 if cfg.gated_ffn else 2  # SwiGLU: gate+up+down / GELU: up+down
    return mats * cfg.d_model * d_ff


def _ssm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n_heads = d_in // cfg.ssm_head_dim
    n_groups = 1
    conv_dim = d_in + 2 * n_groups * cfg.ssm_state
    p = d * (2 * d_in + 2 * n_groups * cfg.ssm_state + n_heads)  # in_proj
    p += conv_dim * cfg.ssm_conv  # depthwise conv
    p += n_heads * 2  # A_log, D
    p += d_in * d  # out_proj
    return p


def _layer_params(cfg: ArchConfig, layer: int) -> int:
    d = cfg.d_model
    norm = 2 * d
    if cfg.family == "ssm":
        return _ssm_params(cfg) + norm
    if cfg.family == "hybrid":
        # zamba2-style: mamba-only layers; attention+MLP live in the single
        # *shared* block, counted once in _param_count
        return _ssm_params(cfg) + norm
    if cfg.is_moe:
        experts = cfg.n_experts * _ffn_params(cfg, cfg.d_ff)
        shared = cfg.n_shared_experts * _ffn_params(cfg, cfg.d_ff)
        router = d * cfg.n_experts
        return _attn_params(cfg) + experts + shared + router + norm
    return _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff) + norm


def _param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    n_dec = cfg.n_layers
    for layer in range(n_dec):
        p = _layer_params(cfg, layer)
        if active_only and cfg.is_moe:
            act = (cfg.n_shared_experts + cfg.top_k) * _ffn_params(cfg, cfg.d_ff)
            p = _attn_params(cfg) + act + d * cfg.n_experts + 2 * d
        total += p
    if cfg.family == "hybrid" and cfg.attn_every:
        total += _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff) + 2 * d  # shared block
    if cfg.is_encdec:
        for _ in range(cfg.encoder_layers):
            total += _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff) + 2 * d
        # decoder cross-attention
        total += cfg.n_layers * (_attn_params(cfg) + d)
    total += d  # final norm
    return total


# ---------------------------------------------------------------------- shapes
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason) for an (arch × shape) cell — the documented skips."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: 500k KV cache is quadratic-cost/unbounded (assignment rule)"
    return True, ""
