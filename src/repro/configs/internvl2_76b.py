"""InternVL2-76B backbone — InternLM2-76B trunk; the InternViT vision
frontend is a STUB per the assignment (``input_specs`` provides patch
embeddings) [arXiv:2404.16821; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    source="[arXiv:2404.16821; unverified]",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    attn_kind="full",
    rope_theta=1e6,
    frontend="vision",
    n_prefix_tokens=256,  # one image tile worth of patch embeddings
)

SMOKE = CONFIG.variant(
    name="internvl2-76b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_prefix_tokens=8,
)
