"""Llama-4-Scout-17B-16E — MoE top-1 + shared expert, chunked local
attention with periodic global layers
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,  # per-expert FFN width
    vocab_size=202048,
    attn_kind="chunked",
    window=8192,  # local chunked attention
    global_every=4,  # every 4th layer attends globally
    n_experts=16,
    n_shared_experts=1,
    top_k=1,
    rope_theta=5e5,
)

SMOKE = CONFIG.variant(
    name="llama4-scout-17b-a16e-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    window=16,
    global_every=2,
    n_experts=4,
    n_shared_experts=1,
    top_k=1,
)
