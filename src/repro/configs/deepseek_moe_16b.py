"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed experts, top-6
[arXiv:2401.06066; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="[arXiv:2401.06066; hf]",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # per-expert FFN width (fine-grained)
    vocab_size=102400,
    attn_kind="full",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    rope_theta=1e4,
)

SMOKE = CONFIG.variant(
    name="deepseek-moe-16b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab_size=256,
    n_experts=8,
    n_shared_experts=1,
    top_k=2,
)
