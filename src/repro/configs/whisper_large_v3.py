"""Whisper-large-v3 backbone — encoder-decoder transformer; the conv/mel
frontend is a STUB per the assignment (``input_specs`` provides precomputed
frame embeddings) [arXiv:2212.04356; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    source="[arXiv:2212.04356; unverified]",
    n_layers=32,  # decoder layers; encoder has its own 32 (see encoder_layers)
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    attn_kind="full",
    encoder_layers=32,
    encoder_seq=1500,  # 30 s of audio after the (stubbed) conv frontend
    frontend="audio",
    gated_ffn=False,  # classic GELU FFN
)

SMOKE = CONFIG.variant(
    name="whisper-large-v3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    encoder_layers=2,
    encoder_seq=32,
)
