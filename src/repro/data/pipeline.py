"""Sharded synthetic data pipeline with executor-driven prefetch.

The pipeline is an AMT consumer of the parcelport runtime (paper §2.2.2
applied to the framework): batch *construction* runs as tasks on the
:class:`~repro.core.executor.AMTExecutor` worker threads, finished batches
flow back through a completion queue (LCRQ), and the trainer pops them —
never blocking on data unless the queue is empty (over-decomposition =
prefetch depth).

Data is synthetic but *deterministic and resumable*: batch ``i`` is a pure
function of (seed, i), so restart-from-checkpoint reproduces the exact
stream without data-state checkpoints.  Host-level straggler mitigation
comes from the executor's work stealing.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig
from ..core.completion import LCRQueue
from ..core.executor import AMTExecutor

__all__ = ["SyntheticLM", "PrefetchingLoader"]


@dataclass
class SyntheticLM:
    """Deterministic synthetic LM stream: Zipf-ish tokens + next-token labels."""

    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0

    def make_batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ index)
        v = self.cfg.vocab_size
        # zipfian-ish marginal over the vocab, cheap to sample
        u = rng.random((self.batch, self.seq + 1))
        toks = np.minimum((u ** 3.0 * v).astype(np.int32), v - 1)
        out: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
        }
        if self.cfg.frontend == "vision":
            out["prefix"] = rng.standard_normal(
                (self.batch, self.cfg.n_prefix_tokens, self.cfg.d_model), dtype=np.float32
            )
        if self.cfg.is_encdec:
            out["frames"] = rng.standard_normal(
                (self.batch, self.cfg.encoder_seq, self.cfg.d_model), dtype=np.float32
            )
        return out


class PrefetchingLoader:
    """Prefetch ``depth`` batches ahead through the AMT executor."""

    def __init__(
        self,
        source: SyntheticLM,
        executor: AMTExecutor,
        depth: int = 4,
        start_index: int = 0,
    ):
        self.source = source
        self.executor = executor
        self.depth = depth
        self.ready = LCRQueue()
        self._next_submit = start_index
        self._next_emit = start_index
        self._lock = threading.Lock()
        self._stash: Dict[int, Any] = {}
        for _ in range(depth):
            self._submit_one()

    def _submit_one(self) -> None:
        idx = self._next_submit
        self._next_submit += 1
        self.executor.submit(lambda i=idx: self.ready.push((i, self.source.make_batch(i))))

    def next(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        """Pop the next in-order batch; pumps executor progress while waiting."""
        import time

        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                if self._next_emit in self._stash:
                    batch = self._stash.pop(self._next_emit)
                    self._next_emit += 1
                    self._submit_one()
                    return batch
            item = self.ready.pop()
            if item is not None:
                with self._lock:
                    self._stash[item[0]] = item[1]
                continue
            self.executor.progress()
            if time.monotonic() > deadline:
                raise TimeoutError("data pipeline stalled")
            time.sleep(1e-4)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()
