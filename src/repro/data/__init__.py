from .pipeline import PrefetchingLoader, SyntheticLM

__all__ = ["PrefetchingLoader", "SyntheticLM"]
