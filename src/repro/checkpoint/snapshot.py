"""In-memory snapshot codec for live state handoff (ISSUE 8).

:mod:`repro.checkpoint.manager` serializes pytrees to *disk* for fault
tolerance; a departing fleet worker needs the same self-describing,
bit-exact encoding as **bytes over a CommChannel** so its KV-slot shard
can move to a successor mid-decode.  Same dtype discipline as the
manager: bf16 leaves travel as a uint16 view with the logical dtype
recorded in the manifest, so the round trip is bit-identical.

Wire format: ``b"RSNP"`` + 4-byte big-endian manifest length + manifest
JSON + concatenated raw leaf bytes.  The manifest carries per-leaf
dtype/shape/offset plus a JSON ``meta`` dict for scalar bookkeeping
(request id, position, remaining budget) that rides along with the
arrays.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .manager import _BF16, _flatten

__all__ = ["pack_state", "unpack_state"]

_MAGIC = b"RSNP"


def pack_state(tree: Any, meta: Optional[Dict[str, Any]] = None) -> bytes:
    """Serialize a pytree of arrays (+ JSON-able ``meta``) to bytes."""
    manifest: Dict[str, Any] = {"meta": meta or {}, "leaves": {}}
    blobs = []
    offset = 0
    for key, leaf in _flatten(tree):
        arr = np.asarray(jax.device_get(leaf))
        dt = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
        if dt == _BF16:
            arr = arr.view(np.uint16) if arr.dtype != np.uint16 else arr
        data = np.ascontiguousarray(arr).tobytes()
        manifest["leaves"][key] = {
            "dtype": dt,  # logical dtype (what the consumer sees)
            "raw": str(arr.dtype),  # storage dtype (what the bytes are)
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(data),
        }
        blobs.append(data)
        offset += len(data)
    mjson = json.dumps(manifest).encode()
    return _MAGIC + struct.pack(">I", len(mjson)) + mjson + b"".join(blobs)


def unpack_state(payload: bytes, abstract: Any = None) -> Tuple[Any, Dict[str, Any]]:
    """Decode :func:`pack_state` bytes → ``(state, meta)``.

    Without ``abstract``, ``state`` is a flat ``{tree-path: array}`` dict.
    With ``abstract`` (a pytree of shape/dtype references, e.g. the
    adopter's own freshly-allocated slot state) the original structure is
    rebuilt onto it, failing loudly on any shape/dtype mismatch — the
    manager's self-validating-restore contract applied to a live handoff.
    """
    if payload[:4] != _MAGIC:
        raise ValueError("not a snapshot payload (bad magic)")
    (mlen,) = struct.unpack(">I", payload[4:8])
    manifest = json.loads(payload[8 : 8 + mlen].decode())
    base = 8 + mlen
    arrays: Dict[str, Any] = {}
    for key, ent in manifest["leaves"].items():
        lo = base + ent["offset"]
        raw = np.frombuffer(payload[lo : lo + ent["nbytes"]], dtype=np.dtype(ent["raw"]))
        arr = raw.reshape(ent["shape"])
        if ent["dtype"] == _BF16:
            arr = arr.view(jnp.bfloat16)
        arrays[key] = arr
    meta = manifest["meta"]
    if abstract is None:
        return arrays, meta
    leaves = _flatten(abstract)
    ordered = []
    for key, ref in leaves:
        if key not in arrays:
            raise KeyError(f"snapshot missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {key}: snapshot shape {arr.shape} != target {ref.shape}")
        if str(ref.dtype) != manifest["leaves"][key]["dtype"]:
            raise ValueError(
                f"leaf {key}: snapshot dtype {manifest['leaves'][key]['dtype']} != target {ref.dtype}"
            )
        ordered.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(abstract)
    return jax.tree_util.tree_unflatten(treedef, ordered), meta
