"""Async sharded checkpointing with atomic commit + elastic restore.

Fault-tolerance contract (1000+-node design):

* **Async**: every leaf is written by an :class:`AMTExecutor` task (the
  parcelport background-work pattern — the trainer never blocks on I/O);
  ``wait()`` (or the next ``save``) joins the outstanding futures.
* **Atomic**: shards land in ``step_<n>.tmp/``; the manifest is written
  last and the directory is atomically renamed to ``step_<n>`` — a crash
  mid-save never corrupts the latest checkpoint.
* **Elastic**: shards are stored unsharded (global arrays) with abstract
  tree paths; restore re-places them onto *any* mesh/sharding — scale up,
  scale down, or change the parallelism layout between runs.
* **Self-validating**: restore checks shapes/dtypes against the target
  abstract state and fails loudly on mismatch.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.executor import AMTExecutor, TaskFuture

__all__ = ["CheckpointManager"]

_BF16 = "bfloat16"


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, executor: Optional[AMTExecutor] = None, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.executor = executor
        self.keep = keep
        self._pending: List[TaskFuture] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ save
    def save(self, state: Any, step: int, wait: bool = False) -> None:
        self.wait()  # only one save in flight
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _flatten(state)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}}
        host_leaves = []
        for key, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            dt = str(leaf.dtype)
            if dt == _BF16:
                arr = arr.view(np.uint16) if arr.dtype != np.uint16 else arr
            fname = key.replace("/", "__") + ".npy"
            manifest["leaves"][key] = {"file": fname, "dtype": dt, "shape": list(arr.shape)}
            host_leaves.append((tmp / fname, arr))

        def write_shard(path: Path, arr: np.ndarray) -> None:
            np.save(path, arr)

        def commit() -> None:
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():  # re-save of the same step: replace
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.executor is None:
            for p, a in host_leaves:
                write_shard(p, a)
            commit()
            return
        futs = [self.executor.submit(write_shard, p, a) for p, a in host_leaves]

        def finalize() -> None:
            for f in futs:
                f.result(timeout=120.0)
            commit()

        with self._lock:
            self._pending = [self.executor.submit(finalize)]
        if wait:
            self.wait()

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result(timeout=300.0)

    def _gc(self) -> None:
        steps = sorted(self.available_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def available_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        abstract_state: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, int]:
        """Rebuild ``abstract_state``'s pytree from disk; ``shardings`` (an
        optional matching tree of NamedShardings) re-places leaves onto the
        current mesh — the elastic-rescale path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = _flatten(abstract_state)
        sh_leaves = dict(_flatten(shardings)) if shardings is not None else {}
        rebuilt: Dict[str, Any] = {}
        for key, ref in leaves:
            ent = manifest["leaves"].get(key)
            if ent is None:
                raise KeyError(f"checkpoint {d} missing leaf {key!r}")
            arr = np.load(d / ent["file"])
            if ent["dtype"] == _BF16:
                arr = arr.view(jnp.bfloat16)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {key}: ckpt shape {arr.shape} != target {ref.shape}")
            if str(ref.dtype) != ent["dtype"]:
                raise ValueError(f"leaf {key}: ckpt dtype {ent['dtype']} != target {ref.dtype}")
            sh = sh_leaves.get(key)
            rebuilt[key] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
        treedef = jax.tree_util.tree_structure(abstract_state)
        ordered = [rebuilt[k] for k, _ in leaves]
        return jax.tree_util.tree_unflatten(treedef, ordered), int(manifest["step"])
