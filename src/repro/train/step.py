"""The train step: microbatched grad accumulation, remat, AdamW.

``make_train_step(cfg, hp, tcfg)`` returns a pure ``(state, batch) →
(state, metrics)`` suitable for ``jax.jit`` with sharded state/batch.
Distribution is GSPMD-driven: parameters/activations carry logical-axis
annotations (:mod:`repro.sharding`), the gradient all-reduce over the
data axes and any tensor/expert-parallel collectives appear in the
lowered HLO (inspected by the dry-run/roofline).

Microbatching: the global batch splits into ``microbatches`` slices
scanned sequentially with f32 gradient accumulation — the activation-
memory lever of §Perf.  Optional int8 gradient compression with error
feedback lives in :mod:`repro.train.grad_sync` (explicit-DP mode).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import model as model_lib
from ..optim import OptHParams, adamw_init, adamw_update
from ..sharding.logical import shard

__all__ = ["TrainConfig", "TrainState", "init_train_state", "make_train_step"]


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "dots"  # 'none' | 'full' | 'dots' | 'dots_no_batch'
    grad_sync: str = "auto"  # 'auto' (GSPMD) | 'int8_ef' (explicit compression)
    # Which packer the explicit-DP wire hand-off uses: 'host' = the numpy
    # reference loop, 'device' = the fused Pallas quantize+pack kernel
    # (bit-identical wire bytes; see grad_sync.make_packer).
    grad_pack: str = "host"

    def __post_init__(self):
        assert self.grad_pack in ("host", "device"), self.grad_pack

    def variant(self, **kw) -> "TrainConfig":
        return dataclasses.replace(self, **kw)


TrainState = Dict[str, Any]  # {"params", "opt", "step", ["ef"]}


def init_train_state(rng: jax.Array, cfg: ArchConfig, tcfg: Optional[TrainConfig] = None) -> TrainState:
    params = model_lib.init_params(rng, cfg)
    state: TrainState = {
        "params": params,
        "opt": adamw_init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg is not None and tcfg.grad_sync == "int8_ef":
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _split_micro(batch: Dict[str, jax.Array], m: int) -> Dict[str, jax.Array]:
    """(B, ...) → (m, B/m, ...) for scanning."""

    def sp(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} % microbatches {m} != 0"
        return x.reshape(m, b // m, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(
    cfg: ArchConfig,
    hp: OptHParams,
    tcfg: TrainConfig = TrainConfig(),
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    def loss(params, mb):
        total, metrics = model_lib.loss_fn(params, cfg, mb, remat=tcfg.remat)
        return total, metrics

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        m = tcfg.microbatches
        if m == 1:
            (l, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, m)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), met

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, l), mets = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / m).astype(jnp.float32), grads)
            l = l / m
            metrics = jax.tree.map(lambda x: x[-1], mets)
        if tcfg.grad_sync == "int8_ef":
            from .grad_sync import compress_grads_int8_ef

            grads, new_ef = compress_grads_int8_ef(grads, state["ef"])
        new_params, new_opt, opt_metrics = adamw_update(grads, state["opt"], params, hp)
        new_state: TrainState = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if tcfg.grad_sync == "int8_ef":
            new_state["ef"] = new_ef
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_mean"] = l
        return new_state, metrics

    return train_step
