"""Trainer: the host-side orchestration loop.

Integrates every substrate piece the way a production run would:

* the **AMT executor** (paper runtime) powers data prefetch, async
  checkpoint shards, and metric sinks; the loop pumps
  ``executor.progress()`` once per step — literally the parcelport
  ``background_work`` contract (paper Listing 2);
* **checkpoint/restart**: resumes from the latest manifest, reshards onto
  the current mesh (elastic), data stream replays deterministically;
* **step-time watchdog**: flags straggler steps (host-level mitigation;
  ICI-level stragglers are XLA's domain) and records them in metrics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs.base import ArchConfig
from ..core.executor import AMTExecutor
from ..data import PrefetchingLoader, SyntheticLM
from ..optim import OptHParams
from .step import TrainConfig, init_train_state, make_train_step

__all__ = ["Trainer", "TrainerConfig"]


@dataclass
class TrainerConfig:
    batch: int = 8
    seq: int = 128
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than 3× median → flagged
    seed: int = 0


class Trainer:
    def __init__(
        self,
        arch: ArchConfig,
        hp: OptHParams,
        tcfg: TrainConfig = TrainConfig(microbatches=1, remat="none"),
        run: TrainerConfig = TrainerConfig(),
        executor: Optional[AMTExecutor] = None,
        donate: bool = True,
    ):
        self.arch = arch
        self.hp = hp
        self.tcfg = tcfg
        self.run_cfg = run
        self.executor = executor or AMTExecutor(n_workers=2)
        self._own_executor = executor is None
        self.step_fn = jax.jit(
            make_train_step(arch, hp, tcfg), donate_argnums=(0,) if donate else ()
        )
        self.ckpt = (
            CheckpointManager(run.ckpt_dir, executor=self.executor)
            if run.ckpt_dir
            else None
        )
        self.metrics_log: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []

    # ------------------------------------------------------------------ run
    def train(self) -> Dict[str, Any]:
        rc = self.run_cfg
        rng = jax.random.PRNGKey(rc.seed)
        state = init_train_state(rng, self.arch, self.tcfg)
        start_step = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                abstract = jax.eval_shape(
                    lambda r: init_train_state(r, self.arch, self.tcfg), rng
                )
                state, start_step = self.ckpt.restore(abstract, latest)
        source = SyntheticLM(self.arch, rc.batch, rc.seq, seed=rc.seed)
        loader = PrefetchingLoader(source, self.executor, depth=4, start_index=start_step)
        times: List[float] = []
        for step in range(start_step, rc.steps):
            batch_np = loader.next()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if "prefix" in batch:
                batch["prefix"] = batch["prefix"].astype(jnp.dtype(self.arch.dtype))
            if "frames" in batch:
                batch["frames"] = batch["frames"].astype(jnp.dtype(self.arch.dtype))
            t0 = time.monotonic()
            state, metrics = self.step_fn(state, batch)
            metrics = jax.device_get(metrics)
            dt = time.monotonic() - t0
            times.append(dt)
            med = float(np.median(times[-32:]))
            if len(times) > 8 and dt > rc.straggler_factor * med:
                self.straggler_steps.append(step)
            rec = {"step": step, "time_s": dt, **{k: float(v) for k, v in metrics.items()}}
            self.metrics_log.append(rec)
            if step % rc.log_every == 0:
                print(
                    f"step {step:5d} loss={rec.get('loss', float('nan')):.4f} "
                    f"lr={rec.get('lr', 0):.2e} {dt*1e3:.0f}ms",
                    flush=True,
                )
            if self.ckpt is not None and (step + 1) % rc.ckpt_every == 0:
                self.ckpt.save(state, step + 1)
            # paper Listing 2 contract: pump host-side background work
            self.executor.progress()
        if self.ckpt is not None:
            self.ckpt.save(state, rc.steps, wait=True)
        summary = {
            "final_loss": self.metrics_log[-1].get("loss") if self.metrics_log else None,
            "steps": len(self.metrics_log),
            "stragglers": self.straggler_steps,
            "median_step_s": float(np.median(times)) if times else None,
        }
        if self._own_executor:
            self.executor.shutdown()
        return summary
