"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor quantization with an error-feedback accumulator: the
quantization residual is carried to the next step, so compression is
unbiased in the long run (Seide et al. / EF-SGD family).  Inside SPMD jit
the quantize→(implicit all-reduce)→dequantize sequence lets XLA move int8
bytes instead of f32 across the data axes for the replicated-gradient
reduction — a 4× collective-bytes reduction visible in the dry-run.

The *host-side* gradient-sync hand-off rides the shared comm layer:
:func:`pack_grads` / :func:`unpack_grads` turn a gradient pytree into wire
bytes and back, so explicit data-parallel ranks exchange compressed
gradients through :class:`~repro.core.comm.interface.CommInterface` verbs
(e.g. a :class:`~repro.core.comm.collective.CommChannel`) with the same
backpressure and progress machinery as the parcelport study — asserted by
the round-trip test in ``tests/test_train.py``.

Convergence is validated in ``tests/test_train.py`` (loss decreases within
tolerance of the uncompressed baseline on a smoke config).
"""
from __future__ import annotations

import pickle
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["compress_grads_int8_ef", "pack_grads", "unpack_grads"]


def _q(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_int8_ef(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads, new error-feedback state)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    pairs = jax.tree.map(leaf, grads, ef)
    # Split the tree-of-(deq, err) pairs into two trees by STRUCTURE, not
    # by sniffing leaves: transposing over the exact outer treedef keeps a
    # gradient pytree whose own leaf containers are tuples intact.  (The
    # previous `is_leaf=lambda t: isinstance(t, tuple)` split misfired on
    # such trees: it stopped at the container tuple and quietly mixed the
    # dequantized values with the error-feedback state.)
    deq, new_ef = jax.tree.transpose(
        jax.tree.structure(grads), jax.tree.structure((0, 0)), pairs
    )
    return deq, new_ef


def pack_grads(tree: Any) -> bytes:
    """Serialize a gradient pytree's leaves to wire bytes for the
    host-side DP hand-off over CommInterface verbs.  Structure travels
    out of band (both ranks hold the same model), so the wire carries
    only the arrays — int8 leaves stay int8 (the 4× reduction)."""
    leaves = jax.tree.leaves(tree)
    return pickle.dumps([np.asarray(leaf) for leaf in leaves])


def unpack_grads(data: bytes, like: Any) -> Any:
    """Rebuild a gradient pytree from :func:`pack_grads` bytes using the
    receiver's own structure (``like``)."""
    leaves = [jnp.asarray(a) for a in pickle.loads(data)]
    return jax.tree.unflatten(jax.tree.structure(like), leaves)
