"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor quantization with an error-feedback accumulator: the
quantization residual is carried to the next step, so compression is
unbiased in the long run (Seide et al. / EF-SGD family).  Inside SPMD jit
the quantize→(implicit all-reduce)→dequantize sequence lets XLA move int8
bytes instead of f32 across the data axes for the replicated-gradient
reduction — a 4× collective-bytes reduction visible in the dry-run.

Convergence is validated in ``tests/test_train.py`` (loss decreases within
tolerance of the uncompressed baseline on a smoke config).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_grads_int8_ef"]


def _q(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_int8_ef(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads, new error-feedback state)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    out = jax.tree.map(leaf, grads, ef)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_ef
