"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor quantization with an error-feedback accumulator: the
quantization residual is carried to the next step, so compression is
unbiased in the long run (Seide et al. / EF-SGD family).  Inside SPMD jit
the quantize→(implicit all-reduce)→dequantize sequence lets XLA move int8
bytes instead of f32 across the data axes for the replicated-gradient
reduction — a 4× collective-bytes reduction visible in the dry-run.

The *host-side* gradient-sync hand-off rides the shared comm layer:
:func:`pack_grads` / :func:`unpack_grads` turn a gradient pytree into wire
bytes and back, so explicit data-parallel ranks exchange compressed
gradients through :class:`~repro.core.comm.interface.CommInterface` verbs
(e.g. a :class:`~repro.core.comm.collective.CommChannel`) with the same
backpressure and progress machinery as the parcelport study — asserted by
the round-trip test in ``tests/test_train.py``.

Wire format (ISSUE 9): a versioned length-prefixed binary header from
:mod:`repro.core.comm.wire` replaces the old pickle stream.  Two kinds
share the header:

* ``KIND_RAW`` — leaf bytes concatenated tightly in leaf order
  (:func:`pack_grads`); int8 leaves stay int8 (the 4× reduction).
* ``KIND_Q8`` — the *quantized* wire: offset table + per-tensor scales +
  tile-padded int8 payload (:func:`pack_grads_q8`).  This host path is the
  byte-exact reference for the fused device kernel in
  :mod:`repro.kernels.grad_pack` — same padding, same f32 quantize math —
  which is what makes "device pack == host pack" a falsifiable parity
  test rather than a tolerance check.

Copy discipline: leaves that are already contiguous host arrays go to the
wire as buffer *views* (no ``np.asarray`` copies); the only allocation is
the joined output buffer itself.  Pinned by the allocation-count test in
``tests/test_grad_pack.py``.

Convergence is validated in ``tests/test_train.py`` (loss decreases within
tolerance of the uncompressed baseline on a smoke config).
"""
from __future__ import annotations

import struct
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.comm import wire

__all__ = [
    "compress_grads_int8_ef",
    "pack_grads",
    "unpack_grads",
    "pack_grads_q8",
    "make_packer",
]

_F32_EPS = np.float32(1e-12)
# Reciprocal multiply, NOT division: jit backends strength-reduce
# division-by-constant into `x * (1/127)`, which differs from IEEE
# division by 1 ulp for some inputs.  Using the multiply explicitly in
# every path (host / XLA / Mosaic) keeps the scale bytes identical.
_F32_RECIP127 = np.float32(1.0) / np.float32(127.0)


def _q(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_int8_ef(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads, new error-feedback state)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    pairs = jax.tree.map(leaf, grads, ef)
    # Split the tree-of-(deq, err) pairs into two trees by STRUCTURE, not
    # by sniffing leaves: transposing over the exact outer treedef keeps a
    # gradient pytree whose own leaf containers are tuples intact.  (The
    # previous `is_leaf=lambda t: isinstance(t, tuple)` split misfired on
    # such trees: it stopped at the container tuple and quietly mixed the
    # dequantized values with the error-feedback state.)
    deq, new_ef = jax.tree.transpose(
        jax.tree.structure(grads), jax.tree.structure((0, 0)), pairs
    )
    return deq, new_ef


def _host_leaf(leaf: Any) -> np.ndarray:
    """Bring a leaf to a contiguous host array without copying when it
    already is one (C-contiguous ndarray → same object)."""
    a = np.asarray(leaf)
    return a if a.flags["C_CONTIGUOUS"] else np.ascontiguousarray(a)


def pack_grads(tree: Any) -> bytes:
    """Serialize a gradient pytree's leaves to ``KIND_RAW`` wire bytes for
    the host-side DP hand-off over CommInterface verbs.  Structure travels
    out of band (both ranks hold the same model), so the wire carries only
    the arrays — int8 leaves stay int8 (the 4× reduction).  Contiguous
    host leaves are joined as views, not copies."""
    arrs = [_host_leaf(leaf) for leaf in jax.tree.leaves(tree)]
    specs = [wire.leaf_spec(a) for a in arrs]
    parts: List[Any] = [wire.encode_grad_header(wire.KIND_RAW, specs)]
    for a in arrs:
        if a.nbytes:
            parts.append(a.reshape(-1).view(np.uint8).data)
    return b"".join(parts)


def unpack_grads(data, like: Any) -> Any:
    """Rebuild a gradient pytree from wire bytes using the receiver's own
    structure (``like``).  Dispatches on the header kind: ``KIND_RAW``
    payloads restore original dtypes; ``KIND_Q8`` payloads dequantize to
    f32 leaves (matching :func:`compress_grads_int8_ef`'s output dtype).
    Leaf arrays are zero-copy views over ``data``."""
    buf = memoryview(data)
    kind, specs, off = wire.parse_grad_header(buf)
    leaves: List[Any] = []
    if kind == wire.KIND_RAW:
        for s in specs:
            a = np.frombuffer(buf, dtype=s.dtype, count=s.nelems, offset=off)
            leaves.append(jnp.asarray(a.reshape(s.shape)))
            off += s.nbytes
    elif kind == wire.KIND_Q8:
        n = len(specs)
        off += 4 * n  # offset table (recomputable from specs; skipped)
        scales = np.frombuffer(buf, dtype=np.float32, count=n, offset=off)
        off += 4 * n
        for s, scale in zip(specs, scales):
            q = np.frombuffer(buf, dtype=np.int8, count=s.nelems, offset=off)
            deq = q.astype(np.float32) * scale
            leaves.append(jnp.asarray(deq.reshape(s.shape)))
            off += wire.padded_nelems(s.nelems)
    else:
        raise ValueError(f"unknown gradient wire kind {kind}")
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def _q8_host(g32: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.float32]:
    """Host-reference int8 quantize — the same f32 ops, in the same order,
    as the device kernel, so the bytes are bit-comparable (max reductions
    are exact; elementwise f32 add/div/round are IEEE; numpy and XLA both
    round half-to-even).  The error feedback is ``(r - q) * scale`` with
    the multiply LAST — the ``g32 - q*scale`` form lets jit backends
    contract multiply+subtract into a single-rounding fma that numpy's
    two-rounding sequence cannot reproduce bitwise."""
    maxabs = np.max(np.abs(g32)) if g32.size else np.float32(0.0)
    scale = np.float32(np.maximum(maxabs, _F32_EPS) * _F32_RECIP127)
    r = g32 / scale
    q = np.clip(np.round(r), -127, 127).astype(np.int8)
    ef = (r - q.astype(np.float32)) * scale
    return q, ef, scale


def pack_grads_q8(tree: Any, ef: Any) -> Tuple[bytes, Any]:
    """Host reference for the fused device pack: error-feedback add +
    per-tensor int8 quantize + pack into one ``KIND_Q8`` wire buffer
    (offset table + scales + tile-padded payload).  Returns
    ``(wire_bytes, new_ef_tree)``.  The device kernel in
    :mod:`repro.kernels.grad_pack` must reproduce these bytes exactly."""
    leaves = jax.tree.leaves(tree)
    ef_leaves = jax.tree.leaves(ef)
    specs = []
    q_segs: List[bytes] = []
    scales: List[np.float32] = []
    new_ef: List[Any] = []
    for g, e in zip(leaves, ef_leaves):
        g32 = _host_leaf(g).astype(np.float32, copy=False) + _host_leaf(e)
        q, ef_leaf, scale = _q8_host(g32)
        spec = wire.leaf_spec(g, quantized=True)
        specs.append(spec)
        scales.append(scale)
        pad = wire.padded_nelems(spec.nelems) - spec.nelems
        seg = q.reshape(-1).view(np.uint8).data
        q_segs.append(seg if pad == 0 else bytes(seg) + b"\x00" * pad)
        new_ef.append(ef_leaf)
    offs = wire.q8_offsets(specs)
    parts: List[Any] = [
        wire.encode_grad_header(wire.KIND_Q8, specs),
        struct.pack(f"<{len(offs)}I", *offs),
        struct.pack(f"<{len(scales)}f", *[float(s) for s in scales]),
    ]
    parts.extend(q_segs)
    data = b"".join(parts)
    return data, jax.tree.unflatten(jax.tree.structure(tree), new_ef)


def make_packer(kind: str = "host"):
    """Resolve the explicit-DP wire packer for ``TrainConfig.grad_pack``:
    ``'host'`` is the numpy reference loop (:func:`pack_grads_q8`),
    ``'device'`` the fused kernel (:func:`repro.kernels.grad_pack.
    pack_grads_fused`, one compiled program + one transfer).  Both emit
    bit-identical ``KIND_Q8`` wire bytes, so the knob is a pure
    performance choice — flipping it mid-run cannot perturb training."""
    if kind == "host":
        return pack_grads_q8
    if kind == "device":
        from ..kernels.grad_pack import pack_grads_fused

        return pack_grads_fused
    raise ValueError(f"grad_pack must be 'host' or 'device', got {kind!r}")
