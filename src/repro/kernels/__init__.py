"""Pallas TPU kernels for the framework's compute hot spots.

The paper itself is a host-networking study with no device kernels; these
kernels belong to the *training/serving framework* built around it: flash
attention, the Mamba2 SSD intra-chunk block, and the MoE grouped matmul.
Each has a pure-jnp oracle in :mod:`ref` and is validated with
``interpret=True`` on CPU; the BlockSpecs are the TPU deployment config.
"""
from .ops import attention, expert_ffn_matmul, flash_attention, grouped_matmul, kernel_mode, ssd_chunk_kernel

__all__ = [
    "attention",
    "expert_ffn_matmul",
    "flash_attention",
    "grouped_matmul",
    "kernel_mode",
    "ssd_chunk_kernel",
]
