"""Pallas TPU kernels for the framework's compute hot spots.

The paper itself is a host-networking study with no device kernels; these
kernels belong to the *training/serving framework* built around it: flash
attention, the Mamba2 SSD intra-chunk block, the MoE grouped matmul, and
the fused gradient quantize+pack of the device data plane (ISSUE 9).
Each has a pure-jnp oracle (:mod:`ref`, or the host reference in
:mod:`repro.train.grad_sync` for the pack kernel) and is validated with
``interpret=True`` on CPU; the BlockSpecs are the TPU deployment config.
"""
from .grad_pack import pack_grads_fused, packed_nbytes, unpack_grads_fused
from .ops import attention, expert_ffn_matmul, flash_attention, grouped_matmul, kernel_mode, ssd_chunk_kernel

__all__ = [
    "attention",
    "expert_ffn_matmul",
    "flash_attention",
    "grouped_matmul",
    "kernel_mode",
    "pack_grads_fused",
    "packed_nbytes",
    "ssd_chunk_kernel",
    "unpack_grads_fused",
]
