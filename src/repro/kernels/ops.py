"""Public jit'd wrappers around the Pallas kernels.

The models call these through the ``kernel_impl`` switch (config/env):
``"xla"`` (default — reference lowering, used by the dry-run and CPU
tests) or ``"pallas"`` (TPU deployment; ``interpret=True`` on CPU).
Numerics contracts are pinned by tests against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .moe_gmm import grouped_matmul
from .ref import attention_ref, grouped_matmul_ref, ssd_chunk_ref
from .ssd_scan import ssd_chunk_kernel

__all__ = [
    "flash_attention",
    "ssd_chunk_kernel",
    "grouped_matmul",
    "attention",
    "expert_ffn_matmul",
    "kernel_mode",
]


def kernel_mode() -> str:
    """'pallas' | 'pallas-interpret' | 'xla' (default on CPU)."""
    mode = os.environ.get("REPRO_KERNELS", "")
    if mode:
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def attention(q, k, v, *, causal=True, window=0, chunk=0) -> jax.Array:
    mode = kernel_mode()
    if mode == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    if mode == "pallas-interpret":
        return flash_attention(q, k, v, causal=causal, window=window, chunk=chunk, interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)


def expert_ffn_matmul(x, w) -> jax.Array:
    mode = kernel_mode()
    if mode == "pallas":
        return grouped_matmul(x, w)
    if mode == "pallas-interpret":
        return grouped_matmul(x, w, interpret=True)
    return grouped_matmul_ref(x, w)
