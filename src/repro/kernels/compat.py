"""JAX version compatibility for Pallas TPU kernels.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
JAX releases; resolve whichever this installation provides so the kernels
run on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
