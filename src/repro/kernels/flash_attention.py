"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native adaptation: Q/K/V stream HBM→VMEM in (block_q × head_dim) /
(block_k × head_dim) tiles sized for the MXU (multiples of 128 on the lane
axis); the online-softmax running max / denominator / accumulator live in
VMEM scratch across the ``kv`` grid steps.  Grid layout
``(batch, q_heads, num_q_blocks, num_kv_blocks)`` with the kv axis
sequential ("arbitrary") and all others parallel.

Supports causal, sliding-window (``window > 0``) and chunked-local
(``chunk > 0``) masking, and GQA via a head-index map (kv head =
q head // group).  Causal/window/chunk block pairs that are fully masked
are skipped entirely (`pl.when` on the block indices), so SWA costs
O(S·window) — the same contract as the model-level reference.

Validated against :mod:`repro.kernels.ref` in ``interpret=True`` mode on
CPU (this container has no TPU); the BlockSpecs are the TPU deployment
configuration.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    o_ref,  # (1, 1, bq, d)
    m_scr,  # (bq, 128) f32 scratch — running max
    l_scr,  # (bq, 128) f32 scratch — running denominator
    acc_scr,  # (bq, d) f32 scratch — weighted-value accumulator
    *,
    scale: float,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
    causal: bool,
    window: int,
    chunk: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        if chunk > 0:
            mask &= (kpos // chunk) == (qpos // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0, 0], preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    # skip fully-masked block pairs
    live = True
    if causal:
        live = jnp.asarray(k_start <= q_start + block_q - 1)
    if window > 0:
        live &= jnp.asarray(k_start + block_k - 1 > q_start - window)
    if chunk > 0:
        # chunk ranges of the two blocks must overlap
        live &= jnp.asarray(k_start // chunk <= (q_start + block_q - 1) // chunk)
        live &= jnp.asarray((k_start + block_k - 1) // chunk >= q_start // chunk)
    if isinstance(live, bool):
        _compute()
    else:
        pl.when(live)(_compute)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunk", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, "GQA requires n_heads % n_kv_heads == 0"
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, "seq must divide block size"
    nq, nk = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(d)

    # layout: heads-major so a (block, d) tile is contiguous per (b, h)
    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, KV, S, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
        causal=causal,
        window=window,
        chunk=chunk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)  # back to (B, S, H, D)
