"""Fused gradient quantize+pack kernel (Pallas TPU) — the device data plane.

The host grad-sync path does three separate walks over the gradient tree:
a per-leaf ``tree.map`` for error-feedback + int8 quantize, a
``tree.transpose`` to split the results, and a host-side pack loop that
serializes leaf-by-leaf.  This module fuses all of it into ONE
``pallas_call`` over HBM→VMEM tiles of a single flat f32 buffer:

    error-feedback add  +  per-tensor int8 quantize  +  pack

producing one flat device buffer — tile-padded int8 payload, per-tensor
f32 scales, u32 offset table — that goes to the wire via a single
``jax.device_get`` with the versioned header from
:mod:`repro.core.comm.wire` prepended.  The receiver's
:func:`unpack_grads_fused` (or :func:`repro.train.grad_sync.unpack_grads`,
same format) rebuilds the pytree.

Kernel shape: leaves are flattened, zero-padded to :data:`wire.PACK_TILE`
elements, and concatenated; a scalar-prefetched ``seg_ids`` table maps
each tile to its leaf.  Grid ``(2, n_tiles)`` makes two sequential passes:

* phase 0 — per-tile ``max(|g+ef|)`` folded into a per-leaf running max
  held in VMEM scratch (scratch persists across grid steps);
* phase 1 — ``scale = max(maxabs, 1e-12)/127`` per leaf, quantize the
  tile, emit the int8 payload tile + the f32 error-feedback tile, and on
  the last tile flush the scales vector.

The payload/ef output index map is ``(i, j) -> (i*j, 0)``: every phase-0
step aliases block 0, so each output block's visits form one consecutive
run (Pallas's revisit rule) and the real writes all happen in phase 1.

Parity contract: in every mode the wire bytes are bit-identical to the
host reference :func:`repro.train.grad_sync.pack_grads_q8` — max
reductions are exact, the elementwise f32 add/div/round/clip pipeline is
IEEE, and numpy/XLA/Mosaic all round half-to-even.  Tier-1 asserts this
at every size in the Fig-3 ladder (``tests/test_grad_pack.py``).

Three-mode ladder as in :mod:`repro.kernels.ops`: ``xla`` reference
(segment-max formulation), ``pallas-interpret`` (CPU CI), ``pallas``
(TPU).
"""
from __future__ import annotations

import functools
import struct
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.comm import wire
from .compat import CompilerParams

__all__ = ["pack_grads_fused", "unpack_grads_fused", "packed_nbytes"]

TILE = wire.PACK_TILE

# Error-feedback update, in every path (host numpy / XLA / Mosaic):
#
#     r  = g32 / scale
#     q  = clip(round(r), -127, 127)
#     ef = (r - q) * scale
#
# NOT ``g32 - q*scale``: backends contract multiply-then-subtract into one
# fma (single rounding) while numpy rounds twice, which makes the EF state
# differ in the last ulp and lets multi-step wire bytes drift.  In the
# ``(r - q) * scale`` form the multiply comes last — there is no
# mul-feeding-add pattern to contract — so each op rounds once,
# identically, everywhere.  The scale likewise uses an explicit
# reciprocal multiply (see _RECIP127): XLA strength-reduces
# division-by-constant into reciprocal multiplication, which is 1 ulp off
# IEEE division for some inputs.
_RECIP127 = float(np.float32(1.0) / np.float32(127.0))


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


def _pack_kernel(n_tiles, seg_ref, g_ref, ef_ref, payload_ref, scales_ref, ef_out_ref, maxabs_ref):
    phase = pl.program_id(0)
    j = pl.program_id(1)
    s = seg_ref[j]
    g32 = g_ref[...] + ef_ref[...]  # (1, TILE) f32 — the fused EF add

    @pl.when((phase == 0) & (j == 0))
    def _init():
        maxabs_ref[...] = jnp.zeros_like(maxabs_ref)

    @pl.when(phase == 0)
    def _max_pass():
        m = jnp.max(jnp.abs(g32))
        cur = pl.load(maxabs_ref, (slice(0, 1), pl.dslice(s, 1)))
        pl.store(maxabs_ref, (slice(0, 1), pl.dslice(s, 1)), jnp.maximum(cur, m[None, None]))

    @pl.when(phase == 1)
    def _quant_pass():
        ma = pl.load(maxabs_ref, (slice(0, 1), pl.dslice(s, 1)))[0, 0]
        scale = jnp.maximum(ma, 1e-12) * _RECIP127
        r = g32 / scale
        q = jnp.clip(jnp.round(r), -127, 127).astype(jnp.int8)
        payload_ref[...] = q
        ef_out_ref[...] = (r - q.astype(jnp.float32)) * scale

        @pl.when(j == n_tiles - 1)
        def _flush_scales():
            scales_ref[...] = jnp.maximum(maxabs_ref[...], 1e-12) * _RECIP127


def _pallas_pack(g_tiles, ef_tiles, seg_ids, n_leaves, *, interpret):
    n_tiles = g_tiles.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i, j, seg: (j, 0)),
            pl.BlockSpec((1, TILE), lambda i, j, seg: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda i, j, seg: (i * j, 0)),
            pl.BlockSpec((1, n_leaves), lambda i, j, seg: (0, 0)),
            pl.BlockSpec((1, TILE), lambda i, j, seg: (i * j, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_leaves), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_pack_kernel, n_tiles),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, TILE), jnp.int8),
            jax.ShapeDtypeStruct((1, n_leaves), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, TILE), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(seg_ids, g_tiles, ef_tiles)


def _xla_pack(g_tiles, ef_tiles, seg_ids, n_leaves):
    """Reference lowering: segment-max over per-tile maxima, then the same
    elementwise quantize pipeline as the kernel."""
    tiles = g_tiles + ef_tiles
    tile_max = jnp.max(jnp.abs(tiles), axis=1)
    maxabs = jax.ops.segment_max(tile_max, seg_ids, num_segments=n_leaves)
    # tile-less (empty) leaves come back as the segment identity (-inf);
    # the host convention for an empty leaf is maxabs == 0.
    maxabs = jnp.maximum(maxabs, 0.0)
    scale = jnp.maximum(maxabs, 1e-12) * _RECIP127
    st = scale[seg_ids][:, None]
    r = tiles / st
    q = jnp.clip(jnp.round(r), -127, 127).astype(jnp.int8)
    ef_out = (r - q.astype(jnp.float32)) * st
    return q, scale[None, :], ef_out


# ---------------------------------------------------------------------------
# Host-facing wrapper with per-(treedef, shapes, mode) jit cache
# ---------------------------------------------------------------------------

_CACHE: dict = {}


def _kernel_mode() -> str:
    from .ops import kernel_mode

    return kernel_mode()


def packed_nbytes(tree: Any) -> int:
    """Wire size of :func:`pack_grads_fused`'s output for ``tree``."""
    specs = [wire.leaf_spec(leaf, quantized=True) for leaf in jax.tree.leaves(tree)]
    payload = sum(wire.padded_nelems(s.nelems) for s in specs)
    return wire.grad_header_bytes(specs) + 8 * len(specs) + payload


def _build(treedef, avals, mode):
    specs = [wire.LeafSpec(wire.dtype_code(d), tuple(int(x) for x in s), int(np.prod(s, dtype=np.int64))) for s, d in avals]
    header = wire.encode_grad_header(wire.KIND_Q8, specs)
    offs = wire.q8_offsets(specs)
    padded = [wire.padded_nelems(s.nelems) for s in specs]
    n_tiles = sum(padded) // TILE
    n_leaves = len(specs)
    seg_ids = np.repeat(np.arange(n_leaves, dtype=np.int32), [p // TILE for p in padded])
    offs_bytes = struct.pack(f"<{n_leaves}I", *offs)

    if n_tiles == 0:
        # Every leaf is empty (or the tree is): nothing for the kernel to
        # do.  Scales follow the maxabs==0 convention; payload is empty.
        scales = struct.pack(f"<{n_leaves}f", *([float(np.float32(np.float32(1e-12) * np.float32(_RECIP127)))] * n_leaves))
        data = header + offs_bytes + scales

        def run_empty(leaves, efs):
            new_ef = [jnp.zeros(s.shape, jnp.float32) for s in specs]
            return data, jax.tree.unflatten(treedef, new_ef)

        return run_empty

    seg_dev = jnp.asarray(seg_ids)
    offs_dev = jnp.asarray(np.frombuffer(offs_bytes, dtype=np.uint8))

    starts = np.cumsum([0] + padded[:-1]) if padded else []

    def flatten(leaves, efs):
        # dynamic_update_slice into one zeroed buffer: ~6x faster than the
        # naive per-leaf pad + concatenate on XLA CPU, and the zero fill
        # doubles as the tile padding.
        g_buf = jnp.zeros((n_tiles * TILE,), jnp.float32)
        e_buf = jnp.zeros((n_tiles * TILE,), jnp.float32)
        for (shape, _d), start, g, e in zip(avals, starts, leaves, efs):
            if int(np.prod(shape, dtype=np.int64)) == 0:
                continue
            g_buf = jax.lax.dynamic_update_slice(
                g_buf, g.astype(jnp.float32).reshape(-1), (int(start),)
            )
            e_buf = jax.lax.dynamic_update_slice(
                e_buf, e.reshape(-1).astype(jnp.float32), (int(start),)
            )
        return g_buf.reshape(n_tiles, TILE), e_buf.reshape(n_tiles, TILE)

    def assemble(q, scales, ef_out):
        body = jnp.concatenate(
            [
                offs_dev,
                jax.lax.bitcast_convert_type(scales.reshape(-1), jnp.uint8).reshape(-1),
                jax.lax.bitcast_convert_type(q.reshape(-1), jnp.uint8),
            ]
        )
        ef_flat = ef_out.reshape(-1)
        new_ef, cur = [], 0
        for s, pad_n in zip(specs, padded):
            new_ef.append(ef_flat[cur : cur + s.nelems].reshape(s.shape))
            cur += pad_n
        return body, new_ef

    @jax.jit
    def run(leaves, efs):
        g_tiles, ef_tiles = flatten(leaves, efs)
        if mode == "xla":
            q, scales, ef_out = _xla_pack(g_tiles, ef_tiles, seg_dev, n_leaves)
        else:
            q, scales, ef_out = _pallas_pack(
                g_tiles, ef_tiles, seg_dev, n_leaves, interpret=(mode == "pallas-interpret")
            )
        return assemble(q, scales, ef_out)

    def run_host(leaves, efs):
        body, new_ef = run(leaves, efs)
        data = b"".join([header, memoryview(np.asarray(jax.device_get(body)).data)])
        return data, jax.tree.unflatten(treedef, new_ef)

    return run_host


def pack_grads_fused(tree: Any, ef: Any, mode: Optional[str] = None) -> Tuple[bytes, Any]:
    """Fused device pack: returns ``(wire_bytes, new_ef_tree)`` with wire
    bytes bit-identical to :func:`repro.train.grad_sync.pack_grads_q8`.
    ``mode`` defaults to the session's :func:`~repro.kernels.ops.kernel_mode`."""
    mode = mode or _kernel_mode()
    leaves, treedef = jax.tree.flatten(tree)
    ef_leaves = jax.tree.leaves(ef)
    avals = tuple((tuple(int(d) for d in np.shape(g)), np.dtype(getattr(g, "dtype", np.float32))) for g in leaves)
    key = (treedef, avals, mode)
    fn = _CACHE.get(key)
    if fn is None:
        fn = _CACHE[key] = _build(treedef, avals, mode)
    return fn(leaves, ef_leaves)


def unpack_grads_fused(data, like: Any) -> Any:
    """Rebuild the dequantized (f32) gradient pytree from
    :func:`pack_grads_fused` wire bytes — the receiver-side twin."""
    buf = memoryview(data)
    kind, specs, off = wire.parse_grad_header(buf)
    if kind != wire.KIND_Q8:
        raise ValueError(f"expected KIND_Q8 wire payload, got kind {kind}")
    n = len(specs)
    off += 4 * n
    scales = np.frombuffer(buf, dtype=np.float32, count=n, offset=off)
    off += 4 * n
    leaves: List[Any] = []
    for s, scale in zip(specs, scales):
        q = np.frombuffer(buf, dtype=np.int8, count=s.nelems, offset=off)
        leaves.append(jnp.asarray(q.astype(np.float32) * scale).reshape(s.shape))
        off += wire.padded_nelems(s.nelems)
    return jax.tree.unflatten(jax.tree.structure(like), leaves)
