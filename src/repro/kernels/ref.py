"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "ssd_chunk_ref", "grouped_matmul_ref"]

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    chunk: int = 0,
) -> jax.Array:
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    if chunk > 0:
        mask &= (kpos // chunk) == (qpos // chunk)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def ssd_chunk_ref(
    x: jax.Array,  # (B, Q, H, P) — pre-discretized (x·dt) single chunk
    a_dt: jax.Array,  # (B, Q, H)
    b: jax.Array,  # (B, Q, H, N) — groups pre-broadcast
    c: jax.Array,  # (B, Q, H, N)
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential (recurrent) oracle for one SSD chunk:
    s_t = exp(a_t)·s_{t-1} + b_t ⊗ x_t ;  y_t = s_t · c_t."""
    bsz, q, h, p = x.shape
    n = b.shape[-1]
    s0 = init_state if init_state is not None else jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(s, inp):
        xt, at, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        s = jnp.exp(at)[..., None, None] * s + xt[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        a_dt.transpose(1, 0, 2).astype(jnp.float32),
        b.transpose(1, 0, 2, 3).astype(jnp.float32),
        c.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), s_fin


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(E, C, D) × (E, D, F) → (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)
