"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

The SSD chunked algorithm splits into (a) an embarrassingly parallel
intra-chunk quadratic block — the compute hot spot, O(S·Q) MXU work — and
(b) a tiny sequential inter-chunk state recurrence.  This kernel computes
(a): for each (batch, head, chunk) grid cell it produces

* ``y_diag``  — the causal intra-chunk output ((C·Bᵀ ⊙ L) · X),
* ``state``   — the chunk's contribution to the running SSM state
  (Σ_t exp(A_last − A_t) · b_t ⊗ x_t),
* ``y_off`` is then a small batched matmul applied in JAX after the
  inter-chunk scan (:func:`repro.models.ssm.ssd_chunked` shape contract).

Grid ``(B, H, num_chunks)``; blocks keep the full (Q × P) / (Q × N) tiles
in VMEM (Q=64..128, P=64, N=128 → ≤128 KiB per operand, MXU-aligned lanes).
GQA-style B/C groups are resolved by the index map (head → group), so the
broadcast never materializes in HBM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["ssd_chunk_kernel"]


def _ssd_kernel(a_ref, x_ref, b_ref, c_ref, y_ref, s_ref):
    # a: (1,1,1,Q)  x: (1,1,1,Q,P)  b,c: (1,1,1,Q,N)
    a = a_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q,P)
    b = b_ref[0, 0, 0].astype(jnp.float32)  # (Q,N)
    c = c_ref[0, 0, 0].astype(jnp.float32)  # (Q,N)
    q = a.shape[0]
    acs = jnp.cumsum(a)  # (Q,)
    # L[i,j] = exp(acs_i - acs_j) for j <= i else 0
    diff = acs[:, None] - acs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(lj <= li, jnp.exp(diff), 0.0)
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y = jax.lax.dot(g * L, x, preferred_element_type=jnp.float32)  # (Q,P)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    decay = jnp.exp(acs[-1] - acs)  # (Q,)
    bw = b * decay[:, None]  # (Q,N)
    state = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[0, 0, 0] = state.astype(s_ref.dtype)  # (P,N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel(
    a_dt: jax.Array,  # (B, H, nc, Q)   A·dt per step
    x: jax.Array,  # (B, H, nc, Q, P) pre-discretized inputs (x·dt)
    b: jax.Array,  # (B, G, nc, Q, N)
    c: jax.Array,  # (B, G, nc, Q, N)
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y_diag (B,H,nc,Q,P), chunk_states (B,H,nc,P,N))."""
    bsz, h, nc, q = a_dt.shape
    p = x.shape[-1]
    g_, n = b.shape[1], b.shape[-1]
    rep = h // g_
    y_shape = jax.ShapeDtypeStruct((bsz, h, nc, q, p), x.dtype)
    s_shape = jax.ShapeDtypeStruct((bsz, h, nc, p, n), jnp.float32)
    return pl.pallas_call(
        _ssd_kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda b_, h_, c_: (b_, h_ // rep, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda b_, h_, c_: (b_, h_ // rep, c_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
        ],
        out_shape=[y_shape, s_shape],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(a_dt, x, b, c)
