"""Grouped (per-expert) matmul kernel for MoE FFN batches (Pallas TPU).

Computes (E, C, D) × (E, D, F) → (E, C, F): every expert's token queue
against its own weight matrix.  Grid ``(E, C/bc, F/bf, D/bd)`` with a
float32 VMEM accumulator; the contraction axis is the innermost
("arbitrary") grid dimension so each (bc × bf) output tile accumulates
across D-tiles while Q/W tiles stream HBM→VMEM.  Block sizes default to
MXU-native 128×128×512.

This is the hot-spot of the MoE channel mixer; the einsum in
:mod:`repro.models.moe` is the reference lowering used by the dry-run.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["grouped_matmul"]


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(di == nd - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def grouped_matmul(
    x: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    f = w.shape[-1]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert c % block_c == 0 and f % block_f == 0 and d % block_d == 0, (
        f"dims ({c},{d},{f}) must divide blocks ({block_c},{block_d},{block_f})"
    )
    nd = d // block_d
    return pl.pallas_call(
        functools.partial(_gmm_kernel, nd=nd),
        grid=(e, c // block_c, f // block_f, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e_, c_, f_, d_: (e_, c_, d_)),
            pl.BlockSpec((1, block_d, block_f), lambda e_, c_, f_, d_: (e_, d_, f_)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda e_, c_, f_, d_: (e_, c_, f_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
