"""Mixture-of-Experts FFN: shared + routed experts, capacity dispatch.

Token-choice top-k routing with per-expert capacity.  Two dispatch
implementations:

* ``scatter`` (default) — tokens scatter-add into per-expert queues
  (B,E,C,D) and gather back; memory O(S·D + E·C·D), survives 32k-token
  sequences.
* ``einsum`` — classic GShard dense dispatch/combine masks (B,S,E,C);
  O(S·E·C) memory, used as the small-shape oracle in tests.

The expert dimension shards over the "model" mesh axis (expert
parallelism); with tokens sharded over "data", XLA lowers the queue
construction to the EP all-to-all visible in the dry-run's collective
schedule.  Covers both assigned MoE flavours: deepseek-moe-16b (2 shared +
64 routed, top-6, fine-grained) and llama4-scout (1 shared + 16 routed,
top-1).  The grouped-matmul Pallas kernel (:mod:`repro.kernels.moe_gmm`)
is the TPU hot-spot implementation of the per-expert FFN batch.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.logical import shard
from .layers import Params, dense_init, ffn_apply, ffn_init

__all__ = ["moe_init", "moe_apply", "expert_capacity"]


def expert_capacity(tokens: int, cfg: ArchConfig) -> int:
    """Per-expert token capacity for a routing group of ``tokens`` tokens."""
    cap = int(math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(cap, 4)


def moe_init(rng: jax.Array, cfg: ArchConfig, dtype) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(rng, 3)
    p: Params = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_up": dense_init(jax.random.fold_in(ks[1], 1), (e, d, f), dtype),
        "w_down": dense_init(jax.random.fold_in(ks[1], 2), (e, f, d), dtype),
    }
    if cfg.gated_ffn:
        p["w_gate"] = dense_init(ks[1], (e, d, f), dtype)
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[2], d, f * cfg.n_shared_experts, dtype, gated=cfg.gated_ffn)
    return p


def _route(p: Params, x: jax.Array, cfg: ArchConfig):
    """Top-k routing: per-slot expert ids, in-expert positions, gates, aux.

    Returns e_idx, pos, keep, gates — all (B, k·S) slot-major — plus the
    load-balancing aux loss.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = expert_capacity(s, cfg)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    logits = shard(logits, "batch", "seq", None)  # routing is per-token
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    # slot-major flattening: slot 0 of every token, then slot 1, …
    e_idx = gate_idx.transpose(0, 2, 1).reshape(b, k * s)  # (B,kS)
    gates = gate_vals.transpose(0, 2, 1).reshape(b, k * s)
    e_idx = shard(e_idx, "batch", None)
    gates = shard(gates, "batch", None)
    assign = jax.nn.one_hot(e_idx, e, dtype=jnp.float32)  # (B,kS,E)
    pos_in_expert = jnp.cumsum(assign, axis=1) - assign  # (B,kS,E)
    pos = jnp.sum(pos_in_expert * assign, axis=-1).astype(jnp.int32)  # (B,kS)
    keep = pos < cap
    # aux loss (Switch/GShard): E · Σ_e frac_tokens_e · mean_prob_e
    top1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    frac_tokens = jnp.mean(top1, axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_probs)
    return e_idx, pos, keep, gates, cap, aux


def _dispatch_scatter(x, e_idx, pos, keep, cap, e):
    """(B,S,D) tokens → (B,E,C,D) expert queues via scatter-add."""
    b, s, d = x.shape
    k_s = e_idx.shape[1]
    k = k_s // s
    x_rep = jnp.tile(x, (1, k, 1))  # slot-major: (B, kS, D)
    contrib = jnp.where(keep[..., None], x_rep, 0)
    contrib = shard(contrib, "batch", "moe_tokens", "embed")  # bf16, slot-sharded

    def per_batch(xb, eb, pb):
        return jnp.zeros((e, cap, xb.shape[-1]), xb.dtype).at[eb, pb].add(xb)

    return jax.vmap(per_batch)(contrib, e_idx, pos)


def _combine_gather(expert_out, e_idx, pos, keep, gates, s):
    """(B,E,C,D) expert outputs → (B,S,D) via gather + gated sum over k."""
    b, e, cap, d = expert_out.shape
    k_s = e_idx.shape[1]
    k = k_s // s

    def per_batch(ob, eb, pb):
        return ob[eb, pb]  # (kS, D)

    hit = jax.vmap(per_batch)(expert_out, e_idx, pos)
    hit = shard(hit, "batch", "moe_tokens", "embed")
    hit = jnp.where(keep[..., None], hit, 0) * gates[..., None].astype(hit.dtype)
    return jnp.sum(hit.reshape(b, k, s, d), axis=1)


def moe_apply(
    p: Params, x: jax.Array, cfg: ArchConfig, dispatch_mode: Optional[str] = None
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar)."""
    import os

    if dispatch_mode is None:
        dispatch_mode = os.environ.get("REPRO_MOE_DISPATCH", "scatter")
    b, s, d = x.shape
    e = cfg.n_experts
    e_idx, pos, keep, gates, cap, aux = _route(p, x, cfg)
    if dispatch_mode == "scatter":
        expert_in = _dispatch_scatter(x, e_idx, pos, keep, cap, e)
    else:  # einsum oracle (small shapes only)
        slot_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None]
        disp = jax.nn.one_hot(e_idx, e, dtype=x.dtype)[..., None] * slot_oh[:, :, None, :]
        x_rep = jnp.tile(x, (1, e_idx.shape[1] // s, 1))
        expert_in = jnp.einsum("bkec,bkd->becd", disp, x_rep)
    expert_in = shard(expert_in, "batch", "experts", "expert_cap", "embed")
    if cfg.gated_ffn:
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", expert_in, p["w_up"]))
    expert_out = jnp.einsum("becf,efd->becd", h, p["w_down"])
    expert_out = shard(expert_out, "batch", "experts", "expert_cap", "embed")
    if dispatch_mode == "scatter":
        out = _combine_gather(expert_out, e_idx, pos, keep, gates, s)
    else:
        comb = disp * gates[:, :, None, None].astype(x.dtype)
        out = jnp.einsum("bkec,becd->bkd", comb, expert_out)
        out = jnp.sum(out.reshape(b, -1, s, d), axis=1)
    if "shared" in p:
        out = out + ffn_apply(p["shared"], x, gated=cfg.gated_ffn)
    return out, aux.astype(jnp.float32)
