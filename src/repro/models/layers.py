"""Shared model primitives: norms, RoPE, FFN, embeddings.

All modules are pure functions over explicit param pytrees (no framework).
Initializers return nested dicts of ``jnp`` arrays; every ``init_*`` is
traceable so ``jax.eval_shape`` gives abstract params for the dry-run.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.logical import shard

__all__ = [
    "Params",
    "dense_init",
    "rms_norm",
    "layer_norm",
    "rope_frequencies",
    "apply_rope",
    "ffn_init",
    "ffn_apply",
    "embed_init",
    "cross_entropy_loss",
]

Params = Dict[str, jax.Array]


def dense_init(rng: jax.Array, shape: Tuple[int, ...], dtype, scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init."""
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies (head_dim//2,) in f32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` (..., seq, heads, head_dim) by position-dependent angles.

    ``positions`` is (..., seq) int32 — explicit so the decode path can pass
    the cache offset.
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- FFN
def ffn_init(rng: jax.Array, d_model: int, d_ff: int, dtype, gated: bool = True) -> Params:
    ks = jax.random.split(rng, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def ffn_apply(p: Params, x: jax.Array, gated: bool = True) -> jax.Array:
    up = shard(jnp.einsum("...d,df->...f", x, p["w_up"]), "batch", "seq", "mlp")
    if gated:
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ----------------------------------------------------------------- embedding
def embed_init(rng: jax.Array, vocab: int, d_model: int, dtype) -> jax.Array:
    return dense_init(rng, (vocab, d_model), dtype, scale=1.0)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross entropy in f32; ``mask`` (same shape as labels)
    excludes padding/vision-prefix positions."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
