"""Generic LM assembly for all assigned architecture families.

One functional namespace serves every family (dense / MLA / MoE / SSM /
hybrid / VLM / enc-dec):

* ``init_params(rng, cfg)``           — stacked per-layer params (scan-ready)
* ``forward_train(params, cfg, batch)`` → (logits, aux_loss)
* ``init_cache(cfg, batch, context)``  — decode cache pytree
* ``prefill(params, cfg, batch, cache)`` → (last-token logits, cache)
* ``decode_step(params, cfg, tokens, positions, cache)`` → (logits, cache)

Every init is traceable: the dry-run builds abstract params with
``jax.eval_shape`` and never allocates.  Homogeneous layer stacks run under
``jax.lax.scan`` to keep compiled HLO size O(1) in depth; heterogeneous
behaviour inside the stack (llama4 global-attention layers, zamba2 shared
block) is expressed with ``lax.cond`` on the layer index.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.logical import shard
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    Params,
    cross_entropy_loss,
    dense_init,
    embed_init,
    ffn_apply,
    ffn_init,
    rms_norm,
)

__all__ = [
    "init_params",
    "forward_train",
    "loss_fn",
    "init_cache",
    "prefill",
    "decode_step",
    "model_dtype",
]


def model_dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _is_global_layer(cfg: ArchConfig, idx) -> Any:
    """llama4-style: every ``global_every``-th layer attends globally."""
    if cfg.attn_kind != "chunked" or not cfg.global_every:
        return jnp.asarray(False)
    return (idx + 1) % cfg.global_every == 0


# ------------------------------------------------------------------- params
def _layer_init(rng: jax.Array, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(rng, 4)
    d = cfg.d_model
    p: Params = {"ln1": jnp.ones((d,), dtype)}
    fam = cfg.family
    if fam == "ssm" or fam == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
        return p
    p["attn"] = (
        attn.mla_init(ks[0], cfg, dtype) if cfg.attn_kind == "mla" else attn.attn_init(ks[0], cfg, dtype)
    )
    p["ln2"] = jnp.ones((d,), dtype)
    if cfg.is_moe:
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = ffn_init(ks[1], d, cfg.d_ff, dtype, gated=cfg.gated_ffn)
    return p


def _encoder_layer_init(rng: jax.Array, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(rng, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), dtype),
        "attn": attn.attn_init(ks[0], cfg, dtype),
        "ln2": jnp.ones((d,), dtype),
        "ffn": ffn_init(ks[1], d, cfg.d_ff, dtype, gated=cfg.gated_ffn),
    }


def _cross_layer_init(rng: jax.Array, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    return {"ln": jnp.ones((d,), dtype), "attn": attn.attn_init(rng, cfg, dtype, cross=True)}


def init_params(rng: jax.Array, cfg: ArchConfig) -> Params:
    dtype = model_dtype(cfg)
    ks = jax.random.split(rng, 8)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype, scale=1.0 / math.sqrt(cfg.d_model))
    layer_rngs = jax.random.split(ks[2], cfg.n_layers)
    p["layers"] = jax.vmap(lambda r: _layer_init(r, cfg, dtype))(layer_rngs)
    if cfg.family == "hybrid" and cfg.attn_every:
        p["shared_block"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.attn_init(ks[3], cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "ffn": ffn_init(ks[4], cfg.d_model, cfg.d_ff, dtype, gated=cfg.gated_ffn),
        }
    if cfg.is_encdec:
        enc_rngs = jax.random.split(ks[5], cfg.encoder_layers)
        p["encoder"] = jax.vmap(lambda r: _encoder_layer_init(r, cfg, dtype))(enc_rngs)
        p["enc_ln_f"] = jnp.ones((cfg.d_model,), dtype)
        cross_rngs = jax.random.split(ks[6], cfg.n_layers)
        p["cross"] = jax.vmap(lambda r: _cross_layer_init(r, cfg, dtype))(cross_rngs)
    return p


# ------------------------------------------------------------------ encoder
def _encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over (stubbed-frontend) frame embeddings."""
    x = shard(frames, "batch", "seq", "embed")

    def body(h, lp):
        a = attn.attention_train(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, "bidir", rope=False)
        h = h + a
        f = ffn_apply(lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps), gated=cfg.gated_ffn)
        return h + f, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


# ------------------------------------------------------------ decoder blocks
def _mixer_train(lp: Params, h: jax.Array, cfg: ArchConfig, idx) -> jax.Array:
    """Sequence mixer (attention or SSD) on a normalized input, train path."""
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        out, _ = ssm_mod.ssm_apply(lp["ssm"], h, cfg)
        return out
    if cfg.attn_kind == "mla":
        return attn.mla_train(lp["attn"], h, cfg)
    if cfg.attn_kind == "chunked" and cfg.global_every:
        return jax.lax.cond(
            _is_global_layer(cfg, idx),
            lambda q: attn.attention_train(lp["attn"], q, cfg, "full"),
            lambda q: attn.attention_train(lp["attn"], q, cfg, "chunked", cfg.window),
            h,
        )
    return attn.attention_train(lp["attn"], h, cfg, cfg.attn_kind, cfg.window)


def _channel_train(lp: Params, h: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.is_moe:
        return moe_mod.moe_apply(lp["moe"], h, cfg)
    return ffn_apply(lp["ffn"], h, gated=cfg.gated_ffn), jnp.asarray(0.0, jnp.float32)


def _shared_block_train(sp: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """zamba2 shared attention+MLP block (train path)."""
    a = attn.attention_train(sp["attn"], rms_norm(x, sp["ln1"], cfg.norm_eps), cfg, cfg.attn_kind, cfg.window)
    x = x + a
    f = ffn_apply(sp["ffn"], rms_norm(x, sp["ln2"], cfg.norm_eps), gated=cfg.gated_ffn)
    return x + f


REMAT_POLICIES = {
    "none": None,
    "full": "full",
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _decoder_train(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    enc_out: Optional[jax.Array],
    remat: str = "none",
) -> Tuple[jax.Array, jax.Array]:
    """Scan the decoder stack; returns (hidden, aux_loss_sum)."""
    idxs = jnp.arange(cfg.n_layers)
    shared = params.get("shared_block")
    cross = params.get("cross")

    def body(carry, inp):
        h, aux = carry
        lp, idx = inp[0], inp[1]
        a = _mixer_train(lp, rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, idx)
        h = h + a
        h = shard(h, "batch", "seq", "embed")
        if cross is not None:
            cp = inp[2]
            ca = attn.attention_train(cp["attn"], rms_norm(h, cp["ln"], cfg.norm_eps), cfg, "bidir", kv_x=enc_out, rope=False)
            h = h + ca
        if "ln2" in lp:
            f, a_loss = _channel_train(lp, rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
            h = h + f
            aux = aux + a_loss
        if shared is not None:
            h = jax.lax.cond(
                idx % cfg.attn_every == 0,
                lambda q: _shared_block_train(shared, q, cfg),
                lambda q: q,
                h,
            )
        h = shard(h, "batch", "seq", "embed")
        return (h, aux), None

    xs = (params["layers"], idxs) if cross is None else (params["layers"], idxs, cross)
    fn = body
    if remat != "none":
        policy = REMAT_POLICIES[remat]
        fn = jax.checkpoint(body, policy=None if policy == "full" else policy)
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.asarray(0.0, jnp.float32)), xs)
    return x, aux


def _embed_tokens(params: Params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]  # gather; vocab sharded → all-gather of slices
    return shard(x, "batch", "seq", "embed")


def _logits(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab")


def forward_train(
    params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], remat: str = "none"
) -> Tuple[jax.Array, jax.Array]:
    """batch: tokens (B,S_text) [+ 'prefix' (B,P,D) | 'frames' (B,F,D)]."""
    x = _embed_tokens(params, cfg, batch["tokens"])
    if cfg.frontend == "vision" and "prefix" in batch:
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
        x = shard(x, "batch", "seq", "embed")
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["frames"])
    x, aux = _decoder_train(params, cfg, x, enc_out, remat=remat)
    return _logits(params, cfg, x), aux


def loss_fn(
    params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], remat: str = "none"
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_train(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "prefix" in batch:
        # loss only over text positions (prefix contributes context)
        logits = logits[:, batch["prefix"].shape[1] :]
    mask = (labels >= 0).astype(jnp.float32)
    xent = cross_entropy_loss(logits, jnp.maximum(labels, 0), mask)
    total = xent + cfg.router_aux_coef * aux
    return total, {"loss": total, "xent": xent, "aux": aux}


# ------------------------------------------------------------------- caches
def init_cache(cfg: ArchConfig, batch: int, context: int) -> Params:
    """Stacked (per-layer leading dim) decode cache."""
    dtype = model_dtype(cfg)
    L = cfg.n_layers
    cache: Params = {}
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        cache["ssm"] = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
    if fam == "hybrid" and cfg.attn_every:
        n_inv = (L + cfg.attn_every - 1) // cfg.attn_every
        one = attn.init_kv_cache(cfg, batch, context, dtype)
        cache["shared_attn"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_inv,) + a.shape).copy(), one
        )
    if fam not in ("ssm", "hybrid"):
        if cfg.attn_kind == "mla":
            one = attn.init_mla_cache(cfg, batch, context, dtype)
        else:
            one = attn.init_kv_cache(cfg, batch, context, dtype)
        cache["kv"] = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
    if cfg.is_encdec:
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache["cross_kv"] = {
            "k": jnp.zeros((L, batch, cfg.encoder_seq, kv, hd), dtype),
            "v": jnp.zeros((L, batch, cfg.encoder_seq, kv, hd), dtype),
        }
    return cache


def _shard_cache(cache: Params) -> Params:
    def ann(path, a):
        if a.ndim == 5:  # (L,B,S,KV,hd)
            return shard(a, None, "batch", "seq_kv", "kv_heads", "head_dim")
        if a.ndim == 4:
            return shard(a, None, "batch", "seq_kv", None)
        return a

    return jax.tree_util.tree_map_with_path(ann, cache)


# ------------------------------------------------------------------ prefill
def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], cache: Params) -> Tuple[jax.Array, Params]:
    """Process the prompt; returns (logits for the last position, cache)."""
    x = _embed_tokens(params, cfg, batch["tokens"])
    if cfg.frontend == "vision" and "prefix" in batch:
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
    enc_out = _encode(params, cfg, batch["frames"]) if cfg.is_encdec else None
    idxs = jnp.arange(cfg.n_layers)
    shared = params.get("shared_block")
    cross = params.get("cross")
    new_cache = dict(cache)

    if cfg.is_encdec:
        # cross K/V computed once per request
        def cross_kv(cp):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"])
            return k, v

        ck, cv = jax.vmap(cross_kv)(cross)
        new_cache["cross_kv"] = {"k": ck.astype(model_dtype(cfg)), "v": cv.astype(model_dtype(cfg))}

    def body(carry, inp):
        h = carry
        lp, idx, lc = inp[0], inp[1], inp[2]
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        out_lc = lc
        fam = cfg.family
        if fam in ("ssm", "hybrid"):
            a, new_state = ssm_mod.ssm_apply(lp["ssm"], hn, cfg, state=lc.get("ssm_slice"))
            out_lc = dict(lc)
            out_lc["ssm_slice"] = new_state
        elif cfg.attn_kind == "mla":
            a, kvc = attn.mla_prefill(lp["attn"], hn, cfg, lc["kv_slice"])
            out_lc = dict(lc)
            out_lc["kv_slice"] = kvc
        else:
            if cfg.attn_kind == "chunked" and cfg.global_every:
                a, kvc = jax.lax.cond(
                    _is_global_layer(cfg, idx),
                    lambda q, c: attn.attention_prefill(lp["attn"], q, cfg, c, "full"),
                    lambda q, c: attn.attention_prefill(lp["attn"], q, cfg, c, "chunked", cfg.window),
                    hn,
                    lc["kv_slice"],
                )
            else:
                a, kvc = attn.attention_prefill(lp["attn"], hn, cfg, lc["kv_slice"], cfg.attn_kind, cfg.window)
            out_lc = dict(lc)
            out_lc["kv_slice"] = kvc
        h = h + a
        if cross is not None:
            cp = inp[3]
            ca = attn.attention_train(cp["attn"], rms_norm(h, cp["ln"], cfg.norm_eps), cfg, "bidir", kv_x=enc_out, rope=False)
            h = h + ca
        if "ln2" in lp:
            f, _ = _channel_train(lp, rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
            h = h + f
        if shared is not None:
            inv = idx // cfg.attn_every

            def with_attn(q, sc):
                sl = jax.tree.map(lambda a: a[inv], sc)
                a2, new_sl = attn.attention_prefill(sp_attn(shared), rms_norm(q, shared["ln1"], cfg.norm_eps), cfg, sl, cfg.attn_kind, cfg.window)
                q = q + a2
                f2 = ffn_apply(shared["ffn"], rms_norm(q, shared["ln2"], cfg.norm_eps), gated=cfg.gated_ffn)
                sc = jax.tree.map(lambda full, piece: jax.lax.dynamic_update_index_in_dim(full, piece.astype(full.dtype), inv, 0), sc, new_sl)
                return q + f2, sc

            h, sa = jax.lax.cond(
                idx % cfg.attn_every == 0,
                with_attn,
                lambda q, sc: (q, sc),
                h,
                out_lc["shared_attn_all"],
            )
            out_lc = dict(out_lc)
            out_lc["shared_attn_all"] = sa
        return h, out_lc

    # assemble per-layer xs
    layer_xs: Dict[str, Any] = {}
    if "ssm" in cache:
        layer_xs["ssm_slice"] = cache["ssm"]
    if "kv" in cache:
        layer_xs["kv_slice"] = cache["kv"]
    # shared_attn is carried, not scanned — thread via carry below if present
    if "shared_attn" in cache:
        return _prefill_hybrid(params, cfg, x, cache, layer_xs)
    xs = (params["layers"], idxs, layer_xs) if cross is None else (params["layers"], idxs, layer_xs, cross)
    x, out_layer_caches = jax.lax.scan(body, x, xs)
    for k_ in ("ssm_slice", "kv_slice"):
        if k_ in out_layer_caches:
            new_cache[{"ssm_slice": "ssm", "kv_slice": "kv"}[k_]] = out_layer_caches[k_]
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, new_cache


def sp_attn(shared: Params) -> Params:
    return shared["attn"]


def _prefill_hybrid(params, cfg, x, cache, layer_xs):
    """zamba2 prefill: ssm states scanned, shared-attn cache carried."""
    idxs = jnp.arange(cfg.n_layers)
    shared = params["shared_block"]

    def body(carry, inp):
        h, sa = carry
        lp, idx, lc = inp
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        a, new_state = ssm_mod.ssm_apply(lp["ssm"], hn, cfg, state=lc["ssm_slice"])
        h = h + a
        inv = idx // cfg.attn_every

        def with_attn(q, sc):
            sl = jax.tree.map(lambda t: t[inv], sc)
            a2, new_sl = attn.attention_prefill(
                shared["attn"], rms_norm(q, shared["ln1"], cfg.norm_eps), cfg, sl, cfg.attn_kind, cfg.window
            )
            q = q + a2
            f2 = ffn_apply(shared["ffn"], rms_norm(q, shared["ln2"], cfg.norm_eps), gated=cfg.gated_ffn)
            sc = jax.tree.map(
                lambda full, piece: jax.lax.dynamic_update_index_in_dim(full, piece.astype(full.dtype), inv, 0),
                sc,
                new_sl,
            )
            return q + f2, sc

        h, sa = jax.lax.cond(idx % cfg.attn_every == 0, with_attn, lambda q, sc: (q, sc), h, sa)
        return (h, sa), {"ssm_slice": new_state}

    (x, sa), outs = jax.lax.scan(body, (x, cache["shared_attn"]), (params["layers"], idxs, layer_xs))
    new_cache = dict(cache)
    new_cache["ssm"] = outs["ssm_slice"]
    new_cache["shared_attn"] = sa
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, new_cache


# -------------------------------------------------------------- decode step
def decode_step(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, 1)
    positions: jax.Array,  # (B,) absolute position of the new token
    cache: Params,
) -> Tuple[jax.Array, Params]:
    x = _embed_tokens(params, cfg, tokens)
    idxs = jnp.arange(cfg.n_layers)
    shared = params.get("shared_block")
    cross = params.get("cross")
    new_cache = dict(cache)

    layer_xs: Dict[str, Any] = {}
    if "ssm" in cache:
        layer_xs["ssm_slice"] = cache["ssm"]
    if "kv" in cache:
        layer_xs["kv_slice"] = cache["kv"]
    if "cross_kv" in cache:
        layer_xs["cross_slice"] = cache["cross_kv"]

    def body(carry, inp):
        h, sa = carry
        lp, idx, lc = inp[0], inp[1], inp[2]
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        out_lc = dict(lc)
        fam = cfg.family
        if fam in ("ssm", "hybrid"):
            a, ns = ssm_mod.ssm_decode(lp["ssm"], hn, cfg, lc["ssm_slice"])
            out_lc["ssm_slice"] = ns
        elif cfg.attn_kind == "mla":
            a, kvc = attn.mla_decode(lp["attn"], hn, cfg, lc["kv_slice"], positions)
            out_lc["kv_slice"] = kvc
        else:
            if cfg.attn_kind == "chunked" and cfg.global_every:
                a, kvc = jax.lax.cond(
                    _is_global_layer(cfg, idx),
                    lambda q, c: attn.attention_decode(lp["attn"], q, cfg, c, positions, "full"),
                    lambda q, c: attn.attention_decode(lp["attn"], q, cfg, c, positions, "chunked", cfg.window),
                    hn,
                    lc["kv_slice"],
                )
            else:
                a, kvc = attn.attention_decode(lp["attn"], hn, cfg, lc["kv_slice"], positions, cfg.attn_kind, cfg.window)
            out_lc["kv_slice"] = kvc
        h = h + a
        if cross is not None:
            cp = inp[3]
            ck, cv_ = lc["cross_slice"]["k"], lc["cross_slice"]["v"]
            hq = rms_norm(h, cp["ln"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", hq, cp["attn"]["wq"])
            g = cfg.n_heads // cfg.n_kv_heads
            b = q.shape[0]
            qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.resolved_head_dim)
            sc = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck).astype(jnp.float32) / math.sqrt(cfg.resolved_head_dim)
            pr = jax.nn.softmax(sc, axis=-1).astype(cv_.dtype)
            ca = jnp.einsum("bkgqs,bskh->bqkgh", pr, cv_).reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim)
            h = h + jnp.einsum("bshk,hkd->bsd", ca, cp["attn"]["wo"])
        if "ln2" in lp:
            f, _ = _channel_train(lp, rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
            h = h + f
        if shared is not None:
            inv = idx // cfg.attn_every

            def with_attn(q, sc2):
                sl = jax.tree.map(lambda t: t[inv], sc2)
                a2, new_sl = attn.attention_decode(
                    shared["attn"], rms_norm(q, shared["ln1"], cfg.norm_eps), cfg, sl, positions, cfg.attn_kind, cfg.window
                )
                q = q + a2
                f2 = ffn_apply(shared["ffn"], rms_norm(q, shared["ln2"], cfg.norm_eps), gated=cfg.gated_ffn)
                sc2 = jax.tree.map(
                    lambda full, piece: jax.lax.dynamic_update_index_in_dim(full, piece.astype(full.dtype), inv, 0),
                    sc2,
                    new_sl,
                )
                return q + f2, sc2

            h, sa = jax.lax.cond(idx % cfg.attn_every == 0, with_attn, lambda q, s: (q, s), h, sa)
        return (h, sa), out_lc

    sa0 = cache.get("shared_attn", jnp.zeros((1,), jnp.int32))
    xs = (params["layers"], idxs, layer_xs) if cross is None else (params["layers"], idxs, layer_xs, cross)
    (x, sa), out_layer = jax.lax.scan(body, (x, sa0), xs)
    for src, dst in (("ssm_slice", "ssm"), ("kv_slice", "kv")):
        if src in out_layer:
            new_cache[dst] = out_layer[src]
    if "shared_attn" in cache:
        new_cache["shared_attn"] = sa
    logits = _logits(params, cfg, x)
    return logits, new_cache
