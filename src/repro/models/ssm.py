"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation inside fixed-size chunks, a linear recurrence across chunk
states (``lax.scan``).  Decode is the O(1)-state recurrent step.  The
Pallas SSD kernel in :mod:`repro.kernels.ssd_scan` implements the same
chunk computation for TPU; this module is the reference lowering the
dry-run compiles (same FLOPs/layout contract).

Layout notes: heads shard over the "model" mesh axis (``ssm_heads``); the
chunk-state scan carries (B, H, P, N) — inter-chunk traffic is tiny, which
is why SSMs run the ``long_500k`` cell (O(1) decode state, no KV cache).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.logical import shard
from .layers import Params, dense_init, rms_norm

__all__ = ["ssm_init", "ssm_apply", "init_ssm_cache", "ssm_decode", "ssd_chunked"]


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    n_groups = 1
    d_state = cfg.ssm_state
    conv_dim = d_in + 2 * n_groups * d_state
    return d_in, n_heads, n_groups, d_state, conv_dim


def ssm_init(rng: jax.Array, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_in, n_heads, n_groups, d_state, conv_dim = _dims(cfg)
    ks = jax.random.split(rng, 5)
    # in_proj emits [z, x, B, C, dt]
    proj_out = 2 * d_in + 2 * n_groups * d_state + n_heads
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 1e-2))).astype(jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum(a[j+1..i])."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P) pre-discretized inputs (x * dt)
    a_dt: jax.Array,  # (B, S, H)  A * dt (negative)
    b: jax.Array,  # (B, S, G, N)
    c: jax.Array,  # (B, S, G, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """The SSD chunked algorithm; returns (y (B,S,H,P), final_state)."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    s_orig = s
    if s % chunk:
        # pad to a chunk multiple; padded steps are identity on the state
        # (a_dt = 0 → decay 1, x = B = 0 → no contribution)
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a_dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    a_cum = jnp.cumsum(ac, axis=2)  # (B,nc,Q,H)

    from ..kernels import ops as _kops

    mode = _kops.kernel_mode()
    if mode.startswith("pallas"):
        # TPU hot-spot path: Pallas kernel for steps 1+2 (intra-chunk block)
        yk, sk = _kops.ssd_chunk_kernel(
            a_dt.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2),
            xc.transpose(0, 3, 1, 2, 4),
            b.reshape(bsz, nc, chunk, g, n).transpose(0, 3, 1, 2, 4),
            c.reshape(bsz, nc, chunk, g, n).transpose(0, 3, 1, 2, 4),
            interpret=mode == "pallas-interpret",
        )
        y_diag = yk.transpose(0, 2, 3, 1, 4)  # (B,nc,Q,H,P)
        states = sk.transpose(0, 2, 1, 3, 4).astype(x.dtype)  # (B,nc,H,P,N)
        cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    else:
        bc = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3)  # (B,nc,Q,H,N)
        cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3)

        # 1. intra-chunk (the "attention-like" quadratic block)
        L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
        y_diag = jnp.einsum(
            "bclhn,bcshn,bchls,bcshp->bclhp", cc, bc, L.astype(cc.dtype), xc
        )

        # 2. per-chunk final states
        decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,Q,H)
        states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", bc, decay_states.astype(bc.dtype), xc)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), x.dtype)

    def step(s_prev, inp):
        decay, st = inp  # (B,H), (B,H,P,N)
        s_new = decay[..., None, None].astype(st.dtype) * s_prev + st
        return s_new, s_prev

    final_state, prev_states = jax.lax.scan(
        step, init_state, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4. state → output contribution
    state_decay = jnp.exp(a_cum)  # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp", cc, prev_states, state_decay.astype(cc.dtype)
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y, final_state


def _in_proj_split(p: Params, u: jax.Array, cfg: ArchConfig):
    d_in, n_heads, n_groups, d_state, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim :]  # (B,S,H)
    return z, xbc, dt


def _conv_apply(p: Params, xbc: jax.Array, conv_state: Optional[jax.Array], cfg: ArchConfig):
    """Depthwise causal conv1d over (B,S,conv_dim); returns (out, new_state)."""
    k = cfg.ssm_conv
    if conv_state is not None:
        xbc_full = jnp.concatenate([conv_state, xbc], axis=1)
    else:
        xbc_full = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    s = xbc.shape[1]
    # sum_k w[k] * x[t - (K-1) + k]
    out = sum(
        xbc_full[:, i : i + s] * p["conv_w"][i][None, None, :] for i in range(k)
    )
    out = jax.nn.silu(out + p["conv_b"])
    new_state = xbc_full[:, -(k - 1) :] if k > 1 else jnp.zeros_like(xbc[:, :0])
    return out, new_state


def _ssd_inputs(p: Params, xbc: jax.Array, dt: jax.Array, cfg: ArchConfig):
    d_in, n_heads, n_groups, d_state, _ = _dims(cfg)
    x = xbc[..., :d_in]
    b = xbc[..., d_in : d_in + n_groups * d_state]
    c = xbc[..., d_in + n_groups * d_state :]
    bsz, s = x.shape[:2]
    x = x.reshape(bsz, s, n_heads, cfg.ssm_head_dim)
    b = b.reshape(bsz, s, n_groups, d_state)
    c = c.reshape(bsz, s, n_groups, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])  # (H,)
    return x, b, c, dt, a


def ssm_apply(
    p: Params,
    u: jax.Array,
    cfg: ArchConfig,
    state: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Full-sequence SSD pass.  ``state`` (prefill) is populated/returned."""
    z, xbc, dt = _in_proj_split(p, u, cfg)
    xbc, conv_state = _conv_apply(p, xbc, None, cfg)
    x, b, c, dt, a = _ssd_inputs(p, xbc, dt, cfg)
    x = shard(x, "batch", "seq", "ssm_heads", None)
    xd = x * dt[..., None].astype(x.dtype)
    a_dt = a * dt  # (B,S,H)
    y, final_state = ssd_chunked(xd, a_dt, b, c, cfg.ssm_chunk)
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    bsz, s = u.shape[:2]
    y = y.reshape(bsz, s, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = None
    if state is not None:
        # decode state: final SSM state + last (K-1) pre-activation inputs
        new_state = {"ssm": final_state.astype(state["ssm"].dtype), "conv": conv_state.astype(state["conv"].dtype)}
    return out, new_state


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    d_in, n_heads, n_groups, d_state, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_head_dim, d_state), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssm_decode(
    p: Params, u: jax.Array, cfg: ArchConfig, state: Params
) -> Tuple[jax.Array, Params]:
    """Single-token recurrent step.  u: (B,1,D)."""
    d_in, n_heads, n_groups, d_state, conv_dim = _dims(cfg)
    z, xbc, dt = _in_proj_split(p, u, cfg)
    # conv over [state ‖ new token]
    window = jnp.concatenate([state["conv"], xbc], axis=1)  # (B,K,conv_dim)
    out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(out)[:, None, :]
    new_conv = window[:, 1:]
    x, b, c, dt, a = _ssd_inputs(p, xbc_t, dt, cfg)
    # recurrence: s = exp(a·dt)·s + dt·B ⊗ x
    decay = jnp.exp(a * dt[:, 0])  # (B,H)
    bsz = u.shape[0]
    rep = n_heads // n_groups
    b1 = jnp.repeat(b[:, 0], rep, axis=1)  # (B,H,N)
    c1 = jnp.repeat(c[:, 0], rep, axis=1)
    xd = x[:, 0] * dt[:, 0, :, None].astype(x.dtype)  # (B,H,P)
    s_new = decay[..., None, None].astype(state["ssm"].dtype) * state["ssm"] + jnp.einsum(
        "bhp,bhn->bhpn", xd, b1
    ).astype(state["ssm"].dtype)
    y = jnp.einsum("bhpn,bhn->bhp", s_new, c1)  # (B,H,P)
    y = y + x[:, 0] * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"ssm": s_new, "conv": new_conv}
