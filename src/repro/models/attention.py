"""Attention: GQA (full / sliding-window / chunked-local) and MLA.

Three entry points per flavour:

* ``*_train``  — full-sequence causal (or bidirectional) attention;
  q-chunked online-softmax scan keeps the logits working set bounded
  (the XLA analogue of the Pallas flash kernel in ``repro.kernels``; the
  kernel is the TPU hot-spot implementation, this is the lowering used by
  the dry-run and CPU tests — same FLOPs, same numerics contract).
* ``*_prefill`` — train-path attention + KV-cache population.
* ``*_decode`` — single-token step against the cache.

The KV cache is a uniform ring buffer: ``S_slots`` = full context for dense
archs, ``window`` for SWA/chunked — each slot remembers its absolute
position, so masking is position-driven and one code path serves every
flavour (this is what makes ``long_500k`` a bounded-memory cell for
SWA/chunked archs).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.logical import shard
from .layers import Params, apply_rope, dense_init

__all__ = [
    "attn_init",
    "attention_train",
    "init_kv_cache",
    "attention_prefill",
    "attention_decode",
    "mla_init",
    "mla_train",
    "init_mla_cache",
    "mla_prefill",
    "mla_decode",
]

NEG_INF = -1e30


# ---------------------------------------------------------------- GQA params
def attn_init(rng: jax.Array, cfg: ArchConfig, dtype, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype),
        "wk": dense_init(ks[1], (d, kv, hd), dtype),
        "wv": dense_init(ks[2], (d, kv, hd), dtype),
        "wo": dense_init(ks[3], (h, hd, d), dtype, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def _project_qkv(
    p: Params, x: jax.Array, kv_x: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(B,S,D) → q (B,S,H,hd), k/v (B,Skv,KV,hd); kv_x for cross-attention."""
    kv_src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


# ------------------------------------------------------------- mask builders
def _mask_block(
    qpos: jax.Array, kpos: jax.Array, kind: str, window: int
) -> jax.Array:
    """(Sq, Skv) boolean visibility from absolute positions."""
    q = qpos[:, None]
    k = kpos[None, :]
    if kind == "bidir":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    causal = k <= q
    if kind == "full":
        return causal
    if kind == "swa":
        return causal & (k > q - window)
    if kind == "chunked":
        return causal & (k // window == q // window)
    raise ValueError(f"unknown attention kind {kind!r}")


# ------------------------------------------------- core (q-chunked, online)
def _attention_core(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    qpos: jax.Array,
    kpos: jax.Array,
    kind: str,
    window: int,
    q_chunk: int = 1024,
) -> jax.Array:
    """Scaled-dot-product GQA over full K/V, scanned over query chunks.

    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd); qpos: (Sq,), kpos: (Skv,).
    KV is additionally sliced per q-chunk for swa/chunked so sub-quadratic
    flavours cost O(S·window) rather than O(S²).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)

    # TPU deployment path: hand the whole call to the Pallas flash kernel
    from ..kernels import ops as _kops

    mode = _kops.kernel_mode()
    if (
        mode.startswith("pallas")
        and sq == skv
        and sq % 128 == 0
        and kind in ("full", "swa", "chunked", "bidir")
    ):
        return _kops.flash_attention(
            q,
            k,
            v,
            causal=kind != "bidir",
            window=window if kind == "swa" else 0,
            chunk=window if kind == "chunked" else 0,
            interpret=mode == "pallas-interpret",
        )

    # sequence-parallel path (§Perf): when the rules map "seq_act" to a
    # mesh axis, partition the score computation over the *query sequence*
    # instead of heads — the win for archs whose head counts don't divide
    # the TP axis (28/40/20 heads on a 16-way model axis would otherwise
    # replicate all attention compute and score traffic on every device).
    from ..sharding.logical import current_rules

    rules = current_rules()
    if rules is not None and rules.table.get("seq_act"):
        q = shard(q, "batch", "seq_act", "heads", "head_dim")
        qg = q.reshape(b, sq, kvh, g, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
        scores = shard(scores, "batch", "kv_heads", None, "seq_act", "seq_kv")
        mask = _mask_block(qpos, kpos, kind, window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        probs = shard(probs, "batch", "kv_heads", None, "seq_act", "seq_kv")
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v).reshape(b, sq, h, hd)
        return shard(out, "batch", "seq_act", "heads", "head_dim")

    cq = min(q_chunk, sq)
    n_chunks = sq // cq if sq % cq == 0 else 0
    if n_chunks == 0:  # ragged: single block
        cq, n_chunks = sq, 1

    # static KV slice length per chunk for bounded-window flavours
    if kind in ("swa", "chunked") and skv > window + cq:
        kv_len = window + cq if kind == "swa" else window
        kv_len = min(kv_len, skv)
    else:
        kv_len = skv

    qg = q.reshape(b, n_chunks, cq, kvh, g, hd).transpose(1, 0, 3, 4, 2, 5)
    # → (n_chunks, B, KV, G, cq, hd)
    qpos_c = qpos.reshape(n_chunks, cq)

    def chunk_attn(carry, inp):
        qc, qp = inp  # (B,KV,G,cq,hd), (cq,)
        if kv_len == skv:
            kc, vc, kp = k, v, kpos
        else:
            # slice the kv range this chunk can see
            if kind == "swa":
                start = jnp.clip(qp[-1] + 1 - kv_len, 0, skv - kv_len)
            else:  # chunked: the chunk containing the queries
                start = jnp.clip((qp[0] // window) * window, 0, skv - kv_len)
            kc = jax.lax.dynamic_slice_in_dim(k, start, kv_len, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, kv_len, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kpos, start, kv_len, axis=0)
        scores = jnp.einsum("bkgqh,bskh->bkgqs", qc, kc).astype(jnp.float32) * scale
        mask = _mask_block(qp, kp, kind, window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskh->bkgqh", probs, vc)
        return carry, out

    _, outs = jax.lax.scan(chunk_attn, None, (qg, qpos_c))
    # (n_chunks, B, KV, G, cq, hd) → (B, Sq, H, hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)
    return out


def attention_train(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    kind: str,
    window: int = 0,
    kv_x: Optional[jax.Array] = None,
    rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / encoder / cross)."""
    b, sq, _ = x.shape
    q, k, v = _project_qkv(p, x, kv_x)
    skv = k.shape[1]
    qpos = jnp.arange(sq, dtype=jnp.int32)
    kpos = jnp.arange(skv, dtype=jnp.int32)
    if rope:
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, kpos, cfg.rope_theta)
    out = _attention_core(q, k, v, qpos, kpos, kind, window)
    out = shard(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ------------------------------------------------------------------ KV cache
def init_kv_cache(cfg: ArchConfig, batch: int, context: int, dtype, window_only: bool = True) -> Params:
    """Ring-buffer cache.  ``S_slots`` = window for bounded flavours."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    slots = context
    if (
        window_only
        and cfg.attn_kind in ("swa", "chunked")
        and cfg.window
        and not cfg.global_every  # global layers need the full context
    ):
        slots = min(context, cfg.window)
    return {
        "k": jnp.zeros((batch, slots, kv, hd), dtype),
        "v": jnp.zeros((batch, slots, kv, hd), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def _cache_write_prefill(cache: Params, k: jax.Array, v: jax.Array, kpos: jax.Array) -> Params:
    """Write the last ``S_slots`` tokens of a prefill into the ring."""
    slots = cache["k"].shape[1]
    s = k.shape[1]
    if s >= slots:
        ktail, vtail, ptail = k[:, -slots:], v[:, -slots:], kpos[-slots:]
        # ring alignment: slot index = pos % slots
        roll = (ptail[0] % slots).astype(jnp.int32)
        ktail = jnp.roll(ktail, roll, axis=1)
        vtail = jnp.roll(vtail, roll, axis=1)
        ptail = jnp.roll(ptail, roll, axis=0)
        return {
            "k": ktail.astype(cache["k"].dtype),
            "v": vtail.astype(cache["v"].dtype),
            "pos": jnp.broadcast_to(ptail[None], cache["pos"].shape),
        }
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
    cp = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.broadcast_to(kpos[None], (k.shape[0], s)), 0, axis=1
    )
    return {"k": ck, "v": cv, "pos": cp}


def attention_prefill(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    cache: Params,
    kind: str,
    window: int = 0,
) -> Tuple[jax.Array, Params]:
    b, sq, _ = x.shape
    q, k, v = _project_qkv(p, x)
    qpos = jnp.arange(sq, dtype=jnp.int32)
    q = apply_rope(q, qpos, cfg.rope_theta)
    k = apply_rope(k, qpos, cfg.rope_theta)
    out = _attention_core(q, k, v, qpos, qpos, kind, window)
    new_cache = _cache_write_prefill(cache, k, v, qpos)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


def attention_decode(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    cache: Params,
    positions: jax.Array,
    kind: str,
    window: int = 0,
) -> Tuple[jax.Array, Params]:
    """One-token step.  x: (B,1,D); positions: (B,) absolute position of the
    new token per request (continuous batching: positions differ)."""
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kvh
    q, k, v = _project_qkv(p, x)  # (B,1,·,hd)
    q = apply_rope(q, positions[:, None], cfg.rope_theta)
    k = apply_rope(k, positions[:, None], cfg.rope_theta)
    slots = cache["k"].shape[1]
    slot = (positions % slots).astype(jnp.int32)
    # one-hot select instead of dynamic scatter: elementwise over the slot
    # dim partitions cleanly when the cache sequence is sharded (a dynamic
    # scatter forces GSPMD into involuntary full rematerialization of the
    # ring — caught by the §Perf HLO audit of the long_500k cells)
    slot_oh = jnp.arange(slots, dtype=jnp.int32)[None, :] == slot[:, None]  # (B, slots)
    ck = jnp.where(slot_oh[..., None, None], k[:, :1].astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(slot_oh[..., None, None], v[:, :1].astype(cache["v"].dtype), cache["v"])
    cpos = jnp.where(slot_oh, positions[:, None], cache["pos"])
    ck = shard(ck, "batch", "seq_kv", "kv_heads", "head_dim")
    cv = shard(cv, "batch", "seq_kv", "kv_heads", "head_dim")
    cpos = shard(cpos, "batch", "seq_kv")
    # visibility: position-tagged slots, per-request mask
    kp = cpos  # (B, slots)
    qp = positions[:, None]
    valid = kp >= 0
    visible = valid & (kp <= qp)
    if kind == "swa":
        visible &= kp > qp - window
    elif kind == "chunked":
        visible &= (kp // window) == (qp // window)
    qg = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(visible[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv).reshape(b, 1, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": ck, "v": cv, "pos": cpos}


# ============================================================== MLA (minicpm3)
def mla_init(rng: jax.Array, cfg: ArchConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 7)
    return {
        "wq_a": dense_init(ks[0], (d, qr), dtype),  # down-project q
        "wq_b": dense_init(ks[1], (qr, h, dn + dr), dtype),  # up-project q
        "wkv_a": dense_init(ks[2], (d, kvr + dr), dtype),  # latent + shared k_rope
        "wk_b": dense_init(ks[3], (kvr, h, dn), dtype),  # latent → per-head k_nope
        "wv_b": dense_init(ks[4], (kvr, h, dv), dtype),  # latent → per-head v
        "wo": dense_init(ks[5], (h, dv, d), dtype, scale=1.0 / math.sqrt(h * dv)),
    }


def _mla_qkv(p: Params, x: jax.Array, cfg: ArchConfig, qpos: jax.Array):
    """Project to q (nope‖rope), latent c_kv, shared k_rope."""
    kvr = cfg.kv_lora_rank
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,rhe->bshe", q, p["wq_b"])  # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, qpos, cfg.rope_theta)
    kv = jnp.einsum("bsd,de->bse", x, p["wkv_a"])  # (B,S,kvr+dr)
    c_kv, k_rope = kv[..., :kvr], kv[..., kvr:]
    k_rope = apply_rope(k_rope[:, :, None, :], qpos, cfg.rope_theta)[:, :, 0]
    c_kv = shard(c_kv, "batch", "seq", "latent")
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, mask, cfg):
    """Absorbed-matmul attention: scores live in latent space.

    With 40 heads on a 16-way model axis the head dim cannot shard, so the
    score tensors (B,H,Sq,T) are partitioned over the *query sequence*
    when the rules enable ``seq_act`` (sequence parallelism, §Perf)."""
    dn = cfg.nope_head_dim
    scale = 1.0 / math.sqrt(dn + cfg.rope_head_dim)
    q_nope = shard(q_nope, "batch", "seq_act", "heads", "head_dim")
    q_rope = shard(q_rope, "batch", "seq_act", "heads", "head_dim")
    # absorb wk_b into the query: q_lat (B,Sq,H,kvr)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["wk_b"])
    s_nope = jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
    s_rope = jnp.einsum("bshe,bte->bhst", q_rope, k_rope)
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    scores = shard(scores, "batch", "heads", "seq_act", "seq_kv")
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    probs = shard(probs, "batch", "heads", "seq_act", "seq_kv")
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv)
    out = jnp.einsum("bshr,rhe->bshe", ctx_lat, p["wv_b"])  # (B,Sq,H,dv)
    out = shard(out, "batch", "seq_act", "heads", "head_dim")
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def _mla_attend_reconstructed(p, q_nope, q_rope, c_kv, k_rope, mask, cfg):
    """Full-sequence MLA via per-head K/V reconstruction (§Perf iteration):
    the absorbed form scores in latent space (kv_rank+rope = 288 wide); at
    prefill/train the reconstructed form scores per head (96 wide) —
    ~2.4× fewer attention FLOPs, with the reconstruction matmuls linear in
    sequence length.  Absorption stays the decode path (where the latent
    cache is the point)."""
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)
    k_nope = jnp.einsum("btr,rhe->bthe", c_kv, p["wk_b"])  # (B,T,H,dn)
    v = jnp.einsum("btr,rhe->bthe", c_kv, p["wv_b"])  # (B,T,H,dv)
    q_nope = shard(q_nope, "batch", "seq_act", "heads", "head_dim")
    q_rope = shard(q_rope, "batch", "seq_act", "heads", "head_dim")
    s_nope = jnp.einsum("bshe,bthe->bhst", q_nope, k_nope)
    s_rope = jnp.einsum("bshe,bte->bhst", q_rope, k_rope)
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    scores = shard(scores, "batch", "heads", "seq_act", "seq_kv")
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    probs = shard(probs, "batch", "heads", "seq_act", "seq_kv")
    out = jnp.einsum("bhst,bthe->bshe", probs, v)
    out = shard(out, "batch", "seq_act", "heads", "head_dim")
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def mla_train(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, s, _ = x.shape
    qpos = jnp.arange(s, dtype=jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, qpos)
    mask = (qpos[:, None] >= qpos[None, :])[None, None]
    return _mla_attend_reconstructed(p, q_nope, q_rope, c_kv, k_rope, mask, cfg)


def init_mla_cache(cfg: ArchConfig, batch: int, context: int, dtype) -> Params:
    return {
        "c_kv": jnp.zeros((batch, context, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, context, cfg.rope_head_dim), dtype),
        "pos": jnp.full((batch, context), -1, jnp.int32),
    }


def mla_prefill(p: Params, x: jax.Array, cfg: ArchConfig, cache: Params) -> Tuple[jax.Array, Params]:
    b, s, _ = x.shape
    qpos = jnp.arange(s, dtype=jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, qpos)
    mask = (qpos[:, None] >= qpos[None, :])[None, None]
    out = _mla_attend_reconstructed(p, q_nope, q_rope, c_kv, k_rope, mask, cfg)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, axis=1)
    cp = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.broadcast_to(qpos[None], (b, s)), 0, axis=1
    )
    return out, {"c_kv": ck, "k_rope": kr, "pos": cp}


def mla_decode(
    p: Params, x: jax.Array, cfg: ArchConfig, cache: Params, positions: jax.Array
) -> Tuple[jax.Array, Params]:
    b = x.shape[0]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions[:, None])
    slot = positions  # full-context cache: slot == position
    slots = cache["c_kv"].shape[1]
    slot_oh = jnp.arange(slots, dtype=jnp.int32)[None, :] == slot[:, None]  # (B, slots)
    ck = jnp.where(slot_oh[..., None], c_kv[:, :1].astype(cache["c_kv"].dtype), cache["c_kv"])
    kr = jnp.where(slot_oh[..., None], k_rope[:, :1].astype(cache["k_rope"].dtype), cache["k_rope"])
    cp = jnp.where(slot_oh, positions[:, None], cache["pos"])
    ck = shard(ck, "batch", "seq_kv", "latent")
    kr = shard(kr, "batch", "seq_kv", None)
    cp = shard(cp, "batch", "seq_kv")
    mask = ((cp >= 0) & (cp <= positions[:, None]))[:, None, None, :]
    out = _mla_attend(p, q_nope, q_rope, ck, kr, mask, cfg)
    return out, {"c_kv": ck, "k_rope": kr, "pos": cp}
