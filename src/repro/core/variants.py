"""Named parcelport variants — one per configuration in paper Figs 6-9."""
from __future__ import annotations

from typing import Callable, Dict

from .device import LockMode
from .fabric import Fabric
from .lci_parcelport import LCIParcelport, LCIPPConfig
from .mpi_parcelport import MPIParcelport
from .parcelport import Locality, Parcelport

__all__ = ["VARIANTS", "make_parcelport_factory", "variant_names", "max_devices"]

# The paper's evaluated configurations.
VARIANTS: Dict[str, LCIPPConfig] = {
    # §4: the full-fledged LCI parcelport ("base" in §5 factor studies).
    "lci": LCIPPConfig(name="lci"),
    "base": LCIPPConfig(name="base"),
    # §5.1 asynchrony: two-sided header transfer keeps the completion queue…
    "sendrecv_queue": LCIPPConfig(name="sendrecv_queue", header_mode="sendrecv", header_comp="queue"),
    # …or drops to a single synchronizer (one pre-posted receive at a time).
    "sendrecv_sync": LCIPPConfig(name="sendrecv_sync", header_mode="sendrecv", header_comp="sync"),
    # §5.2 concurrency: synchronizer pool instead of completion queue for
    # everything except header dynamic puts.
    "sync": LCIPPConfig(name="sync", followup_comp="sync"),
    "queue_lock": LCIPPConfig(name="queue_lock", cq_kind="lock"),
    "queue_ms": LCIPPConfig(name="queue_ms", cq_kind="ms"),
    # §5.3 multithreading/progress: MPI-mimicking ladder.  All use
    # send/recv + synchronizers (completion queues don't work under coarse
    # locks, per the paper).
    "block": LCIPPConfig(
        name="block",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.BLOCK,
        progress_mode="implicit",
    ),
    "try": LCIPPConfig(
        name="try",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.TRY,
        progress_mode="implicit",
    ),
    "try_progress": LCIPPConfig(
        name="try_progress",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.TRY,
        progress_mode="explicit",
    ),
    # the catastrophic combination (§5.3): blocking lock + eager progress
    "progress": LCIPPConfig(
        name="progress",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.BLOCK,
        progress_mode="explicit",
    ),
    "block_d2": LCIPPConfig(
        name="block_d2",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=2,
        lock_mode=LockMode.BLOCK,
        progress_mode="implicit",
    ),
}

# device-scaling families (paper Fig 9)
for _n in (1, 2, 4, 8, 16, 32):
    VARIANTS[f"lci_d{_n}"] = LCIPPConfig(name=f"lci_d{_n}", ndevices=_n)
    VARIANTS[f"lci_try_d{_n}"] = LCIPPConfig(name=f"lci_try_d{_n}", ndevices=_n, lock_mode=LockMode.TRY)

# Protocol factor study (paper §3.3/§4.2: eager vs rendezvous selection).
# ``lci_noeager`` forces every parcel down the rendezvous path (header +
# follow-ups); the ``lci_eager*`` family raises the one-message limit so
# small zero-copy chunks ship inline through bounce buffers.
VARIANTS["lci_noeager"] = LCIPPConfig(name="lci_noeager", eager_threshold=0)
for _kib in (16, 64):
    VARIANTS[f"lci_eager_{_kib}k"] = LCIPPConfig(name=f"lci_eager_{_kib}k", eager_threshold=_kib * 1024)
VARIANTS["lci_eager"] = VARIANTS["lci_eager_16k"].variant(name="lci_eager")

# Threshold-aware aggregation (§2.2.2 x §3.3): merge same-destination
# parcels, but pack each aggregate only up to the eager threshold so it
# still ships as ONE eager message (fills one bounce buffer; never spills
# an eager-sized batch onto the rendezvous path).
VARIANTS["lci_agg_eager"] = LCIPPConfig(
    name="lci_agg_eager", aggregation=True, agg_eager=True, eager_threshold=16 * 1024
)


def variant_names():
    return ["mpi", "mpi_a"] + sorted(VARIANTS)


def max_devices(name: str) -> int:
    if name in ("mpi", "mpi_a"):
        return 1
    return VARIANTS[name].ndevices


def make_parcelport_factory(name: str) -> Callable[[Locality, Fabric], Parcelport]:
    """Factory for :class:`repro.core.parcelport.World`."""
    if name == "mpi":
        return lambda loc, fab: MPIParcelport(loc, fab, aggregation=False)
    if name == "mpi_a":
        return lambda loc, fab: MPIParcelport(loc, fab, aggregation=True)
    cfg = VARIANTS[name]
    return lambda loc, fab: LCIParcelport(loc, fab, cfg)
