"""Named parcelport variants — the paper's configurations (Figs 6-9) as a
composable registry.

Fixed names cover the factor-study matrix; **parameterized families**
(:class:`~repro.core.comm.registry.VariantSpec`) cover every axis that
sweeps a number, resolved on demand without pre-registration:

* ``lci_d{n}`` / ``lci_try_d{n}`` — device replication (paper Fig 9);
* ``lci_eager_{k}k`` — eager/rendezvous threshold at ``k`` KiB (§3.3/§4.2);
* ``lci_b{depth}`` — **bounded injection** (§3.3.4): send ring and bounce
  pool both ``depth`` deep, via the shared
  :class:`~repro.core.comm.resources.ResourceLimits` — the same object the
  fabric sizes its rings from and the DES simulates, so
  ``make_parcelport_factory("lci_b8")`` and ``sim_config_for_variant
  ("lci_b8")`` can never disagree about what "8" bounds.

``VARIANTS`` remains a dict-compatible view for legacy call sites; every
pre-existing name resolves to a config equal to its old hard-coded value
(regression-tested in tests/test_comm_interface.py).
"""
from __future__ import annotations

from typing import Callable

from .comm.registry import RegistryView, VariantRegistry, VariantSpec
from .comm.resources import ResourceLimits
from .device import LockMode
from .fabric import Fabric
from .lci_parcelport import LCIPPConfig, LCIParcelport
from .mpi_parcelport import MPIParcelport
from .parcelport import Locality, Parcelport

__all__ = [
    "REGISTRY",
    "SERVE_REGISTRY",
    "SERVE_VARIANTS",
    "make_parcelport_factory",
    "make_fleet_config",
    "variant_names",
    "fleet_variant_names",
    "variant_limits",
    "max_devices",
]

REGISTRY = VariantRegistry()

# Default bounce-buffer size for the bounded-injection family: matches the
# fabric's default registered-buffer size, comfortably above the 16 KiB
# eager threshold.
_B_FAMILY_BUF_SIZE = 64 * 1024

# -- fixed variants (the paper's evaluated configurations) -------------------
_FIXED = {
    # §4: the full-fledged LCI parcelport ("base" in §5 factor studies).
    "lci": lambda: LCIPPConfig(name="lci"),
    "base": lambda: LCIPPConfig(name="base"),
    # §5.1 asynchrony: two-sided header transfer keeps the completion queue…
    "sendrecv_queue": lambda: LCIPPConfig(name="sendrecv_queue", header_mode="sendrecv", header_comp="queue"),
    # …or drops to a single synchronizer (one pre-posted receive at a time).
    "sendrecv_sync": lambda: LCIPPConfig(name="sendrecv_sync", header_mode="sendrecv", header_comp="sync"),
    # §5.2 concurrency: synchronizer pool instead of completion queue for
    # everything except header dynamic puts.
    "sync": lambda: LCIPPConfig(name="sync", followup_comp="sync"),
    "queue_lock": lambda: LCIPPConfig(name="queue_lock", cq_kind="lock"),
    "queue_ms": lambda: LCIPPConfig(name="queue_ms", cq_kind="ms"),
    # §5.3 multithreading/progress: MPI-mimicking ladder.  All use
    # send/recv + synchronizers (completion queues don't work under coarse
    # locks, per the paper).
    "block": lambda: LCIPPConfig(
        name="block",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.BLOCK,
        progress_mode="implicit",
    ),
    "try": lambda: LCIPPConfig(
        name="try",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.TRY,
        progress_mode="implicit",
    ),
    "try_progress": lambda: LCIPPConfig(
        name="try_progress",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.TRY,
        progress_mode="explicit",
    ),
    # the catastrophic combination (§5.3): blocking lock + eager progress
    "progress": lambda: LCIPPConfig(
        name="progress",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=1,
        lock_mode=LockMode.BLOCK,
        progress_mode="explicit",
    ),
    "block_d2": lambda: LCIPPConfig(
        name="block_d2",
        header_mode="sendrecv",
        header_comp="sync",
        followup_comp="sync",
        ndevices=2,
        lock_mode=LockMode.BLOCK,
        progress_mode="implicit",
    ),
    # Protocol factor study (§3.3/§4.2): force every parcel down the
    # rendezvous path / alias the calibrated 16 KiB eager default.
    "lci_noeager": lambda: LCIPPConfig(name="lci_noeager", eager_threshold=0),
    "lci_eager": lambda: LCIPPConfig(name="lci_eager", eager_threshold=16 * 1024),
    # Threshold-aware aggregation (§2.2.2 x §3.3): merge same-destination
    # parcels, but pack each aggregate only up to the eager threshold so it
    # still ships as ONE eager message (fills one bounce buffer; never
    # spills an eager-sized batch onto the rendezvous path).
    "lci_agg_eager": lambda: LCIPPConfig(
        name="lci_agg_eager", aggregation=True, agg_eager=True, eager_threshold=16 * 1024
    ),
    # Completion-routing topology (§3.3.3): ONE completion queue shared
    # across devices — LCI's load-balancing default, named so the
    # CompletionRouter axis is sweepable against per-device queues
    # (`.variant(cq_scope='device')`).
    "lci_shared_cq": lambda: LCIPPConfig(name="lci_shared_cq", cq_scope="shared"),
    # The JAX-collectives backend (the serving stack's transport): same
    # parcelport protocol logic, CollectiveComm endpoints instead of LCI
    # devices.  No one-sided put, so headers ride two-sided send/recv BY
    # CAPABILITY — the config states the honest path up front.
    "collective": lambda: LCIPPConfig(name="collective", header_mode="sendrecv", header_comp="queue"),
    # The shared-memory backend (ISSUE 6): the one transport with a TRUE
    # one-sided put, run at every rung of the paper's capability ladder
    # (§3.3.1).  The three rungs reuse the shared header_mode/header_comp
    # axes, so the DES resolves them with no new config fields:
    #   shmem      — two-sided emulation over the same receiver-owned slots;
    #   shmem_put  — put-signal: raised per-slot flags, discovered by a
    #                serialized scan (header_comp='sync');
    #   shmem_putq — put + queue-completion: descriptors enqueued straight
    #                into the receiver's completion ring (the paper's
    #                preferred primitive).
    "shmem": lambda: LCIPPConfig(name="shmem", header_mode="sendrecv", header_comp="queue"),
    "shmem_put": lambda: LCIPPConfig(name="shmem_put", header_mode="put", header_comp="sync"),
    "shmem_putq": lambda: LCIPPConfig(name="shmem_putq", header_mode="put", header_comp="queue"),
}
for _name, _build in _FIXED.items():
    REGISTRY.register(_name, _build)

# -- parameterized families --------------------------------------------------
# device-scaling families (paper Fig 9)
REGISTRY.register_family(VariantSpec(
    grammar="lci_d{n}",
    build=lambda name, n: LCIPPConfig(name=name, ndevices=n),
    canonical=((1,), (2,), (4,), (8,), (16,), (32,)),
    doc="device-replication scaling (lock-free)",
))
REGISTRY.register_family(VariantSpec(
    grammar="lci_try_d{n}",
    build=lambda name, n: LCIPPConfig(name=name, ndevices=n, lock_mode=LockMode.TRY),
    canonical=((1,), (2,), (4,), (8,), (16,), (32,)),
    doc="device scaling under a coarse try lock",
))
# eager-threshold family (§3.3/§4.2: the one-message limit in KiB)
REGISTRY.register_family(VariantSpec(
    grammar="lci_eager_{k}k",
    build=lambda name, k: LCIPPConfig(name=name, eager_threshold=k * 1024),
    canonical=((16,), (64,)),
    doc="eager protocol up to {k} KiB",
))
# progress-policy family (§3.3.4, the paper's omitted experiment): n cores
# reserved to ONLY drive the progress engine (ROLE_PROGRESS threads in the
# functional layer, reserved DES workers in the simulator).  n=0 is the
# all-workers-poll baseline (explicit progress on every worker, plain lci);
# n>0 task workers drop to implicit polling — the dedicated workers own the
# eager progress, matching how such runtimes are actually deployed.
REGISTRY.register_family(VariantSpec(
    grammar="lci_prg{n}",
    build=lambda name, n: LCIPPConfig(
        name=name,
        progress_workers=n,
        progress_mode="explicit" if n == 0 else "implicit",
    ),
    canonical=((0,), (2,)),
    doc="dedicated progress workers: {n} reserved cores drive the engine (0 = all workers poll)",
))
# collective-backend progress family: the JAX-collectives transport under
# n dedicated progress workers — the serving stack's progress-policy axis,
# mirroring lci_prg{n} over the other backend.
REGISTRY.register_family(VariantSpec(
    grammar="collective_prg{n}",
    build=lambda name, n: LCIPPConfig(
        name=name,
        header_mode="sendrecv",
        header_comp="queue",
        progress_workers=n,
        progress_mode="explicit" if n == 0 else "implicit",
    ),
    canonical=((2,),),
    doc="collective backend with {n} dedicated progress workers",
))
# shmem-backend progress family: put + queue-completion (the top ladder
# rung) under n dedicated progress workers — the shared-memory transport's
# progress-policy axis, mirroring lci_prg{n}/collective_prg{n}.
REGISTRY.register_family(VariantSpec(
    grammar="shmem_prg{n}",
    build=lambda name, n: LCIPPConfig(
        name=name,
        header_mode="put",
        header_comp="queue",
        progress_workers=n,
        progress_mode="explicit" if n == 0 else "implicit",
    ),
    canonical=((2,),),
    doc="shared-memory put+queue backend with {n} dedicated progress workers",
))
# elastic-progress family (ISSUE 8): the dedicated pool starts at lo and
# an ElasticProgressController grows/shrinks it between (lo, hi) from the
# engine's reap statistics — the adaptive answer to the §5.3 finding that
# the right lci_prg{n} is workload-dependent.
REGISTRY.register_family(VariantSpec(
    grammar="lci_eprg{lo}_{hi}",
    build=lambda name, lo, hi: LCIPPConfig(
        name=name,
        progress_workers=lo,
        elastic_progress=(lo, hi),
        progress_mode="explicit" if lo == 0 else "implicit",
    ),
    canonical=((0, 2),),
    doc="elastic progress workers: pool adapts between {lo} and {hi} from reap occupancy",
))
# bounded-injection family (§3.3.4, ROADMAP follow-up): finite send ring +
# bounce pool, both `depth` deep, through the shared resource model.
REGISTRY.register_family(VariantSpec(
    grammar="lci_b{depth}",
    build=lambda name, depth: LCIPPConfig(
        name=name,
        limits=ResourceLimits(
            send_queue_depth=depth,
            bounce_buffers=depth,
            bounce_buffer_size=_B_FAMILY_BUF_SIZE,
        ),
    ),
    canonical=((4,), (16,), (64,)),
    doc="bounded injection: send ring + bounce pool {depth} deep",
))

#: dict-compatible view (legacy name); resolves family members on demand.
VARIANTS = RegistryView(REGISTRY)

# -- serving-fleet variants (ISSUE 7) ----------------------------------------
# A SEPARATE registry: fleet variants resolve to FleetConfig objects (the
# router+worker serving tier), not parcelport configs — they must never
# leak into `variant_names()`, which the benchmark smoke gate iterates
# through `make_parcelport_factory`/`deliver_payloads`.
SERVE_REGISTRY = VariantRegistry()


def _fleet_cfg(name: str, workers: int, transport: str):
    # lazy: repro.serve pulls in jax/models; variants must stay importable
    # from the stdlib-only gates (tools/check_docs.py)
    from ..serve import FleetConfig

    del name  # the registry keys the cache; FleetConfig carries no name
    return FleetConfig(workers=workers, transport=transport)


for _n, _tr in (("fleet_inline", "inline"), ("fleet", "collective"), ("fleet_shmem", "shmem")):
    SERVE_REGISTRY.register(_n, lambda name=_n, tr=_tr: _fleet_cfg(name, 2, tr))
SERVE_REGISTRY.register_family(VariantSpec(
    grammar="fleet_w{n}",
    build=lambda name, n: _fleet_cfg(name, n, "collective"),
    canonical=((2,), (4,)),
    doc="router + {n} sharded-KV workers over the collective backend",
))
SERVE_REGISTRY.register_family(VariantSpec(
    grammar="fleet_shmem_w{n}",
    build=lambda name, n: _fleet_cfg(name, n, "shmem"),
    canonical=((2,), (4,)),
    doc="router + {n} workers, responses ride one-sided put (shmem backend)",
))


def _elastic_fleet_cfg(name: str, workers: int):
    from ..serve import FleetConfig

    del name
    # one spare pre-provisioned rank: join/leave cycles reuse it
    return FleetConfig(workers=workers, transport="collective", max_workers=workers + 1)


SERVE_REGISTRY.register_family(VariantSpec(
    grammar="fleet_elastic_w{n}",
    build=lambda name, n: _elastic_fleet_cfg(name, n),
    canonical=((2,),),
    doc="elastic fleet: {n} workers + one spare rank for membership join/leave",
))

#: dict-compatible view of the fleet family (resolves members on demand).
SERVE_VARIANTS = RegistryView(SERVE_REGISTRY)


def fleet_variant_names():
    return SERVE_REGISTRY.names()


def make_fleet_config(name: str):
    """Resolve a fleet variant name (fixed or family member, e.g.
    ``fleet_w4``) to a FRESH :class:`~repro.serve.fleet.FleetConfig` —
    registry resolution is cached, and fleet configs are mutated by
    callers (slots/context sizing), so each caller gets its own copy."""
    from dataclasses import replace

    return replace(SERVE_VARIANTS[name])

_NO_LIMITS = ResourceLimits()


def variant_names():
    return ["mpi", "mpi_a"] + REGISTRY.names()


def variant_limits(name: str) -> ResourceLimits:
    """The shared resource model a variant calls for — what the fabric
    backing a :class:`~repro.core.parcelport.World` should be built with.
    Unbounded for the MPI family and every variant that does not opt in."""
    if name in ("mpi", "mpi_a"):
        return _NO_LIMITS
    return VARIANTS[name].limits


def max_devices(name: str) -> int:
    if name in ("mpi", "mpi_a"):
        return 1
    return VARIANTS[name].ndevices


def make_parcelport_factory(name: str) -> Callable[[Locality, Fabric], Parcelport]:
    """Factory for :class:`repro.core.parcelport.World`.  Resolves fixed
    names and parameterized family members (``lci_b8``, ``lci_d7``, …)
    without pre-registration."""
    if name == "mpi":
        return lambda loc, fab: MPIParcelport(loc, fab, aggregation=False)
    if name == "mpi_a":
        return lambda loc, fab: MPIParcelport(loc, fab, aggregation=True)
    cfg = VARIANTS[name]
    if name.startswith("collective"):
        # the JAX-collectives backend (imported lazily: it sits above the
        # parcelport layer this module belongs to)
        from .comm.collective import CollectiveParcelport

        return lambda loc, fab: CollectiveParcelport(loc, fab, cfg)
    if name.startswith("shmem"):
        # the shared-memory backend (the true one-sided put transport)
        from .comm.shmem import ShmemParcelport

        return lambda loc, fab: ShmemParcelport(loc, fab, cfg)
    return lambda loc, fab: LCIParcelport(loc, fab, cfg)
