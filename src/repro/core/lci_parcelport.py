"""The LCI parcelport (paper §3.3) with every studied technique as a flag.

Techniques (paper Table 1) and the flag that controls each:

* **Protocol** — ``eager_threshold``: parcels whose total size fits the
  threshold ship **eager** (one fabric message through a pre-registered
  bounce buffer, zc chunks inline, zero follow-up round trips); larger
  parcels use the **rendezvous** layout (header + sequential follow-ups).
  ``eager_threshold=0`` disables the eager path (the ``lci_noeager``
  variant).  Backpressured posts (full send queue / exhausted bounce pool,
  §3.3.4) park in a retry queue that ``background_work`` drains under a
  bounded per-call budget — the sender-side throttle that keeps injection
  inside the fabric's resource limits.
* **Asynchrony** — ``header_mode``: ``'put'`` uses the one-sided *dynamic
  put* primitive, delivering headers straight into a completion queue;
  ``'sendrecv'`` pre-posts tagged receives (the MPI-like path) with either a
  completion queue (``header_comp='queue'``) or a single synchronizer
  (``header_comp='sync'`` — one pre-posted receive at a time, the variant
  that serializes header processing, §5.1).
* **Concurrency** — ``followup_comp``: ``'queue'`` routes every completion
  through one shared MPMC completion queue (``cq_kind`` picks LCRQ /
  Michael-Scott / lock-based, §5.2); ``'sync'`` uses a synchronizer pool
  (the request-pool analogue).
* **Multithreading** — ``ndevices`` replicates communication resources with
  a static worker→device mapping; ``lock_mode`` wraps each device in a
  coarse blocking/try lock or leaves it fine-grained (§5.3).
* **Progress** — ``progress_mode='explicit'`` invokes the device progress
  engine on every ``background_work``; ``'implicit'`` only when a
  completion poll comes back empty (the MPI behaviour).
* **Aggregation** — ``aggregation`` merges same-destination parcels
  (paper §2.2.2); ``agg_eager`` additionally makes the merge
  threshold-aware: the drain packs parcels into aggregates whose projected
  size stays within ``eager_threshold``, so a batch of eager-sized parcels
  fills at most one bounce buffer and never accidentally crosses onto the
  rendezvous path (the ``lci_agg_eager`` variant).

Invariant that makes the queue-based path lock-free at this layer: chunks of
one parcel transfer sequentially, so at most one completion record per
parcel is in flight, so op state machines are never touched concurrently.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Any, List, Optional, Tuple

from .comm.resources import ResourceLimits
from .completion import (
    CompletionQueue,
    Synchronizer,
    SynchronizerPool,
    make_completion_queue,
)
from .device import WIRE_OVERHEAD, CompletionRecord, LCIDevice, LockMode
from .fabric import Fabric
from .parcel import (
    HEADER_PIGGYBACK_LIMIT,
    Chunk,
    Parcel,
    SendCallback,
    decode_header,
    eager_wire_size,
    encode_eager,
    encode_header,
)
from .parcelport import Locality, Parcelport
from .worker import get_worker_id

TAG_HEADER = 0
HEADER_PREPOST = 16  # sendrecv_queue mode: pre-posted header receives

__all__ = ["LCIParcelport", "LCIPPConfig"]


@dataclass
class LCIPPConfig:
    name: str = "lci"
    header_mode: str = "put"  # 'put' | 'sendrecv'
    header_comp: str = "queue"  # 'queue' | 'sync'  (sendrecv mode only)
    followup_comp: str = "queue"  # 'queue' | 'sync'
    cq_kind: str = "lcrq"  # 'lcrq' | 'ms' | 'lock'
    ndevices: int = 2
    lock_mode: str = LockMode.NONE
    progress_mode: str = "explicit"  # 'explicit' | 'implicit'
    aggregation: bool = False
    # Protocol engine: parcels with total_bytes <= eager_threshold ship as
    # one eager message; 0 disables the eager path entirely.  The default
    # matches the piggyback limit, so plain small parcels behave as before
    # and small zero-copy chunks stop costing follow-up round trips.
    eager_threshold: int = HEADER_PIGGYBACK_LIMIT
    # Threshold-aware aggregation: the drain packs parcels into aggregates
    # whose projected size stays within eager_threshold (fill one bounce
    # buffer, never spill an eager-sized batch into rendezvous).  Only
    # meaningful with aggregation=True and eager_threshold > 0.
    agg_eager: bool = False
    # The shared resource model (paper §3.3.4): send-ring depth, bounce
    # pool, retry throttle.  One object consumed by the fabric, this
    # parcelport, AND the DES SimConfig — never mirrored field by field.
    limits: ResourceLimits = field(default_factory=ResourceLimits)

    @property
    def retry_budget(self) -> int:
        """Sender-side throttle: backpressured posts retried per
        ``background_work`` (delegates to the shared resource model)."""
        return self.limits.retry_budget

    def variant(self, **kw) -> "LCIPPConfig":
        return replace(self, **kw)


class _SendOp:
    __slots__ = ("dest", "parcel", "cb", "msgs", "next_idx", "dev")

    def __init__(self, dest, parcel, cb, msgs, dev):
        self.dest = dest
        self.parcel = parcel
        self.cb = cb
        self.msgs = msgs
        self.next_idx = 1
        self.dev = dev


class _RecvOp:
    __slots__ = ("header", "nzc", "zc_bufs", "idx")

    def __init__(self, header):
        self.header = header
        self.nzc: Optional[bytes] = header.piggybacked_nzc
        self.zc_bufs: List[bytearray] = []
        self.idx = 0


class LCIParcelport(Parcelport):
    def __init__(self, locality: Locality, fabric: Fabric, config: Optional[LCIPPConfig] = None):
        config = config or LCIPPConfig()
        agg_limit = config.eager_threshold if (config.agg_eager and config.eager_threshold > 0) else 0
        super().__init__(
            locality,
            aggregation=config.aggregation,
            agg_limit_bytes=agg_limit,
            retry_budget=config.limits.retry_budget,
        )
        self.cfg = config
        rank = locality.rank
        # The shared completion queue (across devices, to reduce load
        # imbalance — paper §3.3.3).
        self.cq: CompletionQueue = make_completion_queue(config.cq_kind)
        self.sync_pool = SynchronizerPool()
        self.devices: List[LCIDevice] = []
        for d in range(config.ndevices):
            net = fabric.device(rank, d)
            dev = LCIDevice(net, lock_mode=config.lock_mode, put_target_comp=self.cq)
            self.devices.append(dev)
        # Protocol-path selection by CAPABILITY, not flag alone (§2.3): the
        # one-sided header path needs a backend that advertises dynamic
        # put; a backend without it falls back to the two-sided path the
        # same config would otherwise describe.
        caps = self.devices[0].capabilities
        self._use_put = config.header_mode == "put" and caps.one_sided_put
        self.stats_eager_sent = 0
        self.stats_rendezvous_sent = 0
        # Header receive plumbing for the two-sided path.
        self._header_sync: Optional[Synchronizer] = None
        self._header_sync_lock = threading.Lock()
        if not self._use_put:
            if config.header_comp == "sync":
                self._header_sync = Synchronizer()
                self.devices[0].post_recv(-1, TAG_HEADER, self._header_sync, ctx="header")
            else:
                for dev in self.devices:
                    for _ in range(HEADER_PREPOST):
                        dev.post_recv(-1, TAG_HEADER, self.cq, ctx=("header", dev))

    # ------------------------------------------------------------------ send
    def _worker_device(self) -> int:
        return get_worker_id() % self.cfg.ndevices

    def _comp_for(self, kind: str, op: Any) -> Any:
        """Completion object for an operation, per the concurrency flag."""
        if self.cfg.followup_comp == "queue":
            return self.cq
        sync = Synchronizer()
        self.sync_pool.add(sync, (kind, op))
        return sync

    # Injection backpressure (paper §3.3.4): `_post_or_park` /
    # `_drain_retries` / `pending_work` are inherited from ParcelportBase —
    # the same parking + bounded-retry throttle every parcelport shares.

    # -- protocol selection (eager vs rendezvous) ---------------------------
    def _use_eager(self, parcel: Parcel, dev: LCIDevice) -> bool:
        if self.cfg.eager_threshold <= 0 or parcel.total_bytes > self.cfg.eager_threshold:
            return False
        cap = dev.eager_capacity()
        if cap is None:
            return True
        # the two-sided path prepends the library's tag word to the payload;
        # the whole wire message must fit a bounce buffer or acquire() would
        # fail on every retry (silent parcel loss, not backpressure).
        overhead = 0 if self._use_put else WIRE_OVERHEAD
        return eager_wire_size(parcel) + overhead <= cap

    def _send_impl(self, dest: int, parcel: Parcel, cb: Optional[SendCallback]) -> None:
        d = self._worker_device()
        dev = self.devices[d]
        if self._use_eager(parcel, dev):
            # Eager: the whole parcel in one bounce-buffered fabric message.
            wire = encode_eager(parcel, device_index=d)
            op = _SendOp(dest, parcel, cb, [(TAG_HEADER, wire)], d)
            comp = self._comp_for("send", op)
            if self._use_put:
                self._post_or_park(lambda: dev.post_put_signal(dest, d, wire, comp, ctx=("send", op), eager=True))
            else:
                self._post_or_park(lambda: dev.post_send(dest, d, TAG_HEADER, wire, comp, ctx=("send", op), eager=True))
            self.stats_eager_sent += 1
            self.stats_sent += 1
            return
        # Rendezvous: header first, then sequential follow-ups.
        header = encode_header(parcel, device_index=d)
        msgs: List[Tuple[int, bytes]] = [(TAG_HEADER, header)]
        if parcel.nzc_chunk.size > HEADER_PIGGYBACK_LIMIT:
            msgs.append((parcel.parcel_id, parcel.nzc_chunk.data))
        for c in parcel.zc_chunks:
            msgs.append((parcel.parcel_id, c.data))
        op = _SendOp(dest, parcel, cb, msgs, d)
        comp = self._comp_for("send", op)
        if self._use_put:
            self._post_or_park(lambda: dev.post_put_signal(dest, d, header, comp, ctx=("send", op)))
        else:
            self._post_or_park(lambda: dev.post_send(dest, d, TAG_HEADER, header, comp, ctx=("send", op)))
        self.stats_rendezvous_sent += 1
        self.stats_sent += 1

    def _advance_send(self, op: _SendOp) -> None:
        if op.next_idx < len(op.msgs):
            tag, data = op.msgs[op.next_idx]
            op.next_idx += 1
            dev = self.devices[op.dev]
            comp = self._comp_for("send", op)
            self._post_or_park(lambda: dev.post_send(op.dest, op.dev, tag, data, comp, ctx=("send", op)))
        else:
            if op.cb is not None:
                op.cb(op.parcel)

    # ------------------------------------------------------------------ recv
    def _process_header(self, src: int, payload: bytes) -> None:
        h = decode_header(payload)
        if h.is_eager:
            # Everything arrived inline: copy chunks out of the bounce
            # buffer and deliver — no follow-up receives, no round trips.
            self.deliver(
                Parcel(
                    parcel_id=h.parcel_id,
                    source=h.source,
                    dest=h.dest,
                    nzc_chunk=Chunk(h.piggybacked_nzc),
                    zc_chunks=[Chunk(b) for b in h.inline_zc],
                    device_index=h.device_index,
                    is_agg=h.is_agg,
                )
            )
            return
        op = _RecvOp(h)
        if h.piggybacked_nzc is not None and not h.zc_sizes:
            self._finish_recv(op)
            return
        dev = self.devices[h.device_index]
        comp = self._comp_for("recv", op)
        dev.post_recv(h.source, h.parcel_id, comp, ctx=("recv", op))

    def _advance_recv(self, op: _RecvOp, rec: CompletionRecord) -> None:
        h = op.header
        if op.nzc is None:
            op.nzc = rec.data
        else:
            if not op.zc_bufs:
                op.zc_bufs = self.locality.allocate_zc_chunks(op.nzc)
            op.zc_bufs[op.idx][:] = rec.data
            op.idx += 1
        if op.idx < len(h.zc_sizes):
            dev = self.devices[h.device_index]
            comp = self._comp_for("recv", op)
            dev.post_recv(h.source, h.parcel_id, comp, ctx=("recv", op))
        else:
            self._finish_recv(op)

    def _finish_recv(self, op: _RecvOp) -> None:
        h = op.header
        if h.zc_sizes and not op.zc_bufs:
            op.zc_bufs = self.locality.allocate_zc_chunks(op.nzc)
        parcel = Parcel(
            parcel_id=h.parcel_id,
            source=h.source,
            dest=h.dest,
            nzc_chunk=Chunk(bytes(op.nzc)),
            zc_chunks=[Chunk(bytes(b)) for b in op.zc_bufs],
            device_index=h.device_index,
            is_agg=h.is_agg,
        )
        self.deliver(parcel)

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, rec: CompletionRecord) -> None:
        if rec.op == "put_recv":
            self._process_header(rec.src_rank, rec.data)
            return
        kind_op = rec.ctx
        if kind_op == ("header",) or (isinstance(kind_op, tuple) and kind_op and kind_op[0] == "header"):
            # sendrecv_queue header receive: re-post, then process.
            dev = kind_op[1]
            dev.post_recv(-1, TAG_HEADER, self.cq, ctx=("header", dev))
            self._process_header(rec.src_rank, rec.data)
            return
        kind, op = kind_op
        if kind == "send":
            self._advance_send(op)
        else:
            self._advance_recv(op, rec)

    def background_work(self) -> bool:
        cfg = self.cfg
        progressed = False
        my_dev = self.devices[self._worker_device()]
        if cfg.progress_mode == "explicit":
            progressed |= my_dev.progress()
        # Retry backpressured posts before dispatching new completions — the
        # progress() above reaped send completions, freeing fabric slots.
        progressed |= self._drain_retries()

        polled_something = False
        if cfg.followup_comp == "queue" or self._use_put:
            for rec in self.cq.drain(8):
                polled_something = True
                progressed = True
                self._dispatch(rec)
        if cfg.followup_comp == "sync":
            item = self.sync_pool.poll_one()
            if item is not None:
                (kind, op), rec = item
                polled_something = True
                progressed = True
                if kind == "send":
                    self._advance_send(op)
                else:
                    self._advance_recv(op, rec)
        if self._header_sync is not None:
            # single-synchronizer header path (sendrecv_sync): try-lock so a
            # single thread owns the test (MPI-style).
            if self._header_sync_lock.acquire(blocking=False):
                try:
                    rec = self._header_sync.test()
                    if rec is not None:
                        polled_something = True
                        progressed = True
                        self.devices[0].post_recv(-1, TAG_HEADER, self._header_sync, ctx="header")
                        self._process_header(rec.src_rank, rec.data)
                finally:
                    self._header_sync_lock.release()
        if cfg.progress_mode == "implicit" and not polled_something:
            # the MPI behaviour: progress only as a side effect of a failed
            # completion test (the interface's `poll` verb)
            progressed |= my_dev.poll()
            progressed |= self._drain_retries()
        return progressed
