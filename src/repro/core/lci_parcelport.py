"""The LCI parcelport (paper §3.3) with every studied technique as a flag.

Techniques (paper Table 1) and the flag that controls each:

* **Protocol** — ``eager_threshold``: parcels whose total size fits the
  threshold ship **eager** (one fabric message through a pre-registered
  bounce buffer, zc chunks inline, zero follow-up round trips); larger
  parcels use the **rendezvous** layout (header + sequential follow-ups).
  ``eager_threshold=0`` disables the eager path (the ``lci_noeager``
  variant).  Backpressured posts (full send queue / exhausted bounce pool,
  §3.3.4) park in a retry queue that the progress engine drains under a
  bounded per-call budget — the sender-side throttle that keeps injection
  inside the fabric's resource limits.
* **Asynchrony** — ``header_mode``: ``'put'`` uses the one-sided *dynamic
  put* primitive, delivering headers straight into a completion queue;
  ``'sendrecv'`` pre-posts tagged receives (the MPI-like path) with either a
  completion queue (``header_comp='queue'``) or a single synchronizer
  (``header_comp='sync'`` — one pre-posted receive at a time, the variant
  that serializes header processing, §5.1).
* **Concurrency** — ``followup_comp``: ``'queue'`` routes every completion
  through MPMC completion queues (``cq_kind`` picks LCRQ / Michael-Scott /
  lock-based, §5.2); ``'sync'`` uses a synchronizer pool (the request-pool
  analogue).  ``cq_scope`` picks the queue *topology* (§3.3.3): ``'shared'``
  — one queue across devices, reducing load imbalance (the default, and the
  ``lci_shared_cq`` variant) — or ``'device'`` — one queue per device,
  trading imbalance for less queue contention.  The choice is routed by the
  engine's :class:`~repro.core.comm.progress.CompletionRouter`.
* **Multithreading** — ``ndevices`` replicates communication resources with
  a static worker→device mapping; ``lock_mode`` wraps each device in a
  coarse blocking/try lock or leaves it fine-grained (§5.3).
* **Progress** — the shared :class:`~repro.core.comm.progress.
  ProgressEngine` drives one canonical step loop; ``progress_mode``
  selects the :class:`~repro.core.comm.progress.ProgressPolicy`
  (``'explicit'`` invokes the device progress engine every step,
  ``'implicit'`` only when a completion poll comes back empty — the MPI
  behaviour), and ``progress_workers`` reserves that many **dedicated
  progress threads** (§3.3.4's omitted experiment, the ``lci_prg{n}``
  family): real daemon threads that drive retries + device progress on
  every device and never execute tasks or touch client completion objects.
* **Aggregation** — ``aggregation`` merges same-destination parcels
  (paper §2.2.2); ``agg_eager`` additionally makes the merge
  threshold-aware: the drain packs parcels into aggregates whose projected
  size stays within ``eager_threshold``, so a batch of eager-sized parcels
  fills at most one bounce buffer and never accidentally crosses onto the
  rendezvous path (the ``lci_agg_eager`` variant).

``background_work`` is a thin call into the shared engine: this module
implements only the op semantics (``execute``) and the per-parcel protocol
actions the engine dispatches to.  The reap loop itself lives once, in
:mod:`repro.core.comm.progress` (gated by tools/check_api.py).

Invariant that makes the queue-based path lock-free at this layer: chunks of
one parcel transfer sequentially, so at most one completion record per
parcel is in flight, so op state machines are never touched concurrently.
"""
from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass, field, replace
from typing import Any, List, Optional, Tuple

from .comm.membership import ElasticProgressController, ProgressWorkerPool
from .comm.progress import (
    ROLE_PROGRESS,
    CompletionRouter,
    CompletionSource,
    ProgressEngine,
    ProgressPolicy,
    run_step,
)
from .comm.resources import ResourceLimits
from .completion import (
    CompletionQueue,
    Synchronizer,
    SynchronizerPool,
    make_completion_queue,
)
from .device import WIRE_OVERHEAD, CompletionRecord, LCIDevice, LockMode
from .fabric import Fabric
from .parcel import (
    HEADER_PIGGYBACK_LIMIT,
    Chunk,
    Parcel,
    SendCallback,
    decode_header,
    eager_wire_size,
    encode_eager,
    encode_header,
)
from .parcelport import Locality, Parcelport
from .worker import get_worker_id

TAG_HEADER = 0
HEADER_PREPOST = 16  # sendrecv_queue mode: pre-posted header receives

__all__ = ["LCIParcelport", "LCIPPConfig"]


@dataclass
class LCIPPConfig:
    name: str = "lci"
    header_mode: str = "put"  # 'put' | 'sendrecv'
    header_comp: str = "queue"  # 'queue' | 'sync'  (sendrecv mode only)
    followup_comp: str = "queue"  # 'queue' | 'sync'
    cq_kind: str = "lcrq"  # 'lcrq' | 'ms' | 'lock'
    # Completion-queue topology (§3.3.3), routed by the engine's
    # CompletionRouter: 'shared' = one queue across devices (load balance,
    # the lci_shared_cq variant and the default); 'device' = one per device.
    cq_scope: str = "shared"  # 'shared' | 'device'
    ndevices: int = 2
    lock_mode: str = LockMode.NONE
    progress_mode: str = "explicit"  # 'explicit' | 'implicit'
    # Dedicated progress workers (§3.3.4, the lci_prg{n} family): threads
    # reserved to drive the progress engine, never executing tasks.  0 =
    # every worker polls (the paper's recommended configuration).
    progress_workers: int = 0
    # Elastic progress bounds (ISSUE 8, the lci_eprg{lo}_{hi} family):
    # (lo, hi) lets an ElasticProgressController grow/shrink the dedicated
    # pool between the bounds from the engine's reap statistics; None
    # keeps the pool fixed at progress_workers.
    elastic_progress: Optional[Tuple[int, int]] = None
    aggregation: bool = False
    # Protocol engine: parcels with total_bytes <= eager_threshold ship as
    # one eager message; 0 disables the eager path entirely.  The default
    # matches the piggyback limit, so plain small parcels behave as before
    # and small zero-copy chunks stop costing follow-up round trips.
    eager_threshold: int = HEADER_PIGGYBACK_LIMIT
    # Threshold-aware aggregation: the drain packs parcels into aggregates
    # whose projected size stays within eager_threshold (fill one bounce
    # buffer, never spill an eager-sized batch into rendezvous).  Only
    # meaningful with aggregation=True and eager_threshold > 0.
    agg_eager: bool = False
    # The shared resource model (paper §3.3.4): send-ring depth, bounce
    # pool, retry throttle.  One object consumed by the fabric, this
    # parcelport, AND the DES SimConfig — never mirrored field by field.
    limits: ResourceLimits = field(default_factory=ResourceLimits)

    @property
    def retry_budget(self) -> int:
        """Sender-side throttle: backpressured posts retried per
        ``background_work`` (delegates to the shared resource model)."""
        return self.limits.retry_budget

    def variant(self, **kw) -> "LCIPPConfig":
        return replace(self, **kw)


class _SendOp:
    __slots__ = ("dest", "parcel", "cb", "msgs", "next_idx", "dev")

    def __init__(self, dest, parcel, cb, msgs, dev):
        self.dest = dest
        self.parcel = parcel
        self.cb = cb
        self.msgs = msgs
        self.next_idx = 1
        self.dev = dev


class _RecvOp:
    __slots__ = ("header", "nzc", "zc_bufs", "idx")

    def __init__(self, header):
        self.header = header
        self.nzc: Optional[bytes] = header.piggybacked_nzc
        self.zc_bufs: List[bytearray] = []
        self.idx = 0


class LCIParcelport(Parcelport):
    def __init__(self, locality: Locality, fabric: Fabric, config: Optional[LCIPPConfig] = None):
        config = config or LCIPPConfig()
        assert config.cq_scope in ("shared", "device"), config.cq_scope
        agg_limit = config.eager_threshold if (config.agg_eager and config.eager_threshold > 0) else 0
        super().__init__(
            locality,
            aggregation=config.aggregation,
            agg_limit_bytes=agg_limit,
            retry_budget=config.limits.retry_budget,
        )
        self.cfg = config
        rank = locality.rank
        # Completion-queue topology (§3.3.3): one shared queue across
        # devices (reduces load imbalance) or one queue per device (less
        # queue contention) — the router reaps whichever exists.
        self._dev_cqs: Optional[List[CompletionQueue]] = None
        if config.cq_scope == "device":
            self._dev_cqs = [make_completion_queue(config.cq_kind) for _ in range(config.ndevices)]
            self.cq: Optional[CompletionQueue] = None
        else:
            self.cq = make_completion_queue(config.cq_kind)
        self.sync_pool = SynchronizerPool()
        # Backend creation is a hook: CollectiveParcelport swaps the LCI
        # devices for CollectiveComm endpoints and inherits every protocol
        # decision above this line untouched (selection is by capability).
        self.devices: List[Any] = self._make_devices(fabric, config)
        # Protocol-path selection by CAPABILITY, not flag alone (§2.3): the
        # one-sided header path needs a backend that advertises dynamic
        # put; a backend without it falls back to the two-sided path the
        # same config would otherwise describe.
        caps = self.devices[0].capabilities
        self._use_put = config.header_mode == "put" and caps.one_sided_put
        self.stats_eager_sent = 0
        self.stats_rendezvous_sent = 0
        # Header receive plumbing for the two-sided path.
        self._header_sync: Optional[Synchronizer] = None
        self._header_sync_lock = threading.Lock()
        if not self._use_put:
            if config.header_comp == "sync":
                self._header_sync = Synchronizer()
                self.devices[0].post_recv(-1, TAG_HEADER, self._header_sync, ctx="header")
            else:
                for d, dev in enumerate(self.devices):
                    for _ in range(HEADER_PREPOST):
                        dev.post_recv(-1, TAG_HEADER, self._cq_for(d), ctx=("header", d))
        # THE progress engine (shared with the DES): policy + router from
        # the config, ops executed by this parcelport.
        self.engine = ProgressEngine(
            ProgressPolicy.for_config(config),
            self._build_router(config),
            ndevices=config.ndevices,
        )
        # Dedicated progress threads (lci_prg{n}): drive the engine's
        # progress role; task workers keep the implicit fallback poll, so
        # delivery never depends on thread scheduling.  Thread lifecycle
        # lives in the membership layer's ProgressWorkerPool; with
        # elastic_progress=(lo, hi) an ElasticProgressController resizes
        # the pool between the bounds from the engine's reap statistics.
        self._pw_pool: Optional[ProgressWorkerPool] = None
        self._elastic: Optional[ElasticProgressController] = None
        initial = config.progress_workers
        if config.elastic_progress is not None:
            lo, hi = config.elastic_progress
            initial = max(initial, lo)
        if initial > 0 or config.elastic_progress is not None:
            self._pw_pool = ProgressWorkerPool(weakref.ref(self), f"lci-prg{rank}")
            self._pw_pool.resize(initial)
            if config.elastic_progress is not None:
                lo, hi = config.elastic_progress
                self._elastic = ElasticProgressController(self.engine, self._pw_pool, lo, hi)

    def _make_devices(self, fabric: Fabric, config: LCIPPConfig) -> List[LCIDevice]:
        """Open this parcelport's communication backends (one per device
        index).  Subclasses swap the backend family here."""
        rank = self.locality.rank
        return [
            LCIDevice(fabric.device(rank, d), lock_mode=config.lock_mode, put_target_comp=self._cq_for(d))
            for d in range(config.ndevices)
        ]

    def _build_router(self, cfg: LCIPPConfig) -> CompletionRouter:
        srcs: List[CompletionSource] = []
        if cfg.followup_comp == "queue" or self._use_put:
            if cfg.cq_scope == "device":
                srcs.append(CompletionSource("cq", batch=8, per_device=True, sweep="all"))
            else:
                srcs.append(CompletionSource("cq", batch=8))
        if cfg.followup_comp == "sync":
            srcs.append(CompletionSource("sync_pool", batch=1))
        if self._header_sync is not None:
            srcs.append(CompletionSource("header_sync", batch=1))
        return CompletionRouter(srcs, ndevices=cfg.ndevices)

    def _cq_for(self, d: int) -> CompletionQueue:
        """The completion queue serving device ``d`` under the configured
        scope (shared: one queue for all)."""
        return self.cq if self._dev_cqs is None else self._dev_cqs[d]

    def close(self) -> None:
        """Stop AND JOIN the dedicated progress threads.  Idempotent.

        Relying on weakref finalization alone leaked live daemon threads
        for as long as the parcelport object survived (benchmarks and
        tests construct many short-lived worlds); an explicit close joins
        them deterministically — the weakref loop remains only the GC
        backstop for worlds that never call it."""
        if self._pw_pool is not None:
            self._pw_pool.close()

    def __enter__(self) -> "LCIParcelport":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # ------------------------------------------------------------------ send
    def _worker_device(self) -> int:
        return get_worker_id() % self.cfg.ndevices

    def _comp_for(self, kind: str, op: Any, dev: int) -> Any:
        """Completion object for an operation, per the concurrency flag and
        the completion-queue scope."""
        if self.cfg.followup_comp == "queue":
            return self._cq_for(dev)
        sync = Synchronizer()
        self.sync_pool.add(sync, (kind, op))
        return sync

    # Injection backpressure (paper §3.3.4): `_post_or_park` /
    # `_drain_retries` / `pending_work` are inherited from ParcelportBase —
    # the same parking + bounded-retry throttle every parcelport shares.

    # -- protocol selection (eager vs rendezvous) ---------------------------
    def _use_eager(self, parcel: Parcel, dev: LCIDevice) -> bool:
        if self.cfg.eager_threshold <= 0 or parcel.total_bytes > self.cfg.eager_threshold:
            return False
        cap = dev.eager_capacity()
        if cap is None:
            return True
        # the two-sided path prepends the library's tag word to the payload;
        # the whole wire message must fit a bounce buffer or acquire() would
        # fail on every retry (silent parcel loss, not backpressure).
        overhead = 0 if self._use_put else WIRE_OVERHEAD
        return eager_wire_size(parcel) + overhead <= cap

    def _send_impl(self, dest: int, parcel: Parcel, cb: Optional[SendCallback]) -> None:
        d = self._worker_device()
        dev = self.devices[d]
        if self._use_eager(parcel, dev):
            # Eager: the whole parcel in one bounce-buffered fabric message.
            self.engine.record("send", "eager", 0)
            wire = encode_eager(parcel, device_index=d)
            op = _SendOp(dest, parcel, cb, [(TAG_HEADER, wire)], d)
            comp = self._comp_for("send", op, d)
            if self._use_put:
                self._post_or_park(lambda: dev.post_put_signal(dest, d, wire, comp, ctx=("send", op), eager=True))
            else:
                self._post_or_park(lambda: dev.post_send(dest, d, TAG_HEADER, wire, comp, ctx=("send", op), eager=True))
            self.stats_eager_sent += 1
            self.stats_sent += 1
            return
        # Rendezvous: header first, then sequential follow-ups.
        header = encode_header(parcel, device_index=d)
        msgs: List[Tuple[int, bytes]] = [(TAG_HEADER, header)]
        if parcel.nzc_chunk.size > HEADER_PIGGYBACK_LIMIT:
            msgs.append((parcel.parcel_id, parcel.nzc_chunk.data))
        for c in parcel.zc_chunks:
            msgs.append((parcel.parcel_id, c.data))
        self.engine.record("send", "rdv", len(msgs) - 1)
        op = _SendOp(dest, parcel, cb, msgs, d)
        comp = self._comp_for("send", op, d)
        if self._use_put:
            self._post_or_park(lambda: dev.post_put_signal(dest, d, header, comp, ctx=("send", op)))
        else:
            self._post_or_park(lambda: dev.post_send(dest, d, TAG_HEADER, header, comp, ctx=("send", op)))
        self.stats_rendezvous_sent += 1
        self.stats_sent += 1

    def _advance_send(self, op: _SendOp) -> None:
        if op.next_idx < len(op.msgs):
            tag, data = op.msgs[op.next_idx]
            op.next_idx += 1
            dev = self.devices[op.dev]
            comp = self._comp_for("send", op, op.dev)
            self._post_or_park(lambda: dev.post_send(op.dest, op.dev, tag, data, comp, ctx=("send", op)))
        else:
            if op.cb is not None:
                op.cb(op.parcel)

    # ------------------------------------------------------------------ recv
    def _process_header(self, src: int, payload: bytes) -> None:
        h = decode_header(payload)
        if h.is_eager:
            # Everything arrived inline: copy chunks out of the bounce
            # buffer and deliver — no follow-up receives, no round trips.
            self.engine.record("header", "eager")
            self.deliver(
                Parcel(
                    parcel_id=h.parcel_id,
                    source=h.source,
                    dest=h.dest,
                    nzc_chunk=Chunk(h.piggybacked_nzc),
                    zc_chunks=[Chunk(b) for b in h.inline_zc],
                    device_index=h.device_index,
                    is_agg=h.is_agg,
                )
            )
            return
        self.engine.record("header", "rdv")
        op = _RecvOp(h)
        if h.piggybacked_nzc is not None and not h.zc_sizes:
            self._finish_recv(op)
            return
        dev = self.devices[h.device_index]
        comp = self._comp_for("recv", op, h.device_index)
        dev.post_recv(h.source, h.parcel_id, comp, ctx=("recv", op))

    def _advance_recv(self, op: _RecvOp, rec: CompletionRecord) -> None:
        self.engine.record("chunk")
        h = op.header
        if op.nzc is None:
            op.nzc = rec.data
        else:
            if not op.zc_bufs:
                op.zc_bufs = self.locality.allocate_zc_chunks(op.nzc)
            op.zc_bufs[op.idx][:] = rec.data
            op.idx += 1
        if op.idx < len(h.zc_sizes):
            dev = self.devices[h.device_index]
            comp = self._comp_for("recv", op, h.device_index)
            dev.post_recv(h.source, h.parcel_id, comp, ctx=("recv", op))
        else:
            self._finish_recv(op)

    def _finish_recv(self, op: _RecvOp) -> None:
        h = op.header
        if h.zc_sizes and not op.zc_bufs:
            op.zc_bufs = self.locality.allocate_zc_chunks(op.nzc)
        parcel = Parcel(
            parcel_id=h.parcel_id,
            source=h.source,
            dest=h.dest,
            nzc_chunk=Chunk(bytes(op.nzc)),
            zc_chunks=[Chunk(bytes(b)) for b in op.zc_bufs],
            device_index=h.device_index,
            is_agg=h.is_agg,
        )
        self.deliver(parcel)

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, rec: CompletionRecord) -> None:
        if rec.op == "put_recv":
            self._process_header(rec.src_rank, rec.data)
            return
        kind_op = rec.ctx
        if isinstance(kind_op, tuple) and kind_op and kind_op[0] == "header":
            # sendrecv_queue header receive: re-post, then process.
            d = kind_op[1]
            self.devices[d].post_recv(-1, TAG_HEADER, self._cq_for(d), ctx=("header", d))
            self._process_header(rec.src_rank, rec.data)
            return
        kind, op = kind_op
        if kind == "send":
            self._advance_send(op)
        else:
            self._advance_recv(op, rec)

    # ------------------------------------------- the progress-engine hookup
    def background_work(self) -> bool:
        """One step of the SHARED progress engine (drain retries → progress
        → reap → dispatch); this parcelport only supplies op semantics."""
        moved = run_step(self.engine, self, self._worker_device())
        if self._elastic is not None:
            # elastic progress (ISSUE 8): one cheap control decision per
            # task-side pump — grow/shrink the dedicated pool between the
            # configured bounds from the engine's reap statistics
            self._elastic.maybe_resize()
        return moved

    def progress_work(self) -> bool:
        """One dedicated-progress step (ROLE_PROGRESS): retries + device
        progress on every device; no client-side completion dispatch."""
        return run_step(self.engine, self, get_worker_id(), role=ROLE_PROGRESS)

    def execute(self, op: tuple) -> Any:
        """Execute one engine op against the real devices and completion
        objects (the functional layer's half of the engine contract)."""
        kind = op[0]
        if kind == "reap":
            src, d = op[1], op[2]
            name = src.name
            if name == "cq":
                return (self.cq if d < 0 else self._dev_cqs[d]).reap()
            if name == "sync_pool":
                return self.sync_pool.poll_one()
            # header_sync: single pre-posted receive, one thread owns the
            # test (MPI-style try-lock); re-post before processing.
            if self._header_sync_lock.acquire(blocking=False):
                try:
                    rec = self._header_sync.test()
                    if rec is not None:
                        self.devices[0].post_recv(-1, TAG_HEADER, self._header_sync, ctx="header")
                    return rec
                finally:
                    self._header_sync_lock.release()
            return None
        if kind == "dispatch":
            src, item = op[1], op[3]
            name = src.name
            if name == "cq":
                self._dispatch(item)
            elif name == "sync_pool":
                (skind, sop), rec = item
                if skind == "send":
                    self._advance_send(sop)
                else:
                    self._advance_recv(sop, rec)
            else:  # header_sync
                self._process_header(item.src_rank, item.data)
            return True
        if kind == "progress":
            return self.devices[op[1]].progress()
        if kind == "poll":
            # the MPI behaviour: progress only as a side effect of a failed
            # completion test (the interface's `poll` verb)
            return self.devices[op[1]].poll()
        if kind == "drain_retries":
            return self._drain_retries()
        if kind in ("dev_trylock", "step_trylock"):
            # coarse locking is internal to LCIDevice (its lock_mode); the
            # engine's trylock decision maps to "go ahead" here and the
            # device's own try-acquire reports contention via progress().
            return True
        # dev_lock/dev_unlock/big_*/step_unlock/implicit_tax/reap_begin/
        # reap_end/flush: cost-model ops — the DES charges them, the
        # functional layer has nothing to do.
        return False
