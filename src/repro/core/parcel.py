"""HPX-style parcels and serialization (paper §2.2-2.3).

A *parcel* is the unit of communication between localities: the serialized
form of a remote action invocation.  Serialization follows the HPX layout:

* a **data chunk** holding the action metadata and every *small* argument,
* zero or more **zero-copy chunks**, one per *large* argument (an argument is
  large when it exceeds the zero-copy serialization threshold),
* a **transmission chunk** holding (index, length) of every serialized
  argument, present only when there is at least one zero-copy chunk.

Per paper §2.3 we merge the data chunk and the transmission chunk into a
single *non-zero-copy (nzc) chunk* at the parcelport boundary.

The wire protocol (paper §3.2): each parcel becomes one **header message**
(fixed-size-bounded, unexpected, location agnostic) followed by the
*follow-up* messages — the nzc chunk message and one message per zero-copy
chunk, sent sequentially per-parcel.  Small nzc chunks are piggybacked onto
the header message.

Protocol selection (paper §3.3, LCI's eager/rendezvous split): parcels whose
*total* size fits the parcelport's ``eager_threshold`` are shipped **eager**
— the nzc chunk *and* every zero-copy chunk ride inline in one fabric
message (copied through pre-registered bounce buffers, no follow-up round
trips).  Larger parcels use the **rendezvous** layout above.  On the wire
the two are distinguished by a flag bit in the header, so a receiver decodes
either from the same ``decode_header`` entry point.
"""
from __future__ import annotations

import pickle
import struct
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple

# Default HPX zero-copy serialization threshold (the Octo-Tiger runs in the
# paper use 8 KiB).
DEFAULT_ZERO_COPY_THRESHOLD = 8 * 1024

# Maximum bytes of nzc chunk that may ride inside the header message
# (paper §3.2: "if the nonzero-copy chunk messages are small enough, they
# will be piggybacked onto the header message").  LCI's default medium
# message/packet size is 8KiB-ish; keep the header message size-bounded.
HEADER_PIGGYBACK_LIMIT = 8 * 1024

# Header wire layout:  parcel_id, source, dest, device_index (the LCI device
# the follow-ups will use, paper §3.3.3), n_zc_chunks, nzc_size,
# flags byte, followed by zc chunk sizes, optionally the nzc bytes, and —
# for eager messages — every zc chunk inline.
_HEADER_FMT = "<QIIIIIB"
_HEADER_FIXED = struct.calcsize(_HEADER_FMT)

FLAG_PIGGYBACK = 0x01  # nzc chunk rides in this message
FLAG_EAGER = 0x02  # zc chunks ride inline too: no follow-ups at all
FLAG_AGGREGATE = 0x04  # the nzc chunk is an aggregate of parcels (§2.2.2);
# carried out-of-band in the header so a plain parcel whose serialized
# payload happens to start with the aggregate magic byte can never be
# misparsed as one (the framing magic stays as an integrity check only)


@dataclass
class Chunk:
    """A contiguous buffer.  ``data`` is bytes-like (bytes / memoryview)."""

    data: bytes

    @property
    def size(self) -> int:
        return len(self.data)


@dataclass
class Parcel:
    """A serialized action invocation ready for the parcelport."""

    parcel_id: int
    source: int
    dest: int
    nzc_chunk: Chunk
    zc_chunks: List[Chunk] = field(default_factory=list)
    # Filled by the receiving parcelport before handing to the upper layer.
    device_index: int = 0
    # True iff nzc_chunk holds an aggregate of parcels (set by
    # aggregate_parcels, carried on the wire as FLAG_AGGREGATE).
    is_agg: bool = False

    @property
    def num_zc(self) -> int:
        return len(self.zc_chunks)

    @property
    def total_bytes(self) -> int:
        return self.nzc_chunk.size + sum(c.size for c in self.zc_chunks)


@dataclass
class Header:
    """Decoded header message."""

    parcel_id: int
    source: int
    dest: int
    device_index: int
    zc_sizes: Tuple[int, ...]
    nzc_size: int
    piggybacked_nzc: Optional[bytes]  # present iff nzc chunk rode along
    inline_zc: Optional[List[bytes]] = None  # eager messages: zc chunks inline
    is_agg: bool = False  # FLAG_AGGREGATE: the payload is an aggregate

    @property
    def is_eager(self) -> bool:
        return self.inline_zc is not None

    @property
    def num_followups(self) -> int:
        if self.inline_zc is not None:
            return 0
        n = len(self.zc_sizes)
        if self.piggybacked_nzc is None:
            n += 1
        return n


def encode_header(parcel: Parcel, device_index: int) -> bytes:
    """Encode the rendezvous header message (size-bounded by design)."""
    piggy = parcel.nzc_chunk.size <= HEADER_PIGGYBACK_LIMIT
    head = struct.pack(
        _HEADER_FMT,
        parcel.parcel_id,
        parcel.source,
        parcel.dest,
        device_index,
        len(parcel.zc_chunks),
        parcel.nzc_chunk.size,
        (FLAG_PIGGYBACK if piggy else 0) | (FLAG_AGGREGATE if parcel.is_agg else 0),
    )
    sizes = struct.pack(f"<{len(parcel.zc_chunks)}Q", *[c.size for c in parcel.zc_chunks])
    body = parcel.nzc_chunk.data if piggy else b""
    return head + sizes + body


def encode_eager(parcel: Parcel, device_index: int) -> bytes:
    """Encode the whole parcel as ONE eager message: header fields, nzc
    chunk and every zero-copy chunk inline.  The receiver copies the chunks
    out of the bounce buffer — no rendezvous round trips."""
    head = struct.pack(
        _HEADER_FMT,
        parcel.parcel_id,
        parcel.source,
        parcel.dest,
        device_index,
        len(parcel.zc_chunks),
        parcel.nzc_chunk.size,
        FLAG_PIGGYBACK | FLAG_EAGER | (FLAG_AGGREGATE if parcel.is_agg else 0),
    )
    sizes = struct.pack(f"<{len(parcel.zc_chunks)}Q", *[c.size for c in parcel.zc_chunks])
    parts = [head, sizes, parcel.nzc_chunk.data]
    parts.extend(c.data for c in parcel.zc_chunks)
    return b"".join(parts)


def eager_wire_size(parcel: Parcel) -> int:
    """Size of :func:`encode_eager`'s output without building it (used to
    check bounce-buffer capacity before choosing the eager path)."""
    return _HEADER_FIXED + 8 * len(parcel.zc_chunks) + parcel.total_bytes


def decode_header(buf: bytes) -> Header:
    (pid, src, dst, dev, n_zc, nzc_size, flags) = struct.unpack_from(_HEADER_FMT, buf, 0)
    off = _HEADER_FIXED
    zc_sizes = struct.unpack_from(f"<{n_zc}Q", buf, off)
    off += 8 * n_zc
    piggy_nzc = bytes(buf[off : off + nzc_size]) if flags & FLAG_PIGGYBACK else None
    inline_zc: Optional[List[bytes]] = None
    if flags & FLAG_EAGER:
        off += nzc_size
        inline_zc = []
        for sz in zc_sizes:
            inline_zc.append(bytes(buf[off : off + sz]))
            off += sz
    return Header(
        parcel_id=pid,
        source=src,
        dest=dst,
        device_index=dev,
        zc_sizes=tuple(zc_sizes),
        nzc_size=nzc_size,
        piggybacked_nzc=piggy_nzc,
        inline_zc=inline_zc,
        is_agg=bool(flags & FLAG_AGGREGATE),
    )


# ---------------------------------------------------------------------------
# Action serialization (the HPX "upper communication layer", paper §2.2.2)
# ---------------------------------------------------------------------------

class _ZcPlaceholder:
    """Marks where a zero-copy argument sat in the argument tuple."""

    __slots__ = ("index", "length")

    def __init__(self, index: int, length: int):
        self.index = index
        self.length = length


def serialize_action(
    parcel_id: int,
    source: int,
    dest: int,
    action: str,
    args: Sequence[Any],
    zero_copy_threshold: int = DEFAULT_ZERO_COPY_THRESHOLD,
) -> Parcel:
    """Serialize an action invocation into a parcel.

    Arguments that are bytes-like and exceed the threshold become zero-copy
    chunks (never copied into the pickle stream); everything else is
    pickled into the data chunk.  The transmission record (index, length per
    zero-copy chunk) is appended to the same nzc chunk, mirroring HPX's
    merged data+transmission chunk.
    """
    zc_chunks: List[Chunk] = []
    small_args: List[Any] = []
    for a in args:
        if isinstance(a, (bytes, bytearray, memoryview)) and len(a) >= zero_copy_threshold:
            small_args.append(_ZcPlaceholder(len(zc_chunks), len(a)))
            zc_chunks.append(Chunk(bytes(a)))
        else:
            small_args.append(a)
    payload = pickle.dumps((action, small_args), protocol=pickle.HIGHEST_PROTOCOL)
    # transmission record
    trans = struct.pack(f"<I{len(zc_chunks)}Q", len(zc_chunks), *[c.size for c in zc_chunks])
    nzc = Chunk(struct.pack("<I", len(payload)) + payload + trans)
    return Parcel(parcel_id=parcel_id, source=source, dest=dest, nzc_chunk=nzc, zc_chunks=zc_chunks)


def deserialize_action(parcel: Parcel) -> Tuple[str, List[Any]]:
    """Inverse of :func:`serialize_action`."""
    buf = parcel.nzc_chunk.data
    (plen,) = struct.unpack_from("<I", buf, 0)
    action, small_args = pickle.loads(buf[4 : 4 + plen])
    (n_zc,) = struct.unpack_from("<I", buf, 4 + plen)
    if n_zc != len(parcel.zc_chunks):
        raise ValueError(
            f"transmission chunk says {n_zc} zero-copy chunks, parcel has {len(parcel.zc_chunks)}"
        )
    args: List[Any] = []
    for a in small_args:
        if isinstance(a, _ZcPlaceholder):
            chunk = parcel.zc_chunks[a.index]
            if chunk.size != a.length:
                raise ValueError("zero-copy chunk length mismatch")
            args.append(chunk.data)
        else:
            args.append(a)
    return action, args


def zc_sizes_from_nzc(nzc_data: bytes) -> Tuple[int, ...]:
    """Read the zero-copy sizes out of an nzc chunk (``allocate_zc_chunks``
    uses this: the nzc chunk carries the size info, paper §2.3)."""
    (plen,) = struct.unpack_from("<I", nzc_data, 0)
    (n_zc,) = struct.unpack_from("<I", nzc_data, 4 + plen)
    return struct.unpack_from(f"<{n_zc}Q", nzc_data, 8 + plen)


# Callback type used throughout the parcelport layer.
SendCallback = Callable[[Parcel], None]
