"""Shared parcelport machinery above the :class:`CommInterface` boundary.

Everything here used to be duplicated (or split) across the MPI and LCI
parcelports; it is library-agnostic, so it lives once, in the comm layer:

* **parcel aggregation** (paper §2.2.2) — per-destination queues, the
  drain-and-merge cycle, and the threshold-aware batch packing that keeps
  an aggregate of eager-sized parcels inside one bounce buffer;
* **injection backpressure handling** (paper §3.3.4) — parking posts the
  backend refused (:class:`~repro.core.comm.interface.PostStatus` EAGAIN)
  and retrying them under a bounded per-call budget (the sender-side
  throttle drawn from :class:`~repro.core.comm.resources.ResourceLimits`);
* delivery bookkeeping and the ``sent``/``received`` stats the parity
  tests conserve.

Concrete parcelports implement only ``_send_impl`` (per-parcel protocol
selection) and ``background_work`` (their progress/completion loop).
"""
from __future__ import annotations

import struct
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..parcel import Chunk, Parcel, SendCallback

__all__ = [
    "InjectionThrottle",
    "ParcelportBase",
    "aggregate_parcels",
    "aggregate_projected_bytes",
    "is_aggregate",
    "split_aggregate",
    "AGG_MAGIC",
    "AGG_SUB_SHIFT",
    "AGG_MAX_PARCELS",
    "AGG_PREAMBLE_BYTES",
    "AGG_PER_PARCEL_BYTES",
]

AGG_MAGIC = 0xA6

# Parcel-id bit layout: bits 0..39 are the per-locality counter, bits 40..47
# the source rank (Locality seeds its counter at ``rank << 40``), and bits
# 48..63 are RESERVED for aggregate sub-ids: parcel ``i`` of a split
# aggregate gets ``base_id | ((i + 1) << AGG_SUB_SHIFT)``.  Ordinary ids
# never touch the reserved range, so sub-ids cannot collide with dense
# neighbouring ids (the old ``base_id * 1000 + i`` scheme collided as soon
# as ids were dense or an aggregate held >= 1000 parcels).
AGG_SUB_SHIFT = 48
AGG_MAX_PARCELS = (1 << 16) - 1

# Serialized-aggregate framing overhead: the <BI> preamble plus one <II>
# record per member parcel (see aggregate_parcels).  aggregate_projected_bytes
# must stay in lockstep with the actual encoder.
AGG_PREAMBLE_BYTES = 5
AGG_PER_PARCEL_BYTES = 8


def aggregate_projected_bytes(parcels: Sequence[Parcel]) -> int:
    """``total_bytes`` the aggregate of ``parcels`` will have, without
    building it — the threshold-aware drain sizes batches with this."""
    return AGG_PREAMBLE_BYTES + sum(AGG_PER_PARCEL_BYTES + p.total_bytes for p in parcels)


def aggregate_parcels(parcels: Sequence[Parcel]) -> Parcel:
    """Merge parcels sharing a destination into one (paper §2.2.2)."""
    assert parcels, "cannot aggregate zero parcels"
    assert len(parcels) <= AGG_MAX_PARCELS, "aggregate exceeds the sub-id bit range"
    first = parcels[0]
    parts = [struct.pack("<BI", AGG_MAGIC, len(parcels))]
    zc: List[Chunk] = []
    for p in parcels:
        parts.append(struct.pack("<II", p.nzc_chunk.size, len(p.zc_chunks)))
        parts.append(p.nzc_chunk.data)
        zc.extend(p.zc_chunks)
    return Parcel(
        parcel_id=first.parcel_id,
        source=first.source,
        dest=first.dest,
        nzc_chunk=Chunk(b"".join(parts)),
        zc_chunks=zc,
        is_agg=True,
    )


def is_aggregate(parcel: Parcel) -> bool:
    """Aggregate-ness is an out-of-band property (``Parcel.is_agg``,
    FLAG_AGGREGATE on the wire) — never inferred from payload bytes: an
    ordinary parcel whose serialized pickle length happens to put
    ``AGG_MAGIC`` in byte 0 must not be torn apart by the splitter."""
    return parcel.is_agg


def split_aggregate(parcel: Parcel) -> List[Parcel]:
    # memoryview slices, not bytes slices: the aggregate buffer is already
    # immutable, so each sub-parcel's nzc chunk can be a zero-copy view —
    # ``bytes(nzc)`` here used to copy every sub-payload a second time
    # (pinned by the allocation-count test in tests/test_grad_pack.py).
    buf = memoryview(parcel.nzc_chunk.data)
    (magic, n) = struct.unpack_from("<BI", buf, 0)
    assert magic == AGG_MAGIC, "parcel flagged as aggregate lacks the framing magic"
    off = 5
    zc_off = 0
    out: List[Parcel] = []
    for i in range(n):
        nzc_size, n_zc = struct.unpack_from("<II", buf, off)
        off += 8
        nzc = buf[off : off + nzc_size]
        off += nzc_size
        chunks = parcel.zc_chunks[zc_off : zc_off + n_zc]
        zc_off += n_zc
        out.append(
            Parcel(
                parcel_id=parcel.parcel_id | ((i + 1) << AGG_SUB_SHIFT),
                source=parcel.source,
                dest=parcel.dest,
                nzc_chunk=Chunk(nzc),
                zc_chunks=list(chunks),
            )
        )
    return out


class InjectionThrottle:
    """Park-and-retry machinery for backpressured comm-interface posts
    (paper §3.3.4) — the sender-side throttle, shared verbatim by every
    parcelport AND the serving stack's request/response channel: a post the
    backend refused (falsy :class:`~repro.core.comm.interface.PostStatus`)
    parks as a thunk and is retried under a bounded per-call budget,
    stopping at the first refusal (the backend has not freed resources, so
    the rest would fail too — throttle instead of hammering)."""

    def __init__(self, retry_budget: int = 8):
        self.retry_budget = retry_budget
        self.parks = 0  # EAGAIN-parked posts (backpressure observability)
        self._q: deque = deque()
        # One lock serializes posting AND draining end to end: the FIFO
        # non-overtaking guarantee below must hold even when one thread
        # drains retries while another posts fresh work (e.g. the serve
        # loop flushing a token batch during an executor worker's pump).
        self._lock = threading.Lock()

    def post_or_park(self, thunk: Callable[[], Any]) -> bool:
        """Run a comm-interface post; if it EAGAINs, park it for retry.

        Non-overtaking (FIFO): while parked posts exist, a fresh post
        parks BEHIND them instead of attempting — otherwise a post issued
        after the backend freed resources would bypass an earlier parked
        one, reordering traffic the client issued in order (the serving
        channel's token batches rely on this)."""
        with self._lock:
            if self._q:
                self.parks += 1
                self._q.append(thunk)
                return False
            if thunk():
                return True
            self.parks += 1
            self._q.append(thunk)
            return False

    def drain(self) -> bool:
        """Retry up to ``retry_budget`` parked posts, oldest first.  The
        head stays queued until its retry succeeds, so a concurrent
        ``post_or_park`` always observes it and parks behind."""
        moved = False
        with self._lock:
            for _ in range(self.retry_budget):
                if not self._q:
                    break
                if self._q[0]():
                    self._q.popleft()
                    moved = True
                else:
                    break
        return moved

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class ParcelportBase:
    """Library-agnostic parcelport core (one per communication library per
    locality).  See the module docstring for what is shared here."""

    def __init__(
        self,
        locality: Any,
        aggregation: bool = False,
        agg_limit_bytes: int = 0,
        retry_budget: int = 8,
    ):
        self.locality = locality
        # The shared progress engine (set by the concrete parcelport once
        # its policy/router are known); also the decision-trace hub the
        # engine-parity suite reads.
        self.engine = None
        self.aggregation = aggregation
        # Threshold-aware aggregation: max projected aggregate size per
        # batch (0 = classic unbounded merge).
        self.agg_limit_bytes = agg_limit_bytes
        self._agg_queues: Dict[int, deque] = {}
        self._agg_lock = threading.Lock()
        # Backpressured posts awaiting retry (sender-side throttle, §3.3.4).
        self.retry_budget = retry_budget
        self._throttle = InjectionThrottle(retry_budget)
        self.stats_sent = 0
        self.stats_received = 0
        self.stats_agg_batches = 0  # threshold-aware drains that split

    # -- public API (paper Listing 2) ---------------------------------------
    def send(self, dest: int, parcel: Parcel, cb: Optional[SendCallback] = None) -> None:
        if not self.aggregation:
            self._send_impl(dest, parcel, cb)
            return
        # Aggregation path: enqueue, then drain everything for this dest.
        with self._agg_lock:
            q = self._agg_queues.setdefault(dest, deque())
            q.append((parcel, cb))
            drained = list(q)
            q.clear()
        if not drained:
            return
        batches = self._agg_batches(drained)
        if len(batches) > 1:
            self.stats_agg_batches += len(batches)
        for batch in batches:
            self._send_batch(dest, batch)

    def _agg_batches(self, drained: List[tuple]) -> List[List[tuple]]:
        """Split the drained queue into aggregate batches.

        Unbounded mode returns one batch (everything merges).  With
        ``agg_limit_bytes`` set, parcels pack greedily in FIFO order until
        the projected aggregate size (:func:`aggregate_projected_bytes`)
        would exceed the limit — so an aggregate of eager-sized parcels
        never spills past the eager threshold into rendezvous.  A parcel
        that alone exceeds the limit gets its own batch (it is rendezvous
        traffic regardless)."""
        if self.agg_limit_bytes <= 0:
            return [drained]
        batches: List[List[tuple]] = []
        cur: List[tuple] = []
        cur_bytes = AGG_PREAMBLE_BYTES
        for p, cb in drained:
            need = AGG_PER_PARCEL_BYTES + p.total_bytes
            if cur and cur_bytes + need > self.agg_limit_bytes:
                batches.append(cur)
                cur, cur_bytes = [], AGG_PREAMBLE_BYTES
            cur.append((p, cb))
            cur_bytes += need
        if cur:
            batches.append(cur)
        return batches

    def _send_batch(self, dest: int, batch: List[tuple]) -> None:
        if len(batch) == 1:
            self._send_impl(dest, batch[0][0], batch[0][1])
            return
        cbs = [c for (_p, c) in batch if c is not None]
        agg = aggregate_parcels([p for (p, _c) in batch])

        def agg_cb(_parcel: Parcel) -> None:
            for c in cbs:
                c(_parcel)

        self._send_impl(dest, agg, agg_cb)

    # -- injection backpressure (paper §3.3.4) ------------------------------
    @property
    def stats_backpressure_parks(self) -> int:
        return self._throttle.parks

    @property
    def _retry_q(self) -> deque:
        """The parked-post deque (the throttle's queue, historical name)."""
        return self._throttle._q

    def _post_or_park(self, thunk: Callable[[], Any]) -> None:
        """Run a comm-interface post; if it EAGAINs, park it for retry
        (delegates to the shared :class:`InjectionThrottle`)."""
        self._throttle.post_or_park(thunk)

    def _drain_retries(self) -> bool:
        """Retry parked posts under the bounded budget."""
        return self._throttle.drain()

    def retry_queue_depth(self) -> int:
        return len(self._throttle)

    def background_work(self) -> bool:
        raise NotImplementedError

    def pending_work(self) -> bool:
        """True while the parcelport still holds work no completion will
        ever surface on its own (e.g. backpressured posts parked for
        retry).  ``World.drain`` refuses to call a world quiescent while
        any parcelport reports pending work."""
        return bool(self._throttle)

    # -- subclass hook --------------------------------------------------------
    def _send_impl(self, dest: int, parcel: Parcel, cb: Optional[SendCallback]) -> None:
        raise NotImplementedError

    # -- receiver-side glue ---------------------------------------------------
    def deliver(self, parcel: Parcel) -> None:
        self.stats_received += 1
        if is_aggregate(parcel):
            parcels = split_aggregate(parcel)
            if self.engine is not None:
                self.engine.record("deliver", len(parcels))
            for p in parcels:
                self.locality.handle_parcel(p)
        else:
            if self.engine is not None:
                self.engine.record("deliver", 1)
            self.locality.handle_parcel(parcel)
