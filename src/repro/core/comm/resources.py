"""The shared resource model (paper §3.3.4).

One dataclass names every finite communication resource the reproduction
bounds, and *every* layer consumes it: the functional fabric
(:class:`repro.core.fabric.Fabric`) sizes its descriptor rings and
registered bounce-buffer pools from it, the LCI parcelport draws its retry
throttle from it, and the DES model (:class:`repro.amtsim.parcelport_sim.
SimConfig`) carries the *same object* — so the functional and performance
experiments can never drift apart field by field, which is what the old
hand-mirrored ``SimConfig.send_queue_depth``/``bounce_buffers``/... lists
allowed.  ``tools/check_api.py`` gates against the mirror re-growing.

All limits default to 0 = unbounded (the classic model); a config opts in
explicitly, exactly as the paper's §3.3.4 describes real NICs forcing
libraries to.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

__all__ = ["ResourceLimits"]


@dataclass(frozen=True)
class ResourceLimits:
    """Finite communication resources, shared by every layer.

    * ``send_queue_depth`` — per-device descriptor ring (0 = unbounded).
      A send occupies its slot from post until the send completion is
      reaped; a full ring refuses posts ``EAGAIN_QUEUE``-style.
    * ``bounce_buffers`` × ``bounce_buffer_size`` — the pool of
      pre-registered bounce buffers eager messages copy through (0 buffers
      = no pool).  An empty pool refuses eager posts ``EAGAIN_BUFFER``.
    * ``retry_budget`` — backpressured posts a parcelport retries per
      ``background_work`` call (the sender-side throttle).
    * ``recv_slots`` — pre-posted receive descriptors per device (0 =
      effectively unlimited).  Arrivals beyond the posted depth are RNR
      (receiver-not-ready) events: counted, and retried by hardware
      progress rather than lost.
    """

    send_queue_depth: int = 0
    bounce_buffers: int = 0
    bounce_buffer_size: int = 64 * 1024
    retry_budget: int = 8
    recv_slots: int = 0

    @property
    def bounded(self) -> bool:
        """True when injection is bounded (ring or pool finite)."""
        return self.send_queue_depth > 0 or self.bounce_buffers > 0

    def variant(self, **kw) -> "ResourceLimits":
        return replace(self, **kw)
