"""CollectiveComm — the :class:`CommInterface` backend over the JAX
collectives layer (ISSUE 5, ROADMAP follow-up).

The repo has had two communication stacks: the parcelport study (LCI/MPI
backends over the in-process fabric) and the jax_pallas serving/training
stack, whose request/response and gradient-sync hand-offs were ad-hoc
in-memory queues.  This module closes the loop: the *same* five-verb
contract the paper formalizes (§2.3, §3.3; companion proposal arXiv
2503.15400) now also fronts the transport the serving stack rides — the
JAX collectives layer used by :mod:`repro.train.grad_sync`,
:mod:`repro.launch.serve`, and :mod:`repro.serve.server`.

Pieces:

* :class:`CollectiveGroup` — the transport: a set of ``(rank, device)``
  endpoints exchanging byte messages.  The default **pure-python loopback**
  stage keeps tier-1 runnable without multi-host devices; ``stage='jax'``
  additionally round-trips every transmitted payload through a JAX device
  buffer (``device_put``/``device_get``) — what an all-to-all over the
  collectives layer degenerates to on one host.  One group per
  :class:`~repro.core.fabric.Fabric` (see :func:`collective_group_for`),
  drawing its bounds from the SAME shared
  :class:`~repro.core.comm.resources.ResourceLimits`.
* :class:`CollectiveComm` — one endpoint, a full backend: ``post_send`` /
  ``post_recv`` with (src, tag) matching and an unexpected-message queue,
  typed :class:`~repro.core.comm.interface.PostStatus` refusals
  (``EAGAIN_QUEUE`` when the transit ring is full, ``EAGAIN_BUFFER`` when
  the eager bounce accounting is exhausted), explicit ``progress`` /
  ``poll``, and **honest capabilities**: the collectives layer has no
  one-sided put-with-signal, so ``post_put_signal`` raises
  :class:`~repro.core.comm.interface.UnsupportedCapabilityError` and the
  parcelport above drops to the two-sided header path *by capability* —
  exactly the §3.3 fallback the abstraction exists to make automatic.
* :class:`CollectiveParcelport` — the LCI parcelport's protocol logic
  (eager/rendezvous selection, aggregation, backpressure throttle, the
  shared :class:`~repro.core.comm.progress.ProgressEngine`) over
  CollectiveComm endpoints instead of LCI devices.  Registered as the
  ``collective`` variant (plus the ``collective_prg{n}`` family).
* :class:`CommChannel` — the serving stack's request/response hand-off:
  a two-rank group, pre-posted tagged receives completing into shared
  completion queues, and :class:`~repro.core.comm.base.InjectionThrottle`
  parking on both sides.  :class:`repro.serve.server.InferenceServer`
  drives it through the shared engine.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from .base import InjectionThrottle
from .interface import (
    Capabilities,
    CompletionTarget,
    PostStatus,
    UnsupportedCapabilityError,
    complete,
)
from .progress import CompletionRouter, CompletionSource
from .resources import ResourceLimits

__all__ = [
    "CollectiveGroup",
    "CollectiveComm",
    "CollectiveParcelport",
    "CommChannel",
    "collective_group_for",
    "TAG_REQUEST",
    "TAG_RESPONSE",
    "FRAME_OVERHEAD",
]

# Per-message framing overhead (the tag word): THE LCI device's wire
# overhead, imported rather than restated, so eager-capacity arithmetic —
# and therefore the engine's protocol decisions — cannot drift between
# backends.
from ..device import WIRE_OVERHEAD as FRAME_OVERHEAD  # noqa: E402

TAG_REQUEST = 1  # serving hand-off: client -> server request bytes
TAG_RESPONSE = 2  # serving hand-off: server -> client token batches


class _Transit:
    """One posted-but-not-yet-exchanged message in an endpoint's ring."""

    __slots__ = ("dst_rank", "dst_dev", "tag", "data", "comp", "ctx", "eager", "bounce")

    def __init__(self, dst_rank, dst_dev, tag, data, comp, ctx, eager, bounce):
        self.dst_rank = dst_rank
        self.dst_dev = dst_dev
        self.tag = tag
        self.data = data
        self.comp = comp
        self.ctx = ctx
        self.eager = eager
        self.bounce = bounce  # True when the post claimed a bounce buffer


class _Record:
    """What the backend hands back to its client — same duck type as
    :class:`repro.core.device.CompletionRecord` so the parcelport's
    dispatch-by-kind works unchanged across backends."""

    __slots__ = ("op", "tag", "src_rank", "src_dev", "data", "ctx")

    def __init__(self, op, tag=-1, src_rank=-1, src_dev=-1, data=None, ctx=None):
        self.op = op
        self.tag = tag
        self.src_rank = src_rank
        self.src_dev = src_dev
        self.data = data
        self.ctx = ctx


class _PostedRecv:
    __slots__ = ("comp", "ctx")

    def __init__(self, comp: Any, ctx: Any):
        self.comp = comp
        self.ctx = ctx


class CollectiveGroup:
    """The collectives transport: ``n_ranks × devices_per_rank`` endpoints.

    Injection bounds come from one shared :class:`ResourceLimits` (the
    same object the fabric and the DES consume); stats use the fabric's
    :class:`~repro.core.fabric.FabricStats` shape so benchmark code reads
    either transport through one accessor."""

    def __init__(
        self,
        n_ranks: int,
        devices_per_rank: int = 1,
        limits: Optional[ResourceLimits] = None,
        stage: str = "loopback",
    ):
        assert stage in ("loopback", "jax"), stage
        from ..fabric import FabricStats  # stats shape shared with the fabric

        self.n_ranks = n_ranks
        self.devices_per_rank = max(1, devices_per_rank)
        self.limits = limits or ResourceLimits()
        self.stage = stage
        self.stats = FabricStats()
        # Endpoints on different ranks share these counters, and the
        # collective_prg{n} family sweeps them from real threads — every
        # update takes this lock (the fabric guards its stats likewise).
        self._stats_lock = threading.Lock()
        self._endpoints: Dict[Tuple[int, int], CollectiveComm] = {}
        for r in range(n_ranks):
            for d in range(self.devices_per_rank):
                self._endpoints[(r, d)] = CollectiveComm(self, r, d)

    def endpoint(self, rank: int, dev: int = 0) -> "CollectiveComm":
        return self._endpoints[(rank, dev)]

    def _stage_payload(self, data: bytes) -> Any:
        """Move one payload through the configured stage.  ``'jax'`` rides
        the accelerator runtime: host → device buffer → host, the one-host
        degenerate form of an all-to-all over the collectives layer."""
        return self._stage_batch([data])[0]

    def _stage_batch(self, datas: List[bytes]) -> List[Any]:
        """Move a whole aggregation drain through the stage at once.

        The ``'jax'`` stage used to round-trip every message through its
        own device buffer — one ``device_put``/``device_get`` pair per
        message, exactly the per-message software overhead the paper's
        data-plane argument is about (§5).  A drain now concatenates the
        batch into ONE staged device buffer: one transfer each way per
        batch, sliced back into zero-copy views on return.
        :class:`~repro.core.fabric.FabricStats` counts the staged bytes
        and batches (``staged_bytes`` / ``staged_batches``)."""
        if self.stage == "loopback" or not datas:
            return datas
        import jax
        import numpy as np

        sizes = [len(d) for d in datas]
        total = sum(sizes)
        flat = np.empty((total,), dtype=np.uint8)
        off = 0
        for d, n in zip(datas, sizes):
            flat[off : off + n] = np.frombuffer(d, dtype=np.uint8)
            off += n
        arr = jax.device_put(flat)
        back = memoryview(np.asarray(jax.device_get(arr)).data)
        with self._stats_lock:
            self.stats.staged_bytes += total
            self.stats.staged_batches += 1
        out: List[bytes] = []
        off = 0
        for n in sizes:
            out.append(back[off : off + n])
            off += n
        return out


def collective_group_for(fabric: Any, devices_per_rank: int = 1, stage: str = "loopback") -> CollectiveGroup:
    """The one :class:`CollectiveGroup` of a world, keyed on its fabric —
    every locality's parcelport joins the same group, and the group draws
    its bounds from ``fabric.limits`` (the shared resource model), so
    ``lci_b{depth}``-style limits bind the collective transport too."""
    group = getattr(fabric, "_collective_group", None)
    if group is None:
        group = CollectiveGroup(
            fabric.n_ranks, devices_per_rank=devices_per_rank, limits=fabric.limits, stage=stage
        )
        fabric._collective_group = group
    return group


class CollectiveComm:
    """One endpoint of the collectives transport — a full five-verb
    :class:`~repro.core.comm.interface.CommInterface` backend.

    A post claims a transit-ring slot (``EAGAIN_QUEUE`` when
    ``limits.send_queue_depth`` is exhausted) and, for eager messages, one
    unit of the bounce accounting (``EAGAIN_BUFFER``); both free when the
    endpoint's own :meth:`progress` exchanges the message — a rank that
    stops progressing throttles its own injection, like real hardware.
    Receive matching mirrors the LCI device: posted (src, tag) queues,
    any-source queues, and an unexpected-message queue for arrivals that
    beat their receive."""

    def __init__(self, group: CollectiveGroup, rank: int, dev_index: int):
        self.group = group
        self.rank = rank
        self.dev_index = dev_index
        self._send_lock = threading.Lock()
        self._outbox: deque = deque()  # transit ring (posted, unexchanged)
        self._inflight = 0  # occupied ring slots
        self._bounce_free = group.limits.bounce_buffers
        self._inbox: deque = deque()  # arrived (src_rank, tag, payload)
        self._inbox_lock = threading.Lock()
        self._match_lock = threading.Lock()
        self._posted: Dict[Tuple[int, int], deque] = {}  # (src, tag)
        self._posted_any: Dict[int, deque] = {}  # tag (any-source)
        self._unexpected: Dict[Tuple[int, int], deque] = {}
        self.progress_calls = 0

    @property
    def capabilities(self) -> Capabilities:
        """Honest capabilities: the collectives layer offers no one-sided
        put-with-signal; completions queue, progress is explicit, and
        EAGAIN is surfaced whenever the shared limits bound injection."""
        return Capabilities(
            one_sided_put=False,
            queue_completion=True,
            explicit_progress=True,
            bounded_injection=self.group.limits.bounded,
        )

    def eager_capacity(self) -> Optional[int]:
        """Largest eager message this endpoint can inject (None = no
        bounce accounting = unlimited) — same contract as the LCI device."""
        lim = self.group.limits
        return lim.bounce_buffer_size if lim.bounce_buffers > 0 else None

    # ------------------------------------------------------------------ posts
    def post_send(
        self, dst_rank: int, dst_dev: int, tag: int, data: bytes,
        comp: CompletionTarget, ctx: Any = None, eager: bool = False,
    ) -> PostStatus:
        """Nonblocking tagged send; ``comp`` completes locally once the
        message is exchanged.  Typed EAGAIN on a full transit ring or an
        exhausted eager bounce accounting."""
        lim = self.group.limits
        size = len(data) + FRAME_OVERHEAD
        with self._send_lock:
            if lim.send_queue_depth and self._inflight >= lim.send_queue_depth:
                with self.group._stats_lock:
                    self.group.stats.backpressure_events += 1
                return PostStatus.EAGAIN_QUEUE
            bounce = False
            if eager and lim.bounce_buffers > 0:
                if self._bounce_free <= 0 or size > lim.bounce_buffer_size:
                    with self.group._stats_lock:
                        self.group.stats.backpressure_events += 1
                    return PostStatus.EAGAIN_BUFFER
                self._bounce_free -= 1
                bounce = True
            self._inflight += 1
            self._outbox.append(
                _Transit(dst_rank, dst_dev, tag, bytes(data), comp, ctx, eager, bounce)
            )
        return PostStatus.OK

    def post_recv(self, src_rank: int, tag: int, comp: CompletionTarget, ctx: Any = None) -> None:
        """Pre-post a tagged receive (``src_rank`` may be -1 = any source).
        Delivery of an already-arrived (unexpected) message happens OUTSIDE
        the matching lock: ``signal`` is an arbitrary client callback and
        may legally post another receive on this endpoint."""
        pr = _PostedRecv(comp, ctx)
        matched = None
        with self._match_lock:
            if src_rank >= 0:
                uq = self._unexpected.get((src_rank, tag))
                if uq:
                    matched = uq.popleft()
            else:
                for (s, t), uq in self._unexpected.items():
                    if t == tag and uq:
                        matched = uq.popleft()
                        break
            if matched is None:
                if src_rank >= 0:
                    self._posted.setdefault((src_rank, tag), deque()).append(pr)
                else:
                    self._posted_any.setdefault(tag, deque()).append(pr)
        if matched is not None:
            src, data = matched
            self._deliver_recv(pr, src, tag, data)

    def post_put_signal(
        self, dst_rank: int, dst_dev: int, data: bytes,
        comp: CompletionTarget, ctx: Any = None, eager: bool = False,
    ) -> PostStatus:
        raise UnsupportedCapabilityError(
            "the JAX collectives layer has no one-sided put-with-signal "
            "(capabilities.one_sided_put=False) — use the two-sided path"
        )

    # --------------------------------------------------------------- progress
    def progress(self, max_completions: int = 16) -> bool:
        """Explicitly drive the transport: exchange up to
        ``max_completions`` of this endpoint's posted messages (freeing
        their ring slots / bounce units and signalling send completions),
        then match arrivals waiting in this endpoint's inbox."""
        self.progress_calls += 1
        moved = False
        # Drain the whole batch of posted transits first, then stage them
        # through the transport in ONE device-buffer round trip (see
        # CollectiveGroup._stage_batch) — one transfer per drain instead of
        # one per message.  Delivery, stats, and completion signalling stay
        # per message, in post order.
        batch: List[_Transit] = []
        with self._send_lock:
            while self._outbox and len(batch) < max_completions:
                batch.append(self._outbox.popleft())
        if batch:
            payloads = self.group._stage_batch([t.data for t in batch])
            for t, payload in zip(batch, payloads):
                dest = self.group.endpoint(t.dst_rank, t.dst_dev)
                with dest._inbox_lock:
                    dest._inbox.append((self.rank, t.tag, payload))
                st = self.group.stats
                with self.group._stats_lock:
                    st.messages += 1
                    st.sends += 1
                    st.bytes += len(payload) + FRAME_OVERHEAD
                    if t.eager:
                        st.eager_msgs += 1
                    else:
                        st.rendezvous_msgs += 1
                with self._send_lock:
                    self._inflight -= 1
                    if t.bounce:
                        self._bounce_free += 1
                complete(t.comp, _Record(op="send", tag=t.tag, ctx=t.ctx))
            moved = True
        for _ in range(max_completions):
            with self._inbox_lock:
                if not self._inbox:
                    break
                src, tag, payload = self._inbox.popleft()
            self._match_incoming(src, tag, payload)
            moved = True
        return moved

    def poll(self, max_completions: int = 16) -> bool:
        """Completion-test-driven progress — the implicit entry point; at
        this layer it shares :meth:`progress`'s implementation (polling
        the transport IS both), as in the LCI device."""
        return self.progress(max_completions)

    def pending_transport(self) -> bool:
        """Anything still moving through this endpoint: unexchanged
        transits or unmatched arrivals (the base hook every channel-capable
        backend exposes)."""
        return bool(self._outbox or self._inbox)

    # --------------------------------------------------------------- matching
    def _match_incoming(self, src: int, tag: int, payload: bytes) -> None:
        with self._match_lock:
            q = self._posted.get((src, tag))
            if q:
                pr = q.popleft()
            else:
                qa = self._posted_any.get(tag)
                if qa:
                    pr = qa.popleft()
                else:
                    self._unexpected.setdefault((src, tag), deque()).append((src, payload))
                    return
        self._deliver_recv(pr, src, tag, payload)

    def _deliver_recv(self, pr: _PostedRecv, src: int, tag: int, data: bytes) -> None:
        complete(pr.comp, _Record(op="recv", tag=tag, src_rank=src, data=data, ctx=pr.ctx))


from ..lci_parcelport import LCIParcelport  # noqa: E402  (no cycle: the
# lci parcelport imports comm.progress/resources only, never this module)


class CollectiveParcelport(LCIParcelport):
    """The LCI parcelport's protocol logic over CollectiveComm endpoints.

    Defined by *difference*: only device creation changes.  Because the
    endpoints advertise ``one_sided_put=False``, the inherited
    capability-driven selection drops the header path to two-sided
    send/recv automatically — no protocol code is duplicated, which is the
    paper's whole point about the abstraction (§2.3).  The engine-parity
    suite asserts the decision traces match the LCI backend's bit for bit.
    """

    def _make_devices(self, fabric: Any, config: Any) -> List[CollectiveComm]:
        group = collective_group_for(fabric, devices_per_rank=config.ndevices)
        return [group.endpoint(self.locality.rank, d) for d in range(config.ndevices)]


class CommChannel:
    """The serving stack's request/response hand-off over CommInterface
    verbs (client = rank 0, server = rank 1).

    Requests ride ``TAG_REQUEST``, responses (token batches) ride
    ``TAG_RESPONSE``; both directions pre-post tagged receives that
    complete into shared completion queues, re-posted on reap.  Posts the
    transport refuses park in per-direction
    :class:`~repro.core.comm.base.InjectionThrottle`\\ s and retry under
    the shared ``limits.retry_budget`` — the serving hot path gets the
    SAME backpressure/throttle behaviour as the parcelport study.

    **Multi-endpoint registration (ISSUE 7):** the fleet runs N of these
    channels over ONE shared group — pass ``group`` plus explicit
    ``client_rank`` / ``server_rank``, and a shared ``response_cq`` so
    every worker's token batches land in the SAME router-owned queue
    (rank ``client_rank``'s slab is genuinely the router-owned slot space
    on put-capable backends).  Put-target registration on the shared
    client endpoint is idempotent: every channel must bind the same
    landing queue, never silently rebind it."""

    PREPOST = 16

    def __init__(
        self,
        limits: Optional[ResourceLimits] = None,
        stage: str = "loopback",
        backend: str = "collective",
        group: Any = None,
        client_rank: int = 0,
        server_rank: int = 1,
        response_cq: Any = None,
    ):
        from ..completion import LCRQueue

        assert backend in ("collective", "shmem"), backend
        self.limits = limits or ResourceLimits()
        if group is not None:
            self.group = group  # fleet: N channels share one group
        elif backend == "shmem":
            # the true one-sided transport (same two-rank topology)
            from .shmem import ShmemGroup

            self.group: Any = ShmemGroup(2, 1, limits=self.limits, completion_mode="queue")
        else:
            self.group = CollectiveGroup(2, 1, limits=self.limits, stage=stage)
        self.client_rank, self.server_rank = client_rank, server_rank
        self.client = self.group.endpoint(client_rank, 0)
        self.server = self.group.endpoint(server_rank, 0)
        self.request_cq = LCRQueue()  # server-side: arrived requests
        # client-side: arrived token batches — shared across a fleet's
        # channels when the router passes its own landing queue in
        self.response_cq = LCRQueue() if response_cq is None else response_cq
        self._client_throttle = InjectionThrottle(self.limits.retry_budget)
        self._server_throttle = InjectionThrottle(self.limits.retry_budget)
        # Register the router-owned landing queues as put targets where the
        # backend takes one — what makes ``one_sided_put`` honest (a put
        # needs somewhere to complete, exactly like the LCI device's
        # put_target_comp): responses land in the client's response queue,
        # requests would land in the server's request queue.
        for ep, landing in ((self.client, self.response_cq), (self.server, self.request_cq)):
            if hasattr(ep, "put_target_comp"):
                prev = ep.put_target_comp
                assert prev is None or prev is landing, (
                    "endpoint already bound to a different put landing queue "
                    "(fleet channels must share the router's response_cq)"
                )
                ep.put_target_comp = landing
        # ISSUE 6 re-target, selected PURELY by Capabilities (never by
        # backend name/type): when the transport advertises one-sided put,
        # responses ride put straight into the router-owned response queue
        # — no tag, no matching, no pre-posted receive consumed (§3.3.1).
        self._put_responses = self.server.capabilities.one_sided_put
        for _ in range(self.PREPOST):
            self.server.post_recv(-1, TAG_REQUEST, self.request_cq, ctx="request")
            self.client.post_recv(-1, TAG_RESPONSE, self.response_cq, ctx="response")

    # -- posting (any thread) ------------------------------------------------
    def _eager(self, payload: bytes) -> bool:
        cap = self.client.eager_capacity()
        return cap is not None and len(payload) + FRAME_OVERHEAD <= cap

    def send_request(self, payload: bytes) -> None:
        """Client → server; parks on EAGAIN, retried by the engine step."""
        eager = self._eager(payload)
        self._client_throttle.post_or_park(
            lambda: self.client.post_send(self.server_rank, 0, TAG_REQUEST, payload, self.response_cq, ctx="sent", eager=eager)
        )

    def send_response(self, payload: bytes) -> None:
        """Server → client; parks on EAGAIN, retried by the engine step.

        With a put-capable backend (``self._put_responses``, from the
        Capabilities alone) the token batch rides one-sided put into the
        client's router-owned response queue; otherwise the two-sided
        tagged path."""
        eager = self._eager(payload)
        if self._put_responses:
            self._server_throttle.post_or_park(
                lambda: self.server.post_put_signal(self.client_rank, 0, payload, self.request_cq, ctx="sent", eager=eager)
            )
            return
        self._server_throttle.post_or_park(
            lambda: self.server.post_send(self.client_rank, 0, TAG_RESPONSE, payload, self.request_cq, ctx="sent", eager=eager)
        )

    # -- the engine's op surface --------------------------------------------
    def router(self) -> CompletionRouter:
        """The channel's completion topology for the shared engine: the
        server-side request queue, then the client-side response queue."""
        return CompletionRouter(
            [CompletionSource("request"), CompletionSource("response")], ndevices=1
        )

    def progress(self) -> bool:
        a = self.client.progress()
        b = self.server.progress()
        return a or b

    def poll(self) -> bool:
        a = self.client.poll()
        b = self.server.poll()
        return a or b

    def drain_retries(self) -> bool:
        a = self._client_throttle.drain()
        b = self._server_throttle.drain()
        return a or b

    def reap(self, source: str) -> Any:
        return (self.request_cq if source == "request" else self.response_cq).reap()

    def repost(self, ctx: Any) -> None:
        """Keep the pre-post depth after reaping a receive completion."""
        if ctx == "request":
            self.server.post_recv(-1, TAG_REQUEST, self.request_cq, ctx="request")
        elif ctx == "response":
            self.client.post_recv(-1, TAG_RESPONSE, self.response_cq, ctx="response")

    def pending_work(self) -> bool:
        """Anything still moving: parked posts, in-flight transport work
        (the backend's ``pending_transport`` hook), or unreaped
        completions."""
        return bool(
            self._client_throttle
            or self._server_throttle
            or self.client.pending_transport()
            or self.server.pending_transport()
            or len(self.request_cq)
            or len(self.response_cq)
        )

    def backpressure_parks(self) -> int:
        return self._client_throttle.parks + self._server_throttle.parks
