"""ONE progress engine for every executor (paper §3.4, §5.3).

The paper names **explicit progressing** and **resource-contention
mitigation** as communication needs MPI covers poorly, and the companion
proposals (arXiv 2503.15400; *LCI: a Lightweight Communication Interface*)
argue the progress/completion engine should be a first-class,
policy-parameterized component — not an ad-hoc loop re-written inside every
backend.  Before this module, that loop existed three times in this repo
(the LCI parcelport, the MPI parcelport, and ~270 duplicated lines in the
DES).  Now it exists once.

The engine is a **decision sequence**, not an executor: :meth:`ProgressEngine.
step` is a generator that yields small *ops* — ``drain_retries``,
``progress``, ``reap``, ``dispatch``, lock ops — and receives each op's
result back via ``send()``.  The caller supplies the op semantics:

* the **functional parcelports** drive it with :func:`run_step`, executing
  each op against real devices and completion objects;
* the **DES** drives it from a simulation process, charging calibrated
  :class:`~repro.amtsim.costs.Mechanisms` costs (and simulating lock
  contention) per op, then feeding the result back.

Because both layers replay the *same* op sequence for the same
configuration, the protocol-path and completion-dispatch decisions cannot
drift — the engine-parity suite (tests/test_progress_engine.py) asserts
ordered decision traces are identical across layers.

One step is the canonical loop::

    drain retries  →  progress device(s)  →  reap completions  →
    dispatch by kind  →  (implicit mode: poll on an empty reap)

parameterized by

* a :class:`ProgressPolicy` — who invokes the progress engine and under
  which lock discipline (§5.3): worker-polling implicit, explicit
  try-lock, the blocking-lock "catastrophic" combination, the MPI
  request-pool discipline, and **dedicated progress workers** (§3.3.4's
  omitted experiment, the ``lci_prg{n}`` family);
* a :class:`CompletionRouter` — the ordered :class:`~repro.core.comm.
  interface.CompletionTarget` sources a worker reaps each step, shared
  vs per-device completion queues (§3.3.3, the ``lci_shared_cq`` axis).

Op vocabulary (a tuple ``(kind, *args)``; results flow back via ``send``):

======================  =======================================================
op                      meaning / expected result
======================  =======================================================
``step_trylock``        whole-step try-lock (MPI request-pool discipline);
                        ``False`` aborts the step
``step_unlock``         release the step lock
``big_lock``            blocking library big lock (MPI) around the step
``big_unlock``          release it
``drain_retries``       retry backpressured posts under the budget → moved?
``implicit_tax``        implicit progress rides on a completion test: the
                        cost of that test (DES charges it; functional no-op)
``progress`` *d*        explicitly drive device *d*'s progress engine → moved?
``poll`` *d*            completion-test-driven progress on device *d* → moved?
``dev_lock`` *d*        blocking coarse lock on device *d* (§5.3)
``dev_trylock`` *d*     try-lock; ``False`` skips the device's reaps
``dev_unlock`` *d*      release the coarse lock
``reap_begin`` *s d*    entering source *s* on device *d* (platform CQ-lock
                        / poll-sweep costs live here)
``reap`` *s d*          one completed item from source *s* (None = empty)
``dispatch`` *s d i*    dispatch item *i* by kind → did it advance anything?
``reap_end`` *s d*      leaving the source
``flush``               deliver work deferred outside the library locks
======================  =======================================================
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "LOCK_NONE",
    "LOCK_TRY",
    "LOCK_BLOCK",
    "PROGRESS_EXPLICIT",
    "PROGRESS_IMPLICIT",
    "ROLE_TASK",
    "ROLE_PROGRESS",
    "ProgressPolicy",
    "CompletionSource",
    "CompletionRouter",
    "ProgressEngine",
    "run_step",
]

PROGRESS_EXPLICIT = "explicit"
PROGRESS_IMPLICIT = "implicit"

# Coarse-lock disciplines (§5.3).  String values match
# :class:`repro.core.device.LockMode` — comm/ sits *below* device.py in the
# layer diagram, so the constants live here rather than being imported up.
LOCK_NONE = "none"
LOCK_TRY = "try"
LOCK_BLOCK = "block"

#: an ordinary worker thread: runs tasks, pumps background work when idle
ROLE_TASK = "task"
#: a core reserved to drive the progress engine only (§3.3.4, ``lci_prg{n}``)
ROLE_PROGRESS = "progress"


@dataclass(frozen=True)
class ProgressPolicy:
    """Who drives the progress engine, and under which lock discipline.

    The four policies the paper's §5.3 ladder studies, plus the MPI
    request-pool structure, all parameterize the same step loop:

    * :meth:`worker_polling` — *implicit* progress: every worker polls
      completion objects; the engine runs only on an empty poll (the MPI
      behaviour, ``progress_mode='implicit'``).
    * :meth:`explicit_trylock` — explicit progress under a coarse try
      lock: a contended call gives up (the scheduler has other work).
    * :meth:`blocking` — the **catastrophic** §5.3 combination: explicit
      eager progress under a coarse *blocking* lock (every idle worker
      piles onto the same futex).
    * :meth:`dedicated` — ``n`` workers are reserved to drive the engine
      (``ROLE_PROGRESS``); task workers fall back to implicit polling.
    * :meth:`mpi_request_pool` — the whole step behind a pool try-lock
      and the library big lock, progress fused into completion tests.
    """

    progress_mode: str = PROGRESS_EXPLICIT  # 'explicit' | 'implicit'
    lock_mode: str = LOCK_NONE  # coarse per-device lock: none|try|block
    step_lock: bool = False  # whole step behind a try-lock (MPI pools)
    big_lock: bool = False  # whole step under the blocking big lock (MPI)
    dedicated_workers: int = 0  # cores reserved for ROLE_PROGRESS

    # -- named policies (§5.3 ladder) ---------------------------------------
    @classmethod
    def worker_polling(cls) -> "ProgressPolicy":
        return cls(progress_mode=PROGRESS_IMPLICIT)

    @classmethod
    def explicit_trylock(cls) -> "ProgressPolicy":
        return cls(progress_mode=PROGRESS_EXPLICIT, lock_mode=LOCK_TRY)

    @classmethod
    def blocking(cls) -> "ProgressPolicy":
        """Blocking lock + eager explicit progress — §5.3's catastrophe."""
        return cls(progress_mode=PROGRESS_EXPLICIT, lock_mode=LOCK_BLOCK)

    @classmethod
    def dedicated(cls, n: int) -> "ProgressPolicy":
        return cls(progress_mode=PROGRESS_IMPLICIT, dedicated_workers=n)

    @classmethod
    def mpi_request_pool(cls) -> "ProgressPolicy":
        return cls(progress_mode=PROGRESS_EXPLICIT, step_lock=True, big_lock=True)

    @classmethod
    def for_config(cls, cfg: Any) -> "ProgressPolicy":
        """Derive the policy from a parcelport config (``LCIPPConfig`` or
        the DES ``SimConfig`` — the same fields, by design)."""
        if getattr(cfg, "mpi", False):
            return cls.mpi_request_pool()
        return cls(
            progress_mode=cfg.progress_mode,
            lock_mode=cfg.lock_mode,
            dedicated_workers=getattr(cfg, "progress_workers", 0),
        )

    def variant(self, **kw) -> "ProgressPolicy":
        return replace(self, **kw)


@dataclass(frozen=True)
class CompletionSource:
    """One place completed operations surface (§3.3.2 / §5.2).

    The engine never interprets ``name`` — the adapter executing the ops
    does.  What the engine *does* own: the reap batch, whether the source
    is replicated per device, which devices a worker sweeps, whether reaps
    happen under the policy's coarse device lock, and whether the source
    belongs to the progress engine itself (``progress_side`` — what a
    dedicated ``ROLE_PROGRESS`` worker reaps; client-side completion
    objects stay with task workers)."""

    name: str
    batch: int = 8
    per_device: bool = False  # one instance of this source per device
    sweep: str = "own"  # 'own' = the worker's mapped device; 'all' = rotate
    locked: bool = False  # reap under the policy's coarse device lock
    progress_side: bool = False  # reaped by dedicated progress workers too


class CompletionRouter:
    """The ordered completion sources one step reaps (§3.3.3).

    ``shared`` scope routes every completion through one MPMC queue (LCI's
    default: load balance across devices); ``device`` scope gives each
    device its own queue (less queue contention, per-device imbalance) —
    workers still sweep all device queues, own-device first, so a
    single-threaded pump keeps liveness."""

    def __init__(self, sources: Sequence[CompletionSource], ndevices: int = 1):
        self.ndevices = max(1, ndevices)
        self._sources: Tuple[CompletionSource, ...] = tuple(sources)
        self._progress_side = tuple(s for s in self._sources if s.progress_side)

    def sources(self, role: str = ROLE_TASK) -> Tuple[CompletionSource, ...]:
        return self._progress_side if role == ROLE_PROGRESS else self._sources

    def devices_for(self, source: CompletionSource, wid: int, role: str) -> Tuple[int, ...]:
        """Which device instances of a per-device source this worker reaps
        (static worker→device mapping, §3.3.3; ``sweep='all'`` rotates so
        the worker's own device comes first)."""
        if not source.per_device:
            return (-1,)
        nd = self.ndevices
        start = wid % nd
        if role == ROLE_PROGRESS or source.sweep == "all":
            return tuple((start + k) % nd for k in range(nd))
        return (start,)


class ProgressEngine:
    """The single step loop (see module docstring).

    One engine per parcelport (functional) or per simulated world (DES);
    the engine is pure decision logic, so it carries no device or queue
    references — those live behind the adapter executing its ops.

    ``trace`` (when set to a list) records normalized protocol decisions
    (``('send', path, nfollowups)``, ``('header', path)``, ``('chunk',)``,
    ``('deliver', n)``) pushed by the adapters via :meth:`record` — the
    engine-parity suite compares these across layers."""

    #: EWMA smoothing for the reap statistics (one knob, shared with the DES)
    REAP_EWMA_ALPHA = 0.2

    def __init__(self, policy: ProgressPolicy, router: CompletionRouter, ndevices: int = 1):
        self.policy = policy
        self.router = router
        self.ndevices = max(1, ndevices)
        self.trace: Optional[List[tuple]] = None
        # cheap reap-side instrumentation (no per-item stamps, no trace
        # entries — decision-trace parity is unaffected): gap between
        # non-empty reap sweeps and items reaped per sweep, as EWMA +
        # high-water.  The ElasticProgressController consumes these.
        self._reap_last: Optional[float] = None
        self._reap_gap_ewma = 0.0
        self._reap_gap_high = 0.0
        self._reap_occ_ewma = 0.0
        self._reap_occ_high = 0
        self._reap_sweeps = 0
        self._reap_items = 0

    # -- decision trace ------------------------------------------------------
    def record(self, *event: Any) -> None:
        if self.trace is not None:
            self.trace.append(event)

    # -- reap-latency instrumentation (§3.3.4 adaptivity signal) -------------
    def _note_reap_sweep(self, n: int) -> None:
        """Account one non-empty reap sweep: ``n`` items came off a
        completion source in one batch."""
        alpha = self.REAP_EWMA_ALPHA
        now = time.monotonic()
        if self._reap_last is not None:
            gap = now - self._reap_last
            self._reap_gap_ewma += alpha * (gap - self._reap_gap_ewma)
            if gap > self._reap_gap_high:
                self._reap_gap_high = gap
        self._reap_last = now
        self._reap_occ_ewma += alpha * (n - self._reap_occ_ewma)
        if n > self._reap_occ_high:
            self._reap_occ_high = n
        self._reap_sweeps += 1
        self._reap_items += n

    def reap_latency_stats(self) -> Dict[str, float]:
        """Cheap counters for the elastic-progress decision (and results
        reporting): EWMA + high-water of the gap between non-empty reap
        sweeps (wall seconds — meaningful on the functional layer; the DES
        keeps its own sim-time latency) and of the per-sweep occupancy
        (items per batch — backlog pressure, meaningful on both layers)."""
        return {
            "reap_gap_ewma": self._reap_gap_ewma,
            "reap_gap_high": self._reap_gap_high,
            "occupancy_ewma": self._reap_occ_ewma,
            "occupancy_high": float(self._reap_occ_high),
            "sweeps": float(self._reap_sweeps),
            "items": float(self._reap_items),
        }

    # -- the one step loop ---------------------------------------------------
    def step(self, wid: int, role: str = ROLE_TASK):
        """One background-work invocation: yields ops, returns ``moved``.

        ``role=ROLE_PROGRESS`` is the dedicated-worker variant of the same
        loop: progress runs on *every* device regardless of progress_mode,
        and only progress-side sources are reaped."""
        pol = self.policy
        progressed = False
        if pol.step_lock:
            # MPI request-pool discipline: one thread in the step at a time
            if not (yield ("step_trylock",)):
                return False
        if pol.big_lock:
            yield ("big_lock",)
        # 1. drain retries: backpressured posts first (§3.3.4 throttle)
        progressed = bool((yield ("drain_retries",))) or progressed
        # 2. progress device(s), per the policy
        if pol.progress_mode == PROGRESS_EXPLICIT or role == ROLE_PROGRESS:
            progressed = (yield from self._progress_pass(wid, role, "progress")) or progressed
        else:
            # implicit progress rides on a (possibly failed) completion
            # test — charge the test, progress happens at reduced rate
            yield ("implicit_tax",)
        # 3+4. reap completions and dispatch by kind
        polled = False
        for src in self.router.sources(role):
            for d in self.router.devices_for(src, wid, role):
                if src.locked and pol.lock_mode == LOCK_BLOCK:
                    yield ("dev_lock", d)
                elif src.locked and pol.lock_mode == LOCK_TRY:
                    if not (yield ("dev_trylock", d)):
                        continue
                yield ("reap_begin", src, d)
                sweep_items = 0
                for _ in range(src.batch):
                    item = yield ("reap", src, d)
                    if item is None:
                        break
                    polled = True
                    sweep_items += 1
                    progressed = bool((yield ("dispatch", src, d, item))) or progressed
                yield ("reap_end", src, d)
                if sweep_items:
                    self._note_reap_sweep(sweep_items)
                if src.locked and pol.lock_mode != LOCK_NONE:
                    yield ("dev_unlock", d)
        # 5. implicit mode: progress only as a side effect of an *empty*
        # completion test (the MPI behaviour), then retry parked posts —
        # the poll may have reaped send completions and freed resources
        if pol.progress_mode == PROGRESS_IMPLICIT and role == ROLE_TASK and not polled:
            progressed = (yield from self._progress_pass(wid, role, "poll")) or progressed
            progressed = bool((yield ("drain_retries",))) or progressed
        if pol.big_lock:
            yield ("big_unlock",)
        if pol.step_lock:
            yield ("step_unlock",)
        # deliveries deferred outside the library locks (MPI structure)
        progressed = bool((yield ("flush",))) or progressed
        return progressed

    def _progress_pass(self, wid: int, role: str, verb: str):
        """Drive the progress verb on this worker's device — or on every
        device for a dedicated progress worker."""
        moved = False
        nd = self.ndevices
        devs = range(nd) if role == ROLE_PROGRESS else (wid % nd,)
        for d in devs:
            moved = bool((yield (verb, d))) or moved
        return moved


def run_step(engine: ProgressEngine, ops: Any, wid: int, role: str = ROLE_TASK) -> bool:
    """Drive one engine step synchronously (the functional executors).

    ``ops.execute(op) -> result`` supplies the op semantics; the DES has
    its own driver (a simulation process) that charges costs per op.

    If an op raises after a ``step_trylock`` succeeded, the step lock is
    released before the exception propagates — an adapter that implements
    the lock for real (the serving engine does) must not stay wedged
    behind an abandoned generator."""
    gen = engine.step(wid, role)
    result: Any = None
    execute = ops.execute
    step_locked = False
    try:
        while True:
            try:
                op = gen.send(result)
            except StopIteration as stop:
                return bool(stop.value)
            result = execute(op)
            if op[0] == "step_trylock":
                step_locked = bool(result)
            elif op[0] == "step_unlock":
                step_locked = False
    except BaseException:
        if step_locked:
            try:
                execute(("step_unlock",))
            except Exception:
                pass
        raise
