"""ShmemComm — the shared-memory :class:`CommInterface` backend with a
TRUE one-sided ``post_put_signal`` (ISSUE 6, completing the capability
ladder of ROADMAP item 4).

Until now the only put-capable device was the simulated
:class:`~repro.core.device.LCIDevice`, and :class:`~repro.core.comm.
collective.CollectiveComm` honestly declines the verb — so the
capability-driven protocol selection could only *degrade* (put → two-sided
fallback), never act as a measured *speedup* axis.  This backend puts real
bytes through real shared buffers: the sender writes the payload directly
into a **receiver-owned slot** of a shared-memory segment and raises a
signal, with no tag matching and no posted receive on the critical path —
LCI's ideal primitive (paper §3.3.1; companion proposals arXiv 2505.01864
and 2503.15400 motivate put + queue-completion as the primitive AMT
runtimes want).

The capability ladder, as variants (see :mod:`repro.core.variants`):

* ``shmem`` — **two-sided emulation**: the same slots, but messages carry a
  tag and the receiver runs the posted/unexpected matching path
  (``header_mode='sendrecv'``).  The rung every put-less transport stands
  on.
* ``shmem_put`` — **put-signal**: the sender raises the per-slot signal
  flag; the receiver's progress engine discovers completed puts by
  *scanning* the raised signals — a serialized test, no queue machinery
  (``header_mode='put', header_comp='sync'``).
* ``shmem_putq`` — **put + queue-completion**: after writing the slot the
  sender enqueues a completion descriptor directly into the receiver's
  completion ring; receiver progress pops descriptors, never scans
  (``header_mode='put', header_comp='queue'`` — the paper's preferred
  mechanism, §3.3.1/§3.3.2).

Slot/buffer accounting draws from the SAME shared
:class:`~repro.core.comm.resources.ResourceLimits` as the fabric, the
parcelports and the DES (``recv_slots`` sizes the receiver-owned slot
array, ``bounce_buffer_size`` the slot payload capacity,
``send_queue_depth`` the sender's transit ring), and the backend is driven
by the ONE shared :class:`~repro.core.comm.progress.ProgressEngine` — the
:class:`ShmemParcelport` below changes *only* device creation, exactly
like the collective backend.

Segment backing: ``'anon'`` (default) maps an anonymous shared page range
(``mmap(-1, n)``) — real shared memory, reclaimed by plain GC, safe for
the thousands of short-lived test worlds; ``'shm'`` uses named POSIX
segments via :mod:`multiprocessing.shared_memory` (close/unlink handled by
an explicit :meth:`ShmemGroup.close` plus a ``weakref.finalize``
backstop).  Both stage payload bytes through the one shared buffer — the
bytes the receiver reads are the bytes in the slab, not a Python-object
hand-off.
"""
from __future__ import annotations

import mmap
import struct
import threading
import weakref
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from ...analysis.sanitizer import make_lock, note_access
from .interface import (
    Capabilities,
    CompletionTarget,
    PostStatus,
    UnsupportedCapabilityError,
    complete,
)
from .resources import ResourceLimits

__all__ = [
    "ShmemSegment",
    "ShmemGroup",
    "ShmemComm",
    "ShmemParcelport",
    "shmem_group_for",
    "live_segments",
    "DEFAULT_SLOTS",
]

# Per-message framing overhead (the tag word) for the two-sided emulation
# rung — imported from THE device constant so eager-capacity arithmetic
# cannot drift between backends.  Puts add nothing (no tag, no matching).
from ..device import WIRE_OVERHEAD as FRAME_OVERHEAD  # noqa: E402

#: receiver-owned slots per endpoint when ``limits.recv_slots`` is 0
#: (matches the LCI device's pre-post depth)
DEFAULT_SLOTS = 64

# In-slab slot header: kind, src_rank, src_dev, tag, payload length (the
# tag is 64-bit: follow-up tags are locality-unique parcel ids, rank << 40).
_SLOT_HDR = struct.Struct("<Biiqi")

_KIND_SEND = 1  # two-sided emulation: receiver must run tag matching
_KIND_PUT = 2  # one-sided put: straight to the put-target completion

# Per-slot state byte (the signal word lives IN the shared slab):
_ST_FREE = 0
_ST_WRITTEN = 1  # committed; announced through the descriptor ring
_ST_SIG = 2  # committed; the raised signal, discovered by scanning


class _LiveCount:
    """Process-wide census of open shmem slabs — the fleet lifecycle leak
    regression (ISSUE 7) asserts this stays flat across create/close
    cycles, so a channel/world that forgets to release its segments fails
    a test instead of silently accreting mappings."""

    def __init__(self) -> None:
        self.n = 0
        self._lock = threading.Lock()

    def inc(self) -> None:
        with self._lock:
            self.n += 1

    def dec(self) -> None:
        with self._lock:
            self.n -= 1


_LIVE = _LiveCount()


def live_segments() -> int:
    """Open (created, not yet released) :class:`ShmemSegment` count."""
    return _LIVE.n


class ShmemSegment:
    """One receiver-owned shared-memory slab, partitioned into slots.

    Layout: ``nslots`` state bytes (the signal words), then ``nslots``
    slots of ``_SLOT_HDR.size + slot_size`` bytes each.  Senders claim a
    free slot (:meth:`alloc` — the slot accounting), write header +
    payload bytes straight into the slab (:meth:`write`), and commit by
    flipping the state byte last; the receiver reads the same bytes back
    out (:meth:`read`) and returns the slot (:meth:`free`).
    """

    def __init__(self, nslots: int, slot_size: int, backing: str = "anon"):
        assert backing in ("anon", "shm"), backing
        self.nslots = nslots
        self.slot_size = slot_size
        self.backing = backing
        self._stride = _SLOT_HDR.size + slot_size
        nbytes = nslots + nslots * self._stride
        self._shm = None
        self._mmap = None
        _LIVE.inc()
        if backing == "shm":
            from multiprocessing import shared_memory

            self._shm = shared_memory.SharedMemory(create=True, size=nbytes)
            self.buf = self._shm.buf
            # GC backstop: a world that never reaches ShmemGroup.close()
            # must not leak a named /dev/shm segment past interpreter exit.
            self._finalizer = weakref.finalize(
                self, _release_segment, self._shm, None, None
            )
        else:
            self._mmap = mmap.mmap(-1, nbytes)  # anonymous shared mapping
            self.buf = memoryview(self._mmap)
            # anonymous mappings leak too (ISSUE 7): fleets create and
            # close worker slabs by the dozen, so release the view and
            # unmap eagerly on close() — with the same GC backstop.
            self._finalizer = weakref.finalize(
                self, _release_segment, None, self._mmap, self.buf
            )
        self._lock = make_lock("ShmemSegment._lock")
        self._free: deque = deque(range(nslots))
        # The completion ring for queue-announced arrivals (put+queue-
        # completion descriptors and two-sided exchanges).
        self._rxq: deque = deque()
        self._rxq_lock = make_lock("ShmemSegment._rxq_lock")
        self._closed = False

    # ------------------------------------------------------- slot accounting
    def alloc(self) -> Optional[int]:
        """Claim one free slot (None = receiver slab exhausted — the
        caller surfaces ``EAGAIN_BUFFER``)."""
        with self._lock:
            note_access("ShmemSegment.slots", id(self))
            return self._free.popleft() if self._free else None

    def free_slots(self) -> int:
        with self._lock:
            return len(self._free)

    # ----------------------------------------------------------- data plane
    def write(self, idx: int, kind: int, src_rank: int, src_dev: int, tag: int, data: bytes) -> None:
        """The sender's one-sided store: header + payload bytes into the
        slab.  The slot is invisible to the receiver until committed."""
        off = self.nslots + idx * self._stride
        _SLOT_HDR.pack_into(self.buf, off, kind, src_rank, src_dev, tag, len(data))
        start = off + _SLOT_HDR.size
        self.buf[start : start + len(data)] = data

    def commit(self, idx: int, state: int) -> None:
        """Flip the slot's state byte LAST — the signal that makes the
        written bytes visible (``_ST_SIG``: discovered by scanning;
        ``_ST_WRITTEN``: announced through the descriptor ring)."""
        with self._lock:
            note_access("ShmemSegment.slots", id(self))
            self.buf[idx] = state

    def announce(self, idx: int) -> None:
        """Enqueue a completion descriptor into the receiver's ring (the
        put+queue-completion notification; also used by two-sided
        exchanges)."""
        with self._rxq_lock:
            note_access("ShmemSegment.rxq", id(self))
            self._rxq.append(idx)

    def pop_announced(self) -> Optional[int]:
        with self._rxq_lock:
            note_access("ShmemSegment.rxq", id(self))
            return self._rxq.popleft() if self._rxq else None

    def claim_signals(self, max_n: int) -> List[int]:
        """Scan the signal words for raised flags (put-signal discovery):
        a serialized sweep over the state array, claiming up to ``max_n``
        signalled slots."""
        out: List[int] = []
        with self._lock:
            note_access("ShmemSegment.slots", id(self))
            for idx in range(self.nslots):
                if self.buf[idx] == _ST_SIG:
                    self.buf[idx] = _ST_WRITTEN  # claimed, pending read
                    out.append(idx)
                    if len(out) >= max_n:
                        break
        return out

    def read(self, idx: int) -> Tuple[int, int, int, int, bytes]:
        """Read one committed slot back out of the slab:
        ``(kind, src_rank, src_dev, tag, payload)``."""
        off = self.nslots + idx * self._stride
        kind, src_rank, src_dev, tag, length = _SLOT_HDR.unpack_from(self.buf, off)
        start = off + _SLOT_HDR.size
        return kind, src_rank, src_dev, tag, bytes(self.buf[start : start + length])

    def free(self, idx: int) -> None:
        """Return a consumed slot to the receiver-owned pool."""
        with self._lock:
            note_access("ShmemSegment.slots", id(self))
            self.buf[idx] = _ST_FREE
            self._free.append(idx)

    def pending(self) -> bool:
        """Committed-but-unconsumed slots (announced or signalled)."""
        with self._rxq_lock:
            if self._rxq:
                return True
        with self._lock:
            return any(self.buf[i] != _ST_FREE for i in range(self.nslots))

    # -------------------------------------------------------------- teardown
    def close(self) -> None:
        """Release the slab (idempotent): named segments close + unlink,
        anonymous mappings release their exported view and unmap.  Either
        way the segment leaves the :func:`live_segments` census."""
        if self._closed:
            return
        self._closed = True
        self._finalizer()  # weakref.finalize is call-once: safe + idempotent


def _release_segment(shm: Any, mm: Any, view: Any) -> None:
    """Static teardown (no ref to the segment — runs from GC finalizers)."""
    _LIVE.dec()
    if view is not None:
        try:
            view.release()
        except BufferError:  # pragma: no cover - exported sub-views alive
            pass
    if mm is not None:
        try:
            mm.close()
        except (BufferError, ValueError):  # pragma: no cover
            pass
    if shm is not None:
        try:
            shm.close()
        except BufferError:  # pragma: no cover - exported views still alive
            return
        try:
            shm.unlink()
        except FileNotFoundError:  # pragma: no cover - already unlinked
            pass


class ShmemGroup:
    """The shared-memory transport: one receiver-owned segment per
    ``(rank, device)`` endpoint.

    ``completion_mode`` selects how remote put completions are announced —
    ``'signal'`` (raised per-slot flags, scanned) or ``'queue'``
    (descriptors into the receiver's completion ring); slot and ring
    bounds come from ONE shared :class:`ResourceLimits` (the same object
    the fabric and the DES consume), and stats use the fabric's
    :class:`~repro.core.fabric.FabricStats` shape so benchmark code reads
    any transport through one accessor."""

    def __init__(
        self,
        n_ranks: int,
        devices_per_rank: int = 1,
        limits: Optional[ResourceLimits] = None,
        completion_mode: str = "queue",
        backing: str = "anon",
    ):
        assert completion_mode in ("signal", "queue"), completion_mode
        from ..fabric import FabricStats  # stats shape shared with the fabric

        self.n_ranks = n_ranks
        self.devices_per_rank = max(1, devices_per_rank)
        self.limits = limits or ResourceLimits()
        self.completion_mode = completion_mode
        self.backing = backing
        self.nslots = self.limits.recv_slots or DEFAULT_SLOTS
        self.slot_size = self.limits.bounce_buffer_size
        self.stats = FabricStats()
        self._stats_lock = make_lock("ShmemGroup._stats_lock")
        self.segments: Dict[Tuple[int, int], ShmemSegment] = {}
        self._endpoints: Dict[Tuple[int, int], ShmemComm] = {}
        for r in range(n_ranks):
            for d in range(self.devices_per_rank):
                self.segments[(r, d)] = ShmemSegment(self.nslots, self.slot_size, backing=backing)
                self._endpoints[(r, d)] = ShmemComm(self, r, d)

    def endpoint(self, rank: int, dev: int = 0) -> "ShmemComm":
        return self._endpoints[(rank, dev)]

    def close(self) -> None:
        """Release every segment (idempotent).  Worlds that skip this are
        covered by the per-segment GC finalizer."""
        for seg in self.segments.values():
            seg.close()


def shmem_group_for(
    fabric: Any,
    devices_per_rank: int = 1,
    completion_mode: str = "queue",
    backing: str = "anon",
) -> ShmemGroup:
    """The one :class:`ShmemGroup` of a world, keyed on its fabric — every
    locality's parcelport joins the same group, drawing bounds from
    ``fabric.limits`` (the shared resource model), exactly like
    :func:`~repro.core.comm.collective.collective_group_for`."""
    group = getattr(fabric, "_shmem_group", None)
    if group is None:
        group = ShmemGroup(
            fabric.n_ranks,
            devices_per_rank=devices_per_rank,
            limits=fabric.limits,
            completion_mode=completion_mode,
            backing=backing,
        )
        fabric._shmem_group = group
    else:
        assert group.completion_mode == completion_mode, (
            f"one world, one completion mode: group is "
            f"{group.completion_mode!r}, requested {completion_mode!r}"
        )
    return group


class _Transit:
    """One posted-but-not-yet-exchanged two-sided message."""

    __slots__ = ("dst_rank", "dst_dev", "tag", "data", "comp", "ctx", "eager", "bounce")

    def __init__(self, dst_rank, dst_dev, tag, data, comp, ctx, eager, bounce):
        self.dst_rank = dst_rank
        self.dst_dev = dst_dev
        self.tag = tag
        self.data = data
        self.comp = comp
        self.ctx = ctx
        self.eager = eager
        self.bounce = bounce


class _Record:
    """Same duck type as :class:`repro.core.device.CompletionRecord`, so
    the parcelport's dispatch-by-kind works unchanged across backends."""

    __slots__ = ("op", "tag", "src_rank", "src_dev", "data", "ctx")

    def __init__(self, op, tag=-1, src_rank=-1, src_dev=-1, data=None, ctx=None):
        self.op = op
        self.tag = tag
        self.src_rank = src_rank
        self.src_dev = src_dev
        self.data = data
        self.ctx = ctx


class _PostedRecv:
    __slots__ = ("comp", "ctx")

    def __init__(self, comp: Any, ctx: Any):
        self.comp = comp
        self.ctx = ctx


class ShmemComm:
    """One shared-memory endpoint — a full five-verb
    :class:`~repro.core.comm.interface.CommInterface` backend, and the
    repo's only transport that GENUINELY implements ``post_put_signal``.

    A two-sided send claims a transit-ring slot (``EAGAIN_QUEUE`` under
    ``limits.send_queue_depth``) plus, for eager messages, one unit of the
    bounce accounting (``EAGAIN_BUFFER``), and is exchanged into a remote
    slot by this endpoint's own :meth:`progress`.  A put bypasses all of
    that machinery: ``post_put_signal`` claims a **remote** receiver-owned
    slot at post time (``EAGAIN_BUFFER`` when the slab is exhausted —
    slot accounting from the shared limits), writes the payload bytes
    straight into the shared slab, and commits per the group's completion
    mode (raised signal, or a descriptor into the receiver's ring).  The
    local injection completion is delivered by the next :meth:`progress`
    call — completion delivery stays an engine-driven event."""

    def __init__(self, group: ShmemGroup, rank: int, dev_index: int):
        self.group = group
        self.rank = rank
        self.dev_index = dev_index
        self.segment = group.segments[(rank, dev_index)]  # this endpoint's RX slab
        #: completion object remote puts land in (the dynamic-put target);
        #: registered by the client (parcelport / channel) — the capability
        #: is advertised only once a target exists, like the LCI device.
        self.put_target_comp: Any = None
        self._send_lock = make_lock("ShmemComm._send_lock")
        self._outbox: deque = deque()  # two-sided transit ring
        self._inflight = 0  # occupied ring slots (sends AND puts)
        self._bounce_free = group.limits.bounce_buffers
        self._put_done: deque = deque()  # (comp, ctx) pending local put completions
        self._match_lock = make_lock("ShmemComm._match_lock")
        self._posted: Dict[Tuple[int, int], deque] = {}  # (src, tag)
        self._posted_any: Dict[int, deque] = {}  # tag (any-source)
        self._unexpected: Dict[Tuple[int, int], deque] = {}
        self.progress_calls = 0

    @property
    def capabilities(self) -> Capabilities:
        """Honest capabilities: one-sided put is real here — advertised
        once a put-target completion object is registered (the selection
        surface the parcelport consults, §2.3)."""
        return Capabilities(
            one_sided_put=self.put_target_comp is not None,
            queue_completion=True,
            explicit_progress=True,
            bounded_injection=self.group.limits.bounded,
        )

    def eager_capacity(self) -> Optional[int]:
        """Largest eager message this endpoint can inject (None = no
        bounce accounting = unlimited) — same contract as the LCI device
        and the collective endpoint, so protocol decisions cannot drift."""
        lim = self.group.limits
        return lim.bounce_buffer_size if lim.bounce_buffers > 0 else None

    def _check_fits(self, data: bytes) -> None:
        if len(data) > self.group.slot_size:
            raise ValueError(
                f"message of {len(data)} B exceeds the receiver-owned slot "
                f"capacity ({self.group.slot_size} B, limits.bounce_buffer_size)"
            )

    # ------------------------------------------------------------------ posts
    def post_send(
        self, dst_rank: int, dst_dev: int, tag: int, data: bytes,
        comp: CompletionTarget, ctx: Any = None, eager: bool = False,
    ) -> PostStatus:
        """Two-sided emulation rung: nonblocking tagged send, exchanged
        into a remote slot at progress time; typed EAGAIN on a full
        transit ring or an exhausted eager bounce accounting."""
        self._check_fits(data)
        lim = self.group.limits
        size = len(data) + FRAME_OVERHEAD
        with self._send_lock:
            note_access("ShmemComm.send_ring", id(self))
            if lim.send_queue_depth and self._inflight >= lim.send_queue_depth:
                with self.group._stats_lock:
                    self.group.stats.backpressure_events += 1
                return PostStatus.EAGAIN_QUEUE
            bounce = False
            if eager and lim.bounce_buffers > 0:
                if self._bounce_free <= 0 or size > lim.bounce_buffer_size:
                    with self.group._stats_lock:
                        self.group.stats.backpressure_events += 1
                    return PostStatus.EAGAIN_BUFFER
                self._bounce_free -= 1
                bounce = True
            self._inflight += 1
            self._outbox.append(
                _Transit(dst_rank, dst_dev, tag, bytes(data), comp, ctx, eager, bounce)
            )
        return PostStatus.OK

    def post_recv(self, src_rank: int, tag: int, comp: CompletionTarget, ctx: Any = None) -> None:
        """Pre-post a tagged receive (``src_rank`` may be -1 = any
        source).  Unexpected-message delivery happens OUTSIDE the matching
        lock (``signal`` may legally post another receive)."""
        pr = _PostedRecv(comp, ctx)
        matched = None
        with self._match_lock:
            if src_rank >= 0:
                uq = self._unexpected.get((src_rank, tag))
                if uq:
                    matched = uq.popleft()
            else:
                for (s, t), uq in self._unexpected.items():
                    if t == tag and uq:
                        matched = uq.popleft()
                        break
            if matched is None:
                if src_rank >= 0:
                    self._posted.setdefault((src_rank, tag), deque()).append(pr)
                else:
                    self._posted_any.setdefault(tag, deque()).append(pr)
        if matched is not None:
            src, data = matched
            self._deliver_recv(pr, src, tag, data)

    def post_put_signal(
        self, dst_rank: int, dst_dev: int, data: bytes,
        comp: CompletionTarget, ctx: Any = None, eager: bool = False,
    ) -> PostStatus:
        """THE genuine one-sided put (§3.3.1): claim a receiver-owned slot
        in the destination's shared slab, store header + payload bytes
        directly into it, and commit per the group's completion mode —
        raise the per-slot signal (``shmem_put``) or enqueue a completion
        descriptor into the receiver's ring (``shmem_putq``).  No tag, no
        matching, no posted receive.  ``EAGAIN_QUEUE`` on a full local
        injection ring; ``EAGAIN_BUFFER`` when the remote slab has no free
        slot (the receiver-owned slot accounting, shared limits)."""
        if self.put_target_comp is None:
            raise UnsupportedCapabilityError(
                "one-sided put needs a registered put-target completion "
                "object (capabilities.one_sided_put=False on this endpoint)"
            )
        self._check_fits(data)
        lim = self.group.limits
        with self._send_lock:
            note_access("ShmemComm.send_ring", id(self))
            if lim.send_queue_depth and self._inflight >= lim.send_queue_depth:
                with self.group._stats_lock:
                    self.group.stats.backpressure_events += 1
                return PostStatus.EAGAIN_QUEUE
            seg = self.group.segments[(dst_rank, dst_dev)]
            idx = seg.alloc()
            if idx is None:
                with self.group._stats_lock:
                    self.group.stats.backpressure_events += 1
                return PostStatus.EAGAIN_BUFFER
            self._inflight += 1
            # the one-sided store: bytes land in the receiver's slab NOW
            seg.write(idx, _KIND_PUT, self.rank, self.dev_index, -1, bytes(data))
            if self.group.completion_mode == "signal":
                seg.commit(idx, _ST_SIG)  # raise the signal flag
            else:
                seg.commit(idx, _ST_WRITTEN)
                seg.announce(idx)  # descriptor into the receiver's CQ ring
            self._put_done.append((comp, ctx))
        with self.group._stats_lock:
            st = self.group.stats
            st.puts += 1
            st.messages += 1
            st.bytes += len(data)  # puts add no frame overhead
            if eager:
                st.eager_msgs += 1
            else:
                st.rendezvous_msgs += 1
        return PostStatus.OK

    # --------------------------------------------------------------- progress
    def progress(self, max_completions: int = 16) -> bool:
        """Explicitly drive the transport: deliver pending local put
        completions (freeing their ring slots), exchange posted two-sided
        messages into remote slots, then consume this endpoint's own slab —
        descriptor-ring arrivals first (put+queue-completion and two-sided
        exchanges), then a scan of the raised signal flags (put-signal)."""
        self.progress_calls += 1
        moved = False
        # 1. local injection completions for puts already stored remotely
        for _ in range(max_completions):
            with self._send_lock:
                note_access("ShmemComm.send_ring", id(self))
                if not self._put_done:
                    break
                comp, ctx = self._put_done.popleft()
                self._inflight -= 1
            complete(comp, _Record(op="send", ctx=ctx))
            moved = True
        # 2. exchange two-sided transits (flow-controlled by remote slots)
        for _ in range(max_completions):
            with self._send_lock:
                note_access("ShmemComm.send_ring", id(self))
                if not self._outbox:
                    break
                t = self._outbox[0]
                seg = self.group.segments[(t.dst_rank, t.dst_dev)]
                idx = seg.alloc()
                if idx is None:
                    break  # remote slab full: keep FIFO order, retry later
                self._outbox.popleft()
                seg.write(idx, _KIND_SEND, self.rank, self.dev_index, t.tag, t.data)
                seg.commit(idx, _ST_WRITTEN)
                seg.announce(idx)
                self._inflight -= 1
                if t.bounce:
                    self._bounce_free += 1
            with self.group._stats_lock:
                st = self.group.stats
                st.messages += 1
                st.sends += 1
                st.bytes += len(t.data) + FRAME_OVERHEAD
                if t.eager:
                    st.eager_msgs += 1
                else:
                    st.rendezvous_msgs += 1
            complete(t.comp, _Record(op="send", tag=t.tag, ctx=t.ctx))
            moved = True
        # 3. descriptor-ring arrivals (putq completions + two-sided sends)
        for _ in range(max_completions):
            idx = self.segment.pop_announced()
            if idx is None:
                break
            kind, src, src_dev, tag, payload = self.segment.read(idx)
            self.segment.free(idx)
            if kind == _KIND_PUT:
                self._complete_put(src, src_dev, payload)
            else:
                self._match_incoming(src, tag, payload)
            moved = True
        # 4. raised signals (put-signal mode): the serialized scan
        if self.group.completion_mode == "signal":
            for idx in self.segment.claim_signals(max_completions):
                _kind, src, src_dev, _tag, payload = self.segment.read(idx)
                self.segment.free(idx)
                self._complete_put(src, src_dev, payload)
                moved = True
        return moved

    def poll(self, max_completions: int = 16) -> bool:
        """Completion-test-driven progress — the implicit entry point; at
        this layer it shares :meth:`progress`'s implementation, as in the
        LCI device and the collective endpoint."""
        return self.progress(max_completions)

    def pending_transport(self) -> bool:
        """Anything still moving through this endpoint: unexchanged
        transits, undelivered put completions, or unconsumed slots."""
        with self._send_lock:
            note_access("ShmemComm.send_ring", id(self))
            if self._outbox or self._put_done:
                return True
        return self.segment.pending()

    # --------------------------------------------------------------- matching
    def _complete_put(self, src: int, src_dev: int, payload: bytes) -> None:
        if self.put_target_comp is None:
            raise RuntimeError("one-sided put received but no target completion object")
        complete(
            self.put_target_comp,
            _Record(op="put_recv", src_rank=src, src_dev=src_dev, data=payload),
        )

    def _match_incoming(self, src: int, tag: int, payload: bytes) -> None:
        with self._match_lock:
            q = self._posted.get((src, tag))
            if q:
                pr = q.popleft()
            else:
                qa = self._posted_any.get(tag)
                if qa:
                    pr = qa.popleft()
                else:
                    self._unexpected.setdefault((src, tag), deque()).append((src, payload))
                    return
        self._deliver_recv(pr, src, tag, payload)

    def _deliver_recv(self, pr: _PostedRecv, src: int, tag: int, data: bytes) -> None:
        complete(pr.comp, _Record(op="recv", tag=tag, src_rank=src, data=data, ctx=pr.ctx))


from ..lci_parcelport import LCIParcelport  # noqa: E402  (no cycle: the
# lci parcelport imports comm.progress/resources only, never this module)


class ShmemParcelport(LCIParcelport):
    """The LCI parcelport's protocol logic over shared-memory endpoints.

    Defined by *difference*: only device creation changes — the group's
    completion mode comes from ``header_comp`` (``'sync'`` → raised-signal
    discovery, ``'queue'`` → descriptor-ring completion), and each
    endpoint's put target is registered against the parcelport's
    completion queue, which is what makes ``capabilities.one_sided_put``
    honest.  With ``header_mode='put'`` the inherited capability-driven
    selection rides the REAL one-sided path; with ``'sendrecv'`` the same
    endpoints run the two-sided emulation rung — the full capability
    ladder from one protocol engine (§2.3)."""

    def _make_devices(self, fabric: Any, config: Any) -> List[ShmemComm]:
        group = shmem_group_for(
            fabric,
            devices_per_rank=config.ndevices,
            completion_mode="signal" if config.header_comp == "sync" else "queue",
        )
        endpoints = [group.endpoint(self.locality.rank, d) for d in range(config.ndevices)]
        for d, ep in enumerate(endpoints):
            ep.put_target_comp = self._cq_for(d)
        return endpoints
