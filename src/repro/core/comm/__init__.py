"""repro.core.comm — the first-class communication-interface layer.

The paper's conceptual contribution, made explicit (§2.3, §3.3; companion
proposal arXiv 2503.15400):

* :mod:`.interface` — the unified :class:`CommInterface` contract
  (``post_send / post_recv / post_put_signal / progress / poll``),
  :class:`PostStatus` backpressure results, :class:`Capabilities`
  descriptors, and the :class:`CompletionTarget` completion surface.
* :mod:`.resources` — :class:`ResourceLimits`, the single shared model of
  finite communication resources consumed by the fabric, the parcelports,
  AND the DES simulator.
* :mod:`.base` — :class:`ParcelportBase`: aggregation + backpressure
  retry/throttle machinery shared by every parcelport.
* :mod:`.registry` — the composable variant registry (fixed names +
  parameterized families such as ``lci_b{depth}``); imported lazily to
  keep this package a leaf for the modules below it.
"""
from .base import (
    InjectionThrottle,
    ParcelportBase,
    aggregate_parcels,
    aggregate_projected_bytes,
    is_aggregate,
    split_aggregate,
)
from .interface import (
    Capabilities,
    CommInterface,
    CompletionTarget,
    PostStatus,
    UnsupportedCapabilityError,
    complete,
)
from .resources import ResourceLimits

__all__ = [
    "Capabilities",
    "CommInterface",
    "CompletionTarget",
    "ParcelportBase",
    "PostStatus",
    "ResourceLimits",
    "UnsupportedCapabilityError",
    "VariantRegistry",
    "VariantSpec",
    "RegistryView",
    "UnknownVariantError",
    "CollectiveComm",
    "CollectiveGroup",
    "CollectiveParcelport",
    "CommChannel",
    "InjectionThrottle",
    "aggregate_parcels",
    "aggregate_projected_bytes",
    "complete",
    "is_aggregate",
    "split_aggregate",
]

_REGISTRY_NAMES = {"VariantRegistry", "VariantSpec", "RegistryView", "UnknownVariantError"}
_COLLECTIVE_NAMES = {"CollectiveComm", "CollectiveGroup", "CollectiveParcelport", "CommChannel"}


def __getattr__(name: str):
    # Lazy: registry is pure machinery, and the collective backend imports
    # the parcelport layer above this package — importing either eagerly
    # would make every `from .comm.base import ...` in lower layers pay
    # for it (or cycle).
    if name in _REGISTRY_NAMES:
        from . import registry

        return getattr(registry, name)
    if name in _COLLECTIVE_NAMES:
        from . import collective

        return getattr(collective, name)
    raise AttributeError(name)
