"""The communication interface the paper argues AMTs need (§2.3, §3.3).

The companion proposal (*Contemplating a Lightweight Communication Interface
for Asynchronous Many-Task Systems*, arXiv 2503.15400) turns the paper's
analysis into an explicit contract.  This module is that contract for the
reproduction: every communication backend — the LCI-style device
(:mod:`repro.core.device`) and the MPI emulation (:mod:`repro.core.mpi_sim`)
— speaks the same five-verb surface, and the parcelports above select
protocol paths by *capability*, not by ``isinstance`` checks on the backend.

The surface:

* ``post_send(dst_rank, dst_dev, tag, data, comp)`` — nonblocking tagged
  two-sided send; completes into ``comp``.
* ``post_recv(src_rank, tag, comp)`` — pre-post a tagged receive
  (``src_rank`` may be -1 = any source).
* ``post_put_signal(dst_rank, dst_dev, data, comp)`` — one-sided put whose
  *remote* completion signals the target's dynamic-put completion object
  (LCI's ideal primitive, §3.3.1).  Backends without the capability raise
  :class:`UnsupportedCapabilityError`.
* ``progress()`` — explicitly drive the backend's progress engine (§3.3.4).
* ``poll()`` — completion-test-driven progress: the *implicit* entry point
  (all the progress an MPI-like backend ever gets).

Every post returns a :class:`PostStatus`, making injection backpressure a
first-class part of the interface instead of a boolean side channel:
``OK`` truthy, the two ``EAGAIN_*`` refusals falsy (so legacy
``if not post(...)`` call sites keep working) and distinguishable — a full
descriptor ring and an exhausted bounce pool are different resources with
different remedies (§3.3.4).

Completion delivery is unified by :class:`CompletionTarget`: completion
queues, synchronizers, and synchronizer pools all expose
``signal(item)`` / ``reap() -> item | None`` (see
:mod:`repro.core.completion`), so a backend never needs to know which kind
of completion object its client chose (§3.3.2 / §5.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import Enum
from typing import Any, Optional, Protocol, runtime_checkable

__all__ = [
    "PostStatus",
    "Capabilities",
    "CompletionTarget",
    "CommInterface",
    "UnsupportedCapabilityError",
    "complete",
]


class UnsupportedCapabilityError(RuntimeError):
    """A protocol path was requested that the backend's
    :class:`Capabilities` does not advertise (e.g. a one-sided put on the
    MPI backend).  Parcelports avoid this by consulting ``capabilities``
    before selecting a path."""


class PostStatus(Enum):
    """Result of a nonblocking post (§3.3.4 resource boundedness).

    Truthiness follows success, so ``if not comm.post_send(...)`` reads the
    same as the historical boolean API while the enum distinguishes *which*
    finite resource refused the post."""

    OK = "ok"
    EAGAIN_QUEUE = "eagain_queue"  # descriptor ring (send queue) full
    EAGAIN_BUFFER = "eagain_buffer"  # registered bounce-buffer pool exhausted
    # the target rank is DRAINING or GONE under the membership layer
    # (core/comm/membership.py): the post must be re-queued by the caller,
    # never silently dropped — a lifecycle refusal, not a resource one
    EAGAIN_DRAINING = "eagain_draining"

    def __bool__(self) -> bool:
        return self is PostStatus.OK

    @property
    def ok(self) -> bool:
        return self is PostStatus.OK


@dataclass(frozen=True)
class Capabilities:
    """What a communication backend can do — the selection surface.

    Parcelports branch on these flags instead of on the backend's concrete
    type, which is exactly the "communication abstraction" boundary the
    paper formalizes (§2.3): the same parcelport logic drives any backend
    that advertises the needed capability.
    """

    #: one-sided put with remote-completion signal (LCI dynamic put, §3.3.1)
    one_sided_put: bool = False
    #: completions may land in shared MPMC completion queues (§3.3.2);
    #: without it the client is limited to per-operation requests (MPI)
    queue_completion: bool = False
    #: the client may invoke the progress engine directly (§3.3.4);
    #: without it progress only happens inside completion tests
    explicit_progress: bool = False
    #: posts surface EAGAIN to the caller instead of buffering internally —
    #: the client can throttle; MPI hides refusals inside the library
    bounded_injection: bool = False


@runtime_checkable
class CompletionTarget(Protocol):
    """One surface over completion queues, synchronizers, and pools.

    ``signal`` is the producer side (the backend reporting a completed
    operation); ``reap`` is the consumer side (the parcelport collecting
    one completed item, or ``None``).  :mod:`repro.core.completion` makes
    every existing completion class conform.
    """

    def signal(self, item: Any) -> None: ...

    def reap(self) -> Optional[Any]: ...


@runtime_checkable
class CommInterface(Protocol):
    """The unified communication interface (see module docstring)."""

    @property
    def capabilities(self) -> Capabilities: ...

    def post_send(
        self, dst_rank: int, dst_dev: int, tag: int, data: bytes,
        comp: CompletionTarget, ctx: Any = None, eager: bool = False,
    ) -> PostStatus: ...

    def post_recv(
        self, src_rank: int, tag: int, comp: CompletionTarget, ctx: Any = None
    ) -> None: ...

    def post_put_signal(
        self, dst_rank: int, dst_dev: int, data: bytes,
        comp: CompletionTarget, ctx: Any = None, eager: bool = False,
    ) -> PostStatus: ...

    def progress(self, max_completions: int = 16) -> bool: ...

    def poll(self, max_completions: int = 16) -> bool: ...


def complete(target: Any, item: Any) -> None:
    """Signal a completion into any target.

    Prefers the unified ``signal`` surface; falls back to ``push`` for
    duck-typed legacy objects that predate :class:`CompletionTarget`."""
    signal = getattr(target, "signal", None)
    if signal is not None:
        signal(item)
    else:
        target.push(item)
