"""Worker lifecycle as a first-class subsystem (ISSUE 8 tentpole).

The paper's §5.3 progress-contention study shows the *right* number of
dedicated progress workers is workload-dependent, and the companion
proposal (arXiv 2503.15400) argues the communication layer must expose
explicit progress and completion-latency signals precisely so the runtime
above it can adapt resource counts at run time.  Before this module, every
resource count in the repo was frozen at config time and every layer
managed its own worker threads ad hoc (the ``lci_prg{n}`` pool inside the
parcelport, the executor's pool inside the executor, fleet workers inside
the router).  This module makes lifecycle ONE subsystem, above
``World``/``ShmemGroup``/``CollectiveGroup`` and below the consumers:

* :class:`Membership` — typed member lifecycle
  ``JOINING → ACTIVE → DRAINING → GONE`` with **epoch-stamped views**:
  a racing post to a departing rank resolves to the typed
  :data:`~repro.core.comm.interface.PostStatus.EAGAIN_DRAINING` (the
  caller re-queues — never loss), and a completion dispatched under a
  stale epoch is discarded exactly once, counted.  A member that dies
  without ``leave()`` is reaped by a **finalizer-based liveness sweep**
  (:meth:`Membership.sweep`), so its slots return to the pool.
* :func:`spawn_worker` / :func:`join_workers` — the ONLY place in the
  repo that may start or join progress/fleet worker threads (gate 7 in
  tools/check_api.py): a census of live spawned workers backs the
  leak regressions.
* :class:`ProgressWorkerPool` — the dedicated-progress threads of the
  ``lci_prg{n}`` family as a resizable pool: ``resize()`` spawns or
  stops-and-JOINS real threads (extending the PR 5 leak fix to every
  resize, not only close).
* :class:`ElasticProgressController` — grows/shrinks a pool between
  configured bounds from :meth:`ProgressEngine.reap_latency_stats`
  (completion backlog per sweep), with hysteresis + cooldown so a noisy
  signal cannot thrash the pool (the ``lci_eprg{lo}_{hi}`` family; the
  DES twin charges calibrated join/drain costs in
  :mod:`repro.amtsim.parcelport_sim`).
"""
from __future__ import annotations

import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from collections import deque

from ...analysis.sanitizer import make_lock, note_access
from .interface import PostStatus

__all__ = [
    "JOINING",
    "ACTIVE",
    "DRAINING",
    "GONE",
    "Member",
    "MembershipView",
    "Membership",
    "ProgressWorkerPool",
    "ElasticProgressController",
    "spawn_worker",
    "join_workers",
    "live_worker_count",
]

# -- member states (the typed lifecycle; transitions only move rightward
#    until GONE, after which the rank may re-join at a fresh epoch) ----------
JOINING = "joining"  # registered; endpoints wiring up, not yet routable
ACTIVE = "active"  # routable: posts and routing shares flow to it
DRAINING = "draining"  # stopped admitting; quiescing in-flight work
GONE = "gone"  # deregistered; the rank's slots are back in the pool

_NEXT = {JOINING: (ACTIVE, DRAINING, GONE), ACTIVE: (DRAINING, GONE), DRAINING: (GONE,), GONE: ()}


# ---------------------------------------------------------------- thread own
# The one thread-spawn surface for progress/fleet workers (gate 7): every
# worker thread in the repo is created and joined here, so the census below
# is exact and leak regressions have one place to look.
_spawned: "weakref.WeakSet[threading.Thread]" = weakref.WeakSet()


def spawn_worker(
    target: Callable[..., None],
    *,
    name: str,
    args: Tuple[Any, ...] = (),
    daemon: bool = True,
) -> threading.Thread:
    """Start one worker thread.  The ONLY sanctioned spawn point for
    progress/fleet/executor worker threads (tools/check_api.py gate 7)."""
    t = threading.Thread(target=target, args=args, name=name, daemon=daemon)
    _spawned.add(t)
    t.start()
    return t


def join_workers(threads: List[threading.Thread], timeout: float = 5.0) -> None:
    """Join each thread with a bounded per-thread timeout (a wedged worker
    must not hang teardown — the daemon flag is the backstop)."""
    for t in threads:
        t.join(timeout=timeout)


def live_worker_count() -> int:
    """Census of live worker threads spawned through :func:`spawn_worker`
    (the lifecycle-leak regression counter)."""
    return sum(1 for t in _spawned if t.is_alive())


# ------------------------------------------------------------------ members
@dataclass
class Member:
    """One tracked worker: rank, typed state, and the epoch of its last
    transition (completions stamped with an older epoch are stale)."""

    rank: int
    state: str = JOINING
    epoch: int = 0
    meta: Dict[str, Any] = field(default_factory=dict)
    #: run once when the member reaches GONE (leave *or* abandon-sweep) —
    #: the hook that returns its slots/segments to the owning pools
    on_gone: Optional[Callable[["Member"], None]] = None
    _finalizer: Any = None


class MembershipView:
    """An immutable epoch-stamped snapshot of the membership.

    Routing decisions take a view, post guards re-check against the live
    table: a post raced against a leave resolves to EAGAIN_DRAINING, and a
    completion dispatched under this view's epoch is discarded if the
    member has since transitioned (exactly once, counted)."""

    __slots__ = ("epoch", "_states")

    def __init__(self, epoch: int, states: Dict[int, str]):
        self.epoch = epoch
        self._states = dict(states)

    def state(self, rank: int) -> Optional[str]:
        return self._states.get(rank)

    @property
    def active(self) -> Tuple[int, ...]:
        return tuple(sorted(r for r, s in self._states.items() if s == ACTIVE))

    def __contains__(self, rank: int) -> bool:
        return self._states.get(rank) == ACTIVE


class Membership:
    """The lifecycle table: typed states, epochs, events, liveness sweep.

    Consumers (the fleet router, the parcelport pools) own the *mechanics*
    of joining and draining; this table owns the *truth* about who is
    routable, which posts must be refused, and which completions are
    stale.  All transitions are serialized under one lock — lifecycle is
    rare relative to data movement, so a plain mutex is the right tool."""

    def __init__(self) -> None:
        self._lock = make_lock("Membership._lock")
        self._members: Dict[int, Member] = {}
        self._epoch = 0
        #: ranks reaped by the finalizer backstop, awaiting sweep()
        self._abandoned: List[int] = []
        #: lifecycle event log for consumers: (kind, rank, epoch)
        self.events: Deque[Tuple[str, int, int]] = deque()
        #: completions discarded for arriving under a stale epoch
        self.stale_discards = 0

    # -- transitions ---------------------------------------------------------
    def _bump(self, member: Member, state: str, kind: str) -> None:
        # all transitions come through here, under self._lock
        note_access("Membership._members", id(self))
        self._epoch += 1
        member.state = state
        member.epoch = self._epoch
        self.events.append((kind, member.rank, self._epoch))

    def join(
        self,
        rank: int,
        owner: Any = None,
        on_gone: Optional[Callable[[Member], None]] = None,
        **meta: Any,
    ) -> Member:
        """Register a member (state JOINING).  A GONE rank may re-join at a
        fresh epoch — that is how a departed worker's slot is reused.

        ``owner``: the object whose lifetime stands for the worker's; if it
        is garbage-collected without ``leave()``, the finalizer backstop
        marks the rank abandoned and the next :meth:`sweep` reaps it."""
        with self._lock:
            prev = self._members.get(rank)
            if prev is not None and prev.state != GONE:
                raise ValueError(f"rank {rank} already a member (state {prev.state})")
            member = Member(rank=rank, meta=dict(meta), on_gone=on_gone)
            self._bump(member, JOINING, "join")
            self._members[rank] = member
            if owner is not None:
                member._finalizer = weakref.finalize(owner, self._note_abandoned, rank, self._epoch)
            return member

    def activate(self, rank: int) -> None:
        """JOINING → ACTIVE: endpoints wired, landing queues bound — the
        rank becomes routable."""
        with self._lock:
            member = self._members[rank]
            if member.state != JOINING:
                raise ValueError(f"rank {rank}: activate from {member.state}")
            self._bump(member, ACTIVE, "active")

    def begin_drain(self, rank: int) -> bool:
        """Start leaving: stop admitting, quiesce in-flight work.  Returns
        False (a no-op) if the member is already DRAINING or GONE — a
        double leave() is idempotent by construction."""
        with self._lock:
            member = self._members.get(rank)
            if member is None or member.state in (DRAINING, GONE):
                return False
            self._bump(member, DRAINING, "drain")
            return True

    def finish_leave(self, rank: int) -> bool:
        """DRAINING (or JOINING/ACTIVE on a forced reap) → GONE: run the
        member's ``on_gone`` hook and detach the finalizer.  Idempotent."""
        with self._lock:
            member = self._members.get(rank)
            if member is None or member.state == GONE:
                return False
            self._bump(member, GONE, "gone")
            fin, hook = member._finalizer, member.on_gone
            member._finalizer = None
        if fin is not None:
            fin.detach()
        if hook is not None:
            hook(member)
        return True

    # -- liveness sweep (satellite: death without leave) ---------------------
    def _note_abandoned(self, rank: int, joined_epoch: int) -> None:
        # finalizer context: no lock-ordering hazards — just record the rank
        self._abandoned.append(rank)

    def sweep(self) -> List[int]:
        """Reap members whose owners died without ``leave()``: each is
        forced to GONE (its ``on_gone`` hook returns its slots to the
        pool).  Called from ``World.close()`` / fleet teardown, and safe
        to call any time."""
        with self._lock:
            pending, self._abandoned = self._abandoned, []
        reaped = []
        for rank in pending:
            if self.finish_leave(rank):
                reaped.append(rank)
        return reaped

    # -- queries -------------------------------------------------------------
    @property
    def epoch(self) -> int:
        return self._epoch

    def state(self, rank: int) -> Optional[str]:
        with self._lock:
            note_access("Membership._members", id(self))
            member = self._members.get(rank)
            return member.state if member is not None else None

    def view(self) -> MembershipView:
        """An epoch-stamped immutable snapshot for routing decisions."""
        with self._lock:
            note_access("Membership._members", id(self))
            return MembershipView(self._epoch, {r: m.state for r, m in self._members.items()})

    def active_ranks(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(r for r, m in self._members.items() if m.state == ACTIVE))

    def guard_post(self, rank: int) -> PostStatus:
        """The post-side race arbiter: a post targeting a DRAINING or GONE
        (or unknown) rank is refused with the *typed*
        ``EAGAIN_DRAINING`` — the caller re-queues, exactly like a
        resource EAGAIN, and nothing is ever lost to a leave."""
        with self._lock:
            note_access("Membership._members", id(self))
            member = self._members.get(rank)
            if member is None or member.state in (DRAINING, GONE):
                return PostStatus.EAGAIN_DRAINING
            return PostStatus.OK

    def admit_completion(self, rank: int, view_epoch: int) -> bool:
        """Completion-side race arbiter: a completion dispatched under a
        view older than the member's last transition is stale — discarded
        exactly once (counted), never double-processed."""
        with self._lock:
            note_access("Membership._members", id(self))
            member = self._members.get(rank)
            if member is None or (member.state == GONE and view_epoch < member.epoch):
                self.stale_discards += 1
                return False
            return True

    def drain_events(self) -> List[Tuple[str, int, int]]:
        """Pop and return every pending lifecycle event (consumer side)."""
        out = []
        while self.events:
            out.append(self.events.popleft())
        return out


# -------------------------------------------------------- progress workers
def _progress_worker_loop(pp_ref: "weakref.ref", stop: threading.Event) -> None:
    """Body of one dedicated progress thread (§3.3.4, ``lci_prg{n}``).

    Holds only a weak reference: when the owning parcelport is dropped
    (worlds are short-lived in tests and benchmarks) the thread exits on
    its own, so un-``close()``d worlds never leak spinning threads."""
    idle = 0
    while not stop.is_set():
        pp = pp_ref()
        if pp is None:
            return
        moved = pp.progress_work()
        del pp  # drop the strong ref before sleeping so GC can collect
        if moved:
            idle = 0
        else:
            idle += 1
            time.sleep(min(20e-6 * (1 + idle // 4), 2e-3))


class ProgressWorkerPool:
    """The ``lci_prg{n}`` dedicated-progress threads as a RESIZABLE pool.

    Each thread runs :func:`_progress_worker_loop` against a weakly-held
    endpoint (anything with ``progress_work()``).  ``resize`` spawns new
    threads through :func:`spawn_worker` and stops-and-JOINS surplus ones
    (each thread has its own stop event, so a shrink never disturbs the
    survivors) — the PR 5 leak fix applied to every resize, not only
    close.  Not thread-safe by design: exactly one controller (or the
    owning parcelport) resizes it."""

    def __init__(self, endpoint_ref: "weakref.ref", name_prefix: str):
        self._ref = endpoint_ref
        self._prefix = name_prefix
        self._workers: List[Tuple[threading.Thread, threading.Event]] = []
        self._serial = 0
        self.spawned_total = 0
        self.joined_total = 0

    def size(self) -> int:
        return len(self._workers)

    def resize(self, n: int) -> None:
        n = max(0, n)
        while len(self._workers) < n:
            stop = threading.Event()
            t = spawn_worker(
                _progress_worker_loop,
                args=(self._ref, stop),
                name=f"{self._prefix}.{self._serial}",
            )
            self._serial += 1
            self.spawned_total += 1
            self._workers.append((t, stop))
        if len(self._workers) > n:
            surplus = self._workers[n:]
            del self._workers[n:]
            for _, stop in surplus:
                stop.set()
            join_workers([t for t, _ in surplus])
            self.joined_total += len(surplus)

    def close(self) -> None:
        """Stop AND JOIN every thread.  Idempotent."""
        self.resize(0)


class ElasticProgressController:
    """Grow/shrink a :class:`ProgressWorkerPool` between bounds from the
    engine's reap statistics (the ``lci_eprg{lo}_{hi}`` family).

    The signal is per-sweep completion-queue occupancy
    (``reap_latency_stats()['occupancy_ewma']``): sustained full batches
    mean the reapers are behind (grow); a near-empty EWMA means dedicated
    cores are stealing cycles for nothing (shrink).  Two guards keep a
    noisy signal from thrashing the pool — **hysteresis** (the shrink
    threshold sits well below the grow threshold) and a **cooldown**
    between resizes; ``hysteresis=False`` degenerates both to a single
    threshold with no cooldown (the naive controller the elasticity study
    shows oscillating)."""

    def __init__(
        self,
        engine: Any,
        pool: ProgressWorkerPool,
        lo: int,
        hi: int,
        *,
        grow_at: float = 4.0,
        shrink_at: float = 1.0,
        cooldown: float = 0.002,
        hysteresis: bool = True,
    ):
        if not 0 <= lo <= hi:
            raise ValueError(f"elastic bounds must satisfy 0 <= lo <= hi, got ({lo}, {hi})")
        self.engine = engine
        self.pool = pool
        self.lo, self.hi = lo, hi
        self.grow_at = grow_at
        self.shrink_at = shrink_at if hysteresis else grow_at
        self.cooldown = cooldown if hysteresis else 0.0
        self.hysteresis = hysteresis
        self._last_resize = 0.0
        # one controller decision at a time: background_work may be pumped
        # from many task workers, but the pool is single-resizer
        self._decide = threading.Lock()
        self.grows = 0
        self.shrinks = 0

    @property
    def resizes(self) -> int:
        return self.grows + self.shrinks

    def maybe_resize(self) -> bool:
        """One control decision; returns True if the pool was resized.
        Contended calls bail out (a second concurrent decision would act
        on the same sample anyway)."""
        if not self._decide.acquire(blocking=False):
            return False
        try:
            now = time.monotonic()
            if self.cooldown and now - self._last_resize < self.cooldown:
                return False
            occ = self.engine.reap_latency_stats()["occupancy_ewma"]
            n = self.pool.size()
            if occ >= self.grow_at and n < self.hi:
                self.pool.resize(n + 1)
                self.grows += 1
            elif occ <= self.shrink_at and n > self.lo:
                self.pool.resize(n - 1)
                self.shrinks += 1
            else:
                return False
            self._last_resize = now
            return True
        finally:
            self._decide.release()
