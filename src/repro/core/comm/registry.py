"""Composable variant registry: fixed names + parameterized families.

The paper's evaluated configurations (Figs 3-9) used to live in one
hard-coded dict, which meant every new axis (device counts, eager
thresholds, resource-limit depths) had to be *enumerated* up front.  This
registry composes instead: a :class:`VariantSpec` describes a whole family
with a name grammar (``lci_d{n}``, ``lci_eager_{k}k``, ``lci_b{depth}``)
and a builder, and any member — ``lci_d7``, ``lci_b8`` — resolves on
demand, without pre-registration.  A small set of *canonical* members per
family keeps ``variant_names()`` (and the docs/variant-table gate, the
smoke gate, and benchmark sweeps) finite and stable.

The machinery is config-type-agnostic; :mod:`repro.core.variants` defines
the concrete axes over :class:`~repro.core.lci_parcelport.LCIPPConfig` and
re-exports the registry under the legacy ``VARIANTS`` mapping name.
Resolution is cached, so resolving the same name twice returns the *same*
config object (configs are treated as immutable-by-convention, like the
old dict entries).
"""
from __future__ import annotations

import re
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, Tuple

__all__ = ["VariantSpec", "VariantRegistry", "RegistryView", "UnknownVariantError"]


class UnknownVariantError(KeyError):
    """Name matched neither a fixed variant nor any family grammar."""


@dataclass(frozen=True)
class VariantSpec:
    """One parameterized family of variants.

    * ``grammar`` — the documented name pattern, e.g. ``"lci_b{depth}"``.
      Every ``{placeholder}`` matches a decimal integer; the surrounding
      literal text matches itself.  This exact string also appears in
      docs/VARIANTS.md, where ``tools/check_docs.py`` expands it the same
      way, so the docs and the resolver share one grammar.
    * ``build(name, **params)`` — constructs the config for a resolved
      member; params arrive as ints keyed by placeholder name.
    * ``canonical`` — the parameter tuples enumerated by
      ``VariantRegistry.names()`` (each tuple in grammar order).
    * ``doc`` — one-line description for tooling.
    """

    grammar: str
    build: Callable[..., Any]
    canonical: Tuple[Tuple[int, ...], ...] = ()
    doc: str = ""
    _regex: re.Pattern = field(init=False, repr=False, compare=False)
    _params: Tuple[str, ...] = field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        params: List[str] = []

        def to_group(m: re.Match) -> str:
            params.append(m.group(1))
            return f"(?P<{m.group(1)}>\\d+)"

        pattern = "".join(
            to_group(part) if (part := _PLACEHOLDER.fullmatch(piece)) else re.escape(piece)
            for piece in _PLACEHOLDER_SPLIT.split(self.grammar)
            if piece
        )
        object.__setattr__(self, "_regex", re.compile(pattern))
        object.__setattr__(self, "_params", tuple(params))

    @property
    def regex(self) -> re.Pattern:
        """The compiled name grammar — the single source shared with
        tooling (``tools/check_docs.py`` matches documented family rows
        against exactly this pattern)."""
        return self._regex

    def match(self, name: str) -> Optional[Dict[str, int]]:
        m = self._regex.fullmatch(name)
        if m is None:
            return None
        return {k: int(v) for k, v in m.groupdict().items()}

    def member_name(self, values: Tuple[int, ...]) -> str:
        name = self.grammar
        for param, value in zip(self._params, values):
            name = name.replace("{" + param + "}", str(value))
        return name


_PLACEHOLDER = re.compile(r"\{(\w+)\}")
_PLACEHOLDER_SPLIT = re.compile(r"(\{\w+\})")


class VariantRegistry:
    """Fixed variants + family specs, resolved lazily and cached."""

    def __init__(self) -> None:
        self._fixed: Dict[str, Callable[[], Any]] = {}
        self._families: List[VariantSpec] = []
        self._cache: Dict[str, Any] = {}
        self._lock = threading.Lock()

    # -- registration -------------------------------------------------------
    def register(self, name: str, build: Callable[[], Any]) -> None:
        """Register one fixed variant (lazily built on first resolve)."""
        self._fixed[name] = build

    def register_family(self, spec: VariantSpec) -> VariantSpec:
        self._families.append(spec)
        return spec

    # -- resolution ---------------------------------------------------------
    def resolve(self, name: str) -> Any:
        """Resolve any variant name — fixed or family member — to its
        config.  Cached: the same name always yields the same object."""
        with self._lock:
            cfg = self._cache.get(name)
            if cfg is not None:
                return cfg
            cfg = self._build(name)
            self._cache[name] = cfg
            return cfg

    def _build(self, name: str) -> Any:
        build = self._fixed.get(name)
        if build is not None:
            return build()
        for spec in self._families:
            params = spec.match(name)
            if params is not None:
                return spec.build(name, **params)
        raise UnknownVariantError(name)

    def __contains__(self, name: object) -> bool:
        if not isinstance(name, str):
            return False
        if name in self._fixed:
            return True
        return any(spec.match(name) is not None for spec in self._families)

    # -- enumeration --------------------------------------------------------
    def names(self) -> List[str]:
        """Fixed names plus each family's canonical members, sorted."""
        out = set(self._fixed)
        for spec in self._families:
            for values in spec.canonical:
                out.add(spec.member_name(values))
        return sorted(out)

    def families(self) -> List[VariantSpec]:
        return list(self._families)


class RegistryView(Mapping):
    """Legacy dict-compatible view over a :class:`VariantRegistry`.

    Supports everything the old hard-coded ``VARIANTS`` dict supported —
    ``VARIANTS[name]``, ``name in VARIANTS``, ``sorted(VARIANTS)`` — while
    ``__getitem__`` additionally resolves parameterized family members on
    demand (``VARIANTS["lci_b8"]`` works without pre-registration).
    Iteration yields only the canonical names, keeping enumeration finite.
    """

    def __init__(self, registry: VariantRegistry):
        self._registry = registry

    def __getitem__(self, name: str) -> Any:
        try:
            return self._registry.resolve(name)
        except UnknownVariantError:
            raise KeyError(name) from None

    def __contains__(self, name: object) -> bool:
        return name in self._registry

    def __iter__(self) -> Iterator[str]:
        return iter(self._registry.names())

    def __len__(self) -> int:
        return len(self._registry.names())
