"""Pickle-free wire formats for every hot data/control plane (ISSUE 9).

The paper's data-plane lesson (§5; LCI companion arXiv 2505.01864) is that
per-message *software* overhead — not the wire — dominates small-message
cost.  ``pickle`` on the hot path is exactly such overhead: it walks
objects, copies every buffer into its stream, and couples the wire format
to the Python object graph.  This module replaces it with two explicit,
versioned, length-prefixed binary formats:

* **gradient wire format** (:func:`encode_grad_header` /
  :func:`parse_grad_header`) — the header both the *host* pack path
  (:mod:`repro.train.grad_sync`) and the *device* pack path
  (:mod:`repro.kernels.grad_pack`) emit, so the two can be compared
  byte-for-byte (the parity contract of the device data plane).  Two body
  kinds: ``KIND_RAW`` (leaf bytes, tightly concatenated) and ``KIND_Q8``
  (int8 payload + per-tensor scales + offset table — the fused kernel's
  single flat device buffer, see :data:`PACK_TILE`).
* **control-plane message codec** (:func:`encode_msg` / :func:`decode_msg`)
  — a small tagged binary encoding for the serving stack's
  request/response tuples (ints, bools, token lists, …).  Deterministic,
  self-describing, and free of arbitrary-code-execution surface.

The CI gate (``tools/check_api.py`` gate 8) forbids ``pickle`` imports in
the wire-path modules (``train/grad_sync.py``, ``core/comm/``,
``serve/``); this module is what they use instead.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any, List, Sequence, Tuple

import numpy as np

__all__ = [
    "GRAD_MAGIC",
    "GRAD_VERSION",
    "KIND_RAW",
    "KIND_Q8",
    "PACK_TILE",
    "LeafSpec",
    "dtype_code",
    "code_dtype",
    "leaf_spec",
    "encode_grad_header",
    "parse_grad_header",
    "grad_header_bytes",
    "padded_nelems",
    "q8_offsets",
    "MSG_MAGIC",
    "MSG_VERSION",
    "encode_msg",
    "decode_msg",
]

# ---------------------------------------------------------------------------
# Gradient wire format (shared by host + device pack paths)
# ---------------------------------------------------------------------------

GRAD_MAGIC = 0xB7
GRAD_VERSION = 1
KIND_RAW = 0  # body: leaf bytes, tightly concatenated in leaf order
KIND_Q8 = 1  # body: offset table (u32/leaf) + scales (f32/leaf) + int8 payload

# The device pack kernel's tile, in ELEMENTS: every leaf's quantized
# payload segment is padded to a PACK_TILE multiple so HBM→VMEM tiles never
# straddle leaves.  The host path mirrors the padding exactly (zero bytes),
# which is what makes host and device wire bytes bit-comparable.
PACK_TILE = 1024

# dtype registry: code on the wire <-> numpy dtype.  bf16 rides through
# ml_dtypes (registered by jax); adding a code is a format version bump
# only if an existing code changes meaning.
_DTYPES: List[Tuple[int, str]] = [
    (0, "float32"),
    (1, "bfloat16"),
    (2, "float16"),
    (3, "int8"),
    (4, "int16"),
    (5, "int32"),
    (6, "int64"),
    (7, "uint8"),
    (8, "uint32"),
    (9, "float64"),
    (10, "bool"),
]


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


_CODE_TO_DTYPE = {code: _np_dtype(name) for code, name in _DTYPES}
_NAME_TO_CODE = {name: code for code, name in _DTYPES}


def dtype_code(dt: Any) -> int:
    name = np.dtype(dt).name
    try:
        return _NAME_TO_CODE[name]
    except KeyError:
        raise ValueError(f"dtype {name!r} has no gradient-wire code") from None


def code_dtype(code: int) -> np.dtype:
    try:
        return _CODE_TO_DTYPE[code]
    except KeyError:
        raise ValueError(f"unknown gradient-wire dtype code {code}") from None


@dataclass(frozen=True)
class LeafSpec:
    """One leaf's wire metadata: original dtype, shape, and payload bytes
    (raw: ``nelems * itemsize``; q8: ``nelems`` — one int8 byte per
    element, padding excluded)."""

    code: int
    shape: Tuple[int, ...]
    nbytes: int

    @property
    def dtype(self) -> np.dtype:
        return code_dtype(self.code)

    @property
    def nelems(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def leaf_spec(arr: Any, *, quantized: bool = False) -> LeafSpec:
    a = np.asarray(arr) if not hasattr(arr, "dtype") else arr
    shape = tuple(int(d) for d in a.shape)
    n = 1
    for d in shape:
        n *= d
    nbytes = n if quantized else n * np.dtype(a.dtype).itemsize
    return LeafSpec(dtype_code(a.dtype), shape, nbytes)


# header layout: <BBBB I> magic, version, kind, reserved, n_leaves; then per
# leaf <BBH I> dtype_code, ndim, reserved, nbytes followed by ndim × <I>.
_HEAD_FMT = "<BBBBI"
_HEAD_BYTES = struct.calcsize(_HEAD_FMT)
_LEAF_FMT = "<BBHI"
_LEAF_BYTES = struct.calcsize(_LEAF_FMT)


def encode_grad_header(kind: int, specs: Sequence[LeafSpec]) -> bytes:
    parts = [struct.pack(_HEAD_FMT, GRAD_MAGIC, GRAD_VERSION, kind, 0, len(specs))]
    for s in specs:
        parts.append(struct.pack(_LEAF_FMT, s.code, len(s.shape), 0, s.nbytes))
        parts.append(struct.pack(f"<{len(s.shape)}I", *s.shape))
    return b"".join(parts)


def grad_header_bytes(specs: Sequence[LeafSpec]) -> int:
    """Size of :func:`encode_grad_header`'s output without building it."""
    return _HEAD_BYTES + sum(_LEAF_BYTES + 4 * len(s.shape) for s in specs)


def parse_grad_header(buf) -> Tuple[int, List[LeafSpec], int]:
    """Returns ``(kind, specs, body_offset)``; ``buf`` is any bytes-like."""
    magic, version, kind, _r, n = struct.unpack_from(_HEAD_FMT, buf, 0)
    if magic != GRAD_MAGIC:
        raise ValueError(f"not a gradient wire payload (magic {magic:#x})")
    if version != GRAD_VERSION:
        raise ValueError(f"gradient wire version {version} not supported")
    off = _HEAD_BYTES
    specs: List[LeafSpec] = []
    for _ in range(n):
        code, ndim, _r2, nbytes = struct.unpack_from(_LEAF_FMT, buf, off)
        off += _LEAF_BYTES
        shape = struct.unpack_from(f"<{ndim}I", buf, off)
        off += 4 * ndim
        specs.append(LeafSpec(code, tuple(shape), nbytes))
    return kind, specs, off


def padded_nelems(nelems: int) -> int:
    """A leaf's q8 payload segment, padded to the kernel tile."""
    if nelems <= 0:
        return 0
    return -(-nelems // PACK_TILE) * PACK_TILE


def q8_offsets(specs: Sequence[LeafSpec]) -> List[int]:
    """Byte offset of each leaf's segment inside the padded q8 payload
    region (1 byte per element, tile-padded) — the wire's offset table."""
    offs, cur = [], 0
    for s in specs:
        offs.append(cur)
        cur += padded_nelems(s.nelems)
    return offs


# ---------------------------------------------------------------------------
# Control-plane message codec (the serving request/response tuples)
# ---------------------------------------------------------------------------

MSG_MAGIC = 0xC3
MSG_VERSION = 1

_T_NONE = 0x00
_T_FALSE = 0x01
_T_TRUE = 0x02
_T_INT = 0x03  # <q>
_T_FLOAT = 0x04  # <d>
_T_STR = 0x05  # <I> + utf8
_T_BYTES = 0x06  # <I> + raw
_T_LIST = 0x07  # <I> + items
_T_TUPLE = 0x08  # <I> + items
_T_DICT = 0x09  # <I> + key/value pairs


def _enc(obj: Any, out: List[bytes]) -> None:
    if obj is None:
        out.append(b"\x00")
    elif isinstance(obj, bool) or isinstance(obj, np.bool_):
        out.append(b"\x02" if obj else b"\x01")
    elif isinstance(obj, (int, np.integer)):
        out.append(struct.pack("<Bq", _T_INT, int(obj)))
    elif isinstance(obj, (float, np.floating)):
        out.append(struct.pack("<Bd", _T_FLOAT, float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(struct.pack("<BI", _T_STR, len(raw)))
        out.append(raw)
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        out.append(struct.pack("<BI", _T_BYTES, len(obj)))
        out.append(bytes(obj) if not isinstance(obj, bytes) else obj)
    elif isinstance(obj, (list, tuple)):
        tag = _T_LIST if isinstance(obj, list) else _T_TUPLE
        out.append(struct.pack("<BI", tag, len(obj)))
        for item in obj:
            _enc(item, out)
    elif isinstance(obj, dict):
        out.append(struct.pack("<BI", _T_DICT, len(obj)))
        for k, v in obj.items():
            _enc(k, out)
            _enc(v, out)
    else:
        raise TypeError(
            f"control-plane codec cannot encode {type(obj).__name__} — the "
            "wire carries plain ints/floats/str/bytes/containers only"
        )


def _dec(buf, off: int) -> Tuple[Any, int]:
    tag = buf[off]
    off += 1
    if tag == _T_NONE:
        return None, off
    if tag == _T_FALSE:
        return False, off
    if tag == _T_TRUE:
        return True, off
    if tag == _T_INT:
        (v,) = struct.unpack_from("<q", buf, off)
        return v, off + 8
    if tag == _T_FLOAT:
        (v,) = struct.unpack_from("<d", buf, off)
        return v, off + 8
    if tag == _T_STR:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        return bytes(buf[off : off + n]).decode("utf-8"), off + n
    if tag == _T_BYTES:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        return bytes(buf[off : off + n]), off + n
    if tag in (_T_LIST, _T_TUPLE):
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        items = []
        for _ in range(n):
            v, off = _dec(buf, off)
            items.append(v)
        return (items if tag == _T_LIST else tuple(items)), off
    if tag == _T_DICT:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        d = {}
        for _ in range(n):
            k, off = _dec(buf, off)
            v, off = _dec(buf, off)
            d[k] = v
        return d, off
    raise ValueError(f"control-plane codec: unknown tag {tag:#x} at offset {off - 1}")


def encode_msg(obj: Any) -> bytes:
    """Encode one control-plane message (nested ints/floats/bools/str/
    bytes/lists/tuples/dicts) to versioned wire bytes."""
    out: List[bytes] = [struct.pack("<BB", MSG_MAGIC, MSG_VERSION)]
    _enc(obj, out)
    return b"".join(out)


def decode_msg(data) -> Any:
    """Inverse of :func:`encode_msg`; accepts any bytes-like."""
    buf = memoryview(data) if not isinstance(data, (bytes, bytearray)) else data
    magic, version = buf[0], buf[1]
    if magic != MSG_MAGIC:
        raise ValueError(f"not a control-plane message (magic {magic:#x})")
    if version != MSG_VERSION:
        raise ValueError(f"control-plane message version {version} not supported")
    obj, off = _dec(buf, 2)
    if off != len(buf):
        raise ValueError(f"trailing bytes after message ({len(buf) - off})")
    return obj
