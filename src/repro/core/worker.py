"""Thread-local worker identities.

The LCI parcelport uses a *static* mapping from worker threads to devices
(paper §3.3.3).  The executor assigns ids; unknown threads (e.g. the main
thread in tests) get one lazily from a global counter.
"""
from __future__ import annotations

import itertools
import threading

_tls = threading.local()
_counter = itertools.count()


def set_worker_id(wid: int) -> None:
    _tls.wid = wid


def get_worker_id() -> int:
    wid = getattr(_tls, "wid", None)
    if wid is None:
        wid = next(_counter)
        _tls.wid = wid
    return wid
