"""repro.core — the paper's contribution: the HPX/LCI communication stack.

Layers (bottom-up, mirroring paper Fig 2):

* :mod:`repro.core.fabric` — native network layer (libibverbs semantics).
* :mod:`repro.core.device`, :mod:`repro.core.completion` — the
  communication-library layer (LCI): devices, completion objects, progress.
* :mod:`repro.core.mpi_sim` — MPI emulation with its interface limitations.
* :mod:`repro.core.parcelport`, :mod:`repro.core.mpi_parcelport`,
  :mod:`repro.core.lci_parcelport`, :mod:`repro.core.variants` — the HPX
  adaptation layer and the paper's studied configurations.
* :mod:`repro.core.executor` — the AMT worker runtime (HPX threads).
* :mod:`repro.core.comm` — the first-class communication-interface layer:
  the unified :class:`CommInterface` contract, :class:`PostStatus`
  backpressure, :class:`Capabilities`, the shared :class:`ResourceLimits`
  resource model, and the composable variant registry.
"""
from .comm import (
    Capabilities,
    CommInterface,
    CompletionTarget,
    ParcelportBase,
    PostStatus,
    ResourceLimits,
    UnsupportedCapabilityError,
)
from .completion import (
    LCRQueue,
    LockQueue,
    MichaelScottQueue,
    Synchronizer,
    SynchronizerPool,
    make_completion_queue,
)
from .device import LCIDevice, LockMode
from .executor import AMTExecutor, TaskFuture
from .fabric import Fabric, NetDevice
from .lci_parcelport import LCIParcelport, LCIPPConfig
from .mpi_parcelport import MPIParcelport
from .parcel import Chunk, Parcel, deserialize_action, serialize_action
from .parcelport import Locality, Parcelport, World
from .variants import (
    VARIANTS,
    make_parcelport_factory,
    max_devices,
    variant_limits,
    variant_names,
)

__all__ = [
    "AMTExecutor",
    "Capabilities",
    "Chunk",
    "CommInterface",
    "CompletionTarget",
    "Fabric",
    "LCIDevice",
    "LCIParcelport",
    "LCIPPConfig",
    "LCRQueue",
    "LockMode",
    "LockQueue",
    "Locality",
    "MPIParcelport",
    "MichaelScottQueue",
    "NetDevice",
    "Parcel",
    "Parcelport",
    "ParcelportBase",
    "PostStatus",
    "ResourceLimits",
    "Synchronizer",
    "SynchronizerPool",
    "TaskFuture",
    "UnsupportedCapabilityError",
    "VARIANTS",
    "World",
    "deserialize_action",
    "make_completion_queue",
    "make_parcelport_factory",
    "max_devices",
    "serialize_action",
    "variant_limits",
    "variant_names",
]
