"""In-process "native network layer" with libibverbs semantics (paper §3.1).

This is the lowest layer of the reproduction: it models what Libibverbs (and,
we argue with the paper, Libfabric/Cassini) gives a communication library:

* communication happens between *devices* — sets of hardware resources
  (send queue, receive queue, completion queue).  A process may open several
  devices (→ LCI device replication, uUAR-style hardware parallelism);
* **receives must be pre-posted**; a two-sided send arriving at a device with
  no posted receive triggers an RNR (Receiver Not Ready) event, which real
  hardware turns into a catastrophic retry storm — we count them and make the
  sender retry from its pending queue;
* completed operations are reported **only** through per-device hardware
  completion queues that the library must poll;
* one-sided RDMA put needs no posted receive and can carry a small immediate
  value for remote notification.

Each hardware resource is guarded by its *own* small mutex — "native network
resources typically use distinct locks to ensure thread safety" (§3.3.3).
Coarse-grained locking, when studied, is applied *above* this layer, exactly
where the paper locates it (the communication-library layer).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Fabric", "NetDevice", "Completion", "FabricStats"]


@dataclass
class Completion:
    """Hardware completion descriptor."""

    kind: str  # 'send' | 'recv' | 'put'
    src_rank: int = -1
    src_dev: int = -1
    data: Optional[bytes] = None  # payload for recv/put completions
    imm: Optional[int] = None  # 4-byte immediate (put with signal)
    ctx: Any = None  # user cookie (send ctx or posted-recv ctx)


@dataclass
class FabricStats:
    messages: int = 0
    bytes: int = 0
    rnr_events: int = 0
    puts: int = 0
    sends: int = 0


@dataclass
class _SendDesc:
    dst_rank: int
    dst_dev: int
    data: bytes
    ctx: Any


class NetDevice:
    """One set of network hardware resources (≈ QP + CQ + SRQ)."""

    def __init__(self, fabric: "Fabric", rank: int, dev_index: int, recv_slots: int = 0):
        self.fabric = fabric
        self.rank = rank
        self.dev_index = dev_index
        # Each resource has a distinct lock (hardware-level concurrency).
        self._recv_lock = threading.Lock()
        self._cq_lock = threading.Lock()
        self._send_lock = threading.Lock()
        self._posted_recvs: deque = deque()  # ctx cookies, SRQ-style
        self._cq: deque = deque()  # hardware completion queue
        self._pending_sends: deque = deque()  # RNR'd sends awaiting retry
        for _ in range(recv_slots):
            self._posted_recvs.append(None)

    # -- receive side -------------------------------------------------------
    def post_recv(self, ctx: Any = None) -> None:
        """Pre-post one receive slot (location-agnostic, SRQ semantics)."""
        with self._recv_lock:
            self._posted_recvs.append(ctx)

    def posted_recv_count(self) -> int:
        return len(self._posted_recvs)

    # -- send side ----------------------------------------------------------
    def post_send(self, dst_rank: int, dst_dev: int, data: bytes, ctx: Any = None) -> None:
        """Post a two-sided send.  Completion appears in this device's CQ
        once the remote had a posted receive; otherwise the descriptor parks
        in the pending queue and is retried by :meth:`hw_progress` (the
        fabric's stand-in for hardware RNR retransmission)."""
        desc = _SendDesc(dst_rank, dst_dev, data, ctx)
        if not self._try_deliver(desc):
            with self._send_lock:
                self._pending_sends.append(desc)

    def post_put(self, dst_rank: int, dst_dev: int, data: bytes, imm: int, ctx: Any = None) -> None:
        """One-sided RDMA put with immediate: lands directly in the remote
        CQ, no posted receive consumed (LCI *dynamic put* maps here)."""
        target = self.fabric.device(dst_rank, dst_dev)
        with target._cq_lock:
            target._cq.append(
                Completion(kind="put", src_rank=self.rank, src_dev=self.dev_index, data=data, imm=imm)
            )
        with self._cq_lock:
            self._cq.append(Completion(kind="send", ctx=ctx))
        st = self.fabric.stats
        st.messages += 1
        st.puts += 1
        st.bytes += len(data)

    def _try_deliver(self, desc: _SendDesc) -> bool:
        target = self.fabric.device(desc.dst_rank, desc.dst_dev)
        with target._recv_lock:
            if not target._posted_recvs:
                self.fabric.stats.rnr_events += 1
                return False
            recv_ctx = target._posted_recvs.popleft()
        with target._cq_lock:
            target._cq.append(
                Completion(
                    kind="recv",
                    src_rank=self.rank,
                    src_dev=self.dev_index,
                    data=desc.data,
                    ctx=recv_ctx,
                )
            )
        with self._cq_lock:
            self._cq.append(Completion(kind="send", ctx=desc.ctx))
        st = self.fabric.stats
        st.messages += 1
        st.sends += 1
        st.bytes += len(desc.data)
        return True

    # -- completion / progress ---------------------------------------------
    def poll_cq(self, max_n: int = 16) -> List[Completion]:
        """Poll up to ``max_n`` completions (users must poll with sufficient
        frequency to avoid overflow — we never overflow but the contract
        stands)."""
        out: List[Completion] = []
        with self._cq_lock:
            for _ in range(max_n):
                if not self._cq:
                    break
                out.append(self._cq.popleft())
        return out

    def hw_progress(self) -> bool:
        """Retry RNR'd sends.  Returns True if anything moved."""
        moved = False
        with self._send_lock:
            pending = list(self._pending_sends)
            self._pending_sends.clear()
        for desc in pending:
            if self._try_deliver(desc):
                moved = True
            else:
                with self._send_lock:
                    self._pending_sends.append(desc)
        return moved

    def cq_depth(self) -> int:
        return len(self._cq)


class Fabric:
    """The interconnect: a set of (rank, device) endpoints."""

    def __init__(self, n_ranks: int, devices_per_rank: int = 1, recv_slots: int = 0):
        self.n_ranks = n_ranks
        self.devices_per_rank = devices_per_rank
        self.stats = FabricStats()
        self._devices: Dict[Tuple[int, int], NetDevice] = {}
        for r in range(n_ranks):
            for d in range(devices_per_rank):
                self._devices[(r, d)] = NetDevice(self, r, d, recv_slots=recv_slots)

    def device(self, rank: int, dev: int = 0) -> NetDevice:
        return self._devices[(rank, dev)]

    def add_device(self, rank: int) -> NetDevice:
        """Open an extra device on ``rank`` (device replication)."""
        idx = sum(1 for (r, _d) in self._devices if r == rank)
        dev = NetDevice(self, rank, idx)
        self._devices[(rank, idx)] = dev
        return dev

    def devices_of(self, rank: int) -> List[NetDevice]:
        return [d for (r, _i), d in sorted(self._devices.items()) if r == rank]
