"""In-process "native network layer" with libibverbs semantics (paper §3.1).

This is the lowest layer of the reproduction: it models what Libibverbs (and,
we argue with the paper, Libfabric/Cassini) gives a communication library:

* communication happens between *devices* — sets of hardware resources
  (send queue, receive queue, completion queue).  A process may open several
  devices (→ LCI device replication, uUAR-style hardware parallelism);
* **receives must be pre-posted**; a two-sided send arriving at a device with
  no posted receive triggers an RNR (Receiver Not Ready) event, which real
  hardware turns into a catastrophic retry storm — we count them and make the
  sender retry from its pending queue;
* completed operations are reported **only** through per-device hardware
  completion queues that the library must poll;
* one-sided RDMA put needs no posted receive and can carry a small immediate
  value for remote notification.

Resource boundedness (paper §3.3.4): real NICs have a **finite send queue**
(descriptor ring) and communication libraries draw *eager* messages from a
**finite pool of pre-registered bounce buffers**.  Posting into a full queue
or an exhausted pool fails EAGAIN-style — the library above must retry or
throttle, which is exactly the resource-contention mitigation the paper
credits for LCI's small-message robustness.  Both limits default to
*unbounded* so that higher layers opt in explicitly.  The limits live in
one shared :class:`~repro.core.comm.resources.ResourceLimits` object (the
same model the DES consumes), and refusals are typed
:class:`~repro.core.comm.interface.PostStatus` values — a full descriptor
ring (``EAGAIN_QUEUE``) and an exhausted bounce pool (``EAGAIN_BUFFER``)
are different resources.

Each hardware resource is guarded by its *own* small mutex — "native network
resources typically use distinct locks to ensure thread safety" (§3.3.3).
Coarse-grained locking, when studied, is applied *above* this layer, exactly
where the paper locates it (the communication-library layer).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..analysis.sanitizer import make_lock, note_access
from .comm.interface import PostStatus
from .comm.resources import ResourceLimits

__all__ = [
    "Fabric",
    "NetDevice",
    "Completion",
    "FabricStats",
    "RegisteredBufferPool",
]


@dataclass
class Completion:
    """Hardware completion descriptor."""

    kind: str  # 'send' | 'recv' | 'put'
    src_rank: int = -1
    src_dev: int = -1
    data: Optional[bytes] = None  # payload for recv/put completions
    imm: Optional[int] = None  # 4-byte immediate (put with signal)
    ctx: Any = None  # user cookie (send ctx or posted-recv ctx)
    bounce: Any = None  # registered bounce buffer to recycle on send reap


@dataclass
class FabricStats:
    messages: int = 0
    bytes: int = 0
    rnr_events: int = 0
    puts: int = 0
    sends: int = 0
    eager_msgs: int = 0  # messages shipped through the eager protocol
    rendezvous_msgs: int = 0  # header/follow-up (rendezvous) messages
    backpressure_events: int = 0  # EAGAIN-style post rejections
    staged_bytes: int = 0  # payload bytes moved through staged device buffers
    staged_batches: int = 0  # device-buffer staging round trips (1 per drain)


@dataclass
class _SendDesc:
    dst_rank: int
    dst_dev: int
    data: bytes
    ctx: Any
    eager: bool = False
    bounce: Any = None


class RegisteredBufferPool:
    """Finite pool of pre-registered fixed-size bounce buffers.

    Eager sends copy their payload into one of these (registration is
    expensive, so it is done once up front); the buffer returns to the pool
    when the send completion is reaped from the CQ.  ``acquire`` failing is
    the second source of injection backpressure besides the send queue."""

    def __init__(self, nbufs: int, buf_size: int):
        self.buf_size = buf_size
        self.capacity = nbufs
        self._free: deque = deque(bytearray(buf_size) for _ in range(nbufs))
        self._lock = make_lock("RegisteredBufferPool._lock")

    def acquire(self, size: int) -> Optional[bytearray]:
        if size > self.buf_size:
            return None
        with self._lock:
            note_access("RegisteredBufferPool._free", id(self))
            if not self._free:
                return None
            return self._free.popleft()

    def release(self, buf: bytearray) -> None:
        with self._lock:
            note_access("RegisteredBufferPool._free", id(self))
            self._free.append(buf)

    def free_count(self) -> int:
        return len(self._free)


class NetDevice:
    """One set of network hardware resources (≈ QP + CQ + SRQ).

    ``send_queue_depth == 0`` means unbounded (the seed behaviour); a finite
    depth makes :meth:`post_send`/:meth:`post_put` return ``False`` when the
    ring is full.  A send occupies its slot from post until its *send
    completion is reaped* via :meth:`poll_cq` — not polling your CQ
    backpressures your own injection, like real hardware."""

    def __init__(
        self,
        fabric: "Fabric",
        rank: int,
        dev_index: int,
        recv_slots: int = 0,
        send_queue_depth: int = 0,
        bounce_pool: Optional[RegisteredBufferPool] = None,
    ):
        self.fabric = fabric
        self.rank = rank
        self.dev_index = dev_index
        self.send_queue_depth = send_queue_depth
        self.bounce_pool = bounce_pool
        self.bounded = send_queue_depth > 0 or bounce_pool is not None
        # Each resource has a distinct lock (hardware-level concurrency).
        self._recv_lock = make_lock("NetDevice._recv_lock")
        self._cq_lock = make_lock("NetDevice._cq_lock")
        self._send_lock = make_lock("NetDevice._send_lock")
        self._posted_recvs: deque = deque()  # ctx cookies, SRQ-style
        self._cq: deque = deque()  # hardware completion queue
        self._pending_sends: deque = deque()  # RNR'd sends awaiting retry
        self._inflight_sends = 0  # occupied send-queue slots
        for _ in range(recv_slots):
            self._posted_recvs.append(None)

    # -- receive side -------------------------------------------------------
    def post_recv(self, ctx: Any = None) -> None:
        """Pre-post one receive slot (location-agnostic, SRQ semantics)."""
        with self._recv_lock:
            note_access("NetDevice._posted_recvs", id(self))
            self._posted_recvs.append(ctx)

    def posted_recv_count(self) -> int:
        return len(self._posted_recvs)

    # -- send side ----------------------------------------------------------
    def eager_capacity(self) -> Optional[int]:
        """Largest message the eager path can carry here (None = unlimited)."""
        return None if self.bounce_pool is None else self.bounce_pool.buf_size

    def _claim_slot(self, size: int, eager: bool) -> Tuple[PostStatus, Any]:
        """Reserve a send-queue slot (+ bounce buffer for eager sends).
        Returns (status, bounce_buffer); a refusal names the exhausted
        resource (queue vs buffer pool — different remedies)."""
        with self._send_lock:
            note_access("NetDevice.send_ring", id(self))
            if self.send_queue_depth and self._inflight_sends >= self.send_queue_depth:
                self.fabric.stats.backpressure_events += 1
                return PostStatus.EAGAIN_QUEUE, None
            bounce = None
            if eager and self.bounce_pool is not None:
                bounce = self.bounce_pool.acquire(size)
                if bounce is None:
                    self.fabric.stats.backpressure_events += 1
                    return PostStatus.EAGAIN_BUFFER, None
            self._inflight_sends += 1
        return PostStatus.OK, bounce

    def post_send(self, dst_rank: int, dst_dev: int, data: bytes, ctx: Any = None, eager: bool = False) -> PostStatus:
        """Post a two-sided send.  Completion appears in this device's CQ
        once the remote had a posted receive; otherwise the descriptor parks
        in the pending queue and is retried by :meth:`hw_progress` (the
        fabric's stand-in for hardware RNR retransmission).

        Returns a falsy :class:`PostStatus` (EAGAIN) if the send queue is
        full or — for eager sends — no registered bounce buffer is
        available."""
        status, bounce = self._claim_slot(len(data), eager)
        if not status:
            return status
        if bounce is not None:
            bounce[: len(data)] = data  # the copy into registered memory
        desc = _SendDesc(dst_rank, dst_dev, data, ctx, eager=eager, bounce=bounce)
        if not self._try_deliver(desc):
            with self._send_lock:
                note_access("NetDevice.send_ring", id(self))
                self._pending_sends.append(desc)
        return PostStatus.OK

    def post_put(self, dst_rank: int, dst_dev: int, data: bytes, imm: int, ctx: Any = None, eager: bool = False) -> PostStatus:
        """One-sided RDMA put with immediate: lands directly in the remote
        CQ, no posted receive consumed (LCI *dynamic put* maps here).
        Subject to the same send-queue/bounce-pool bounds as two-sided
        sends; returns a falsy :class:`PostStatus` on backpressure."""
        status, bounce = self._claim_slot(len(data), eager)
        if not status:
            return status
        if bounce is not None:
            bounce[: len(data)] = data
        target = self.fabric.device(dst_rank, dst_dev)
        with target._cq_lock:
            note_access("NetDevice._cq", id(target))
            target._cq.append(
                Completion(kind="put", src_rank=self.rank, src_dev=self.dev_index, data=data, imm=imm)
            )
        with self._cq_lock:
            note_access("NetDevice._cq", id(self))
            self._cq.append(Completion(kind="send", ctx=ctx, bounce=bounce))
        st = self.fabric.stats
        st.messages += 1
        st.puts += 1
        st.bytes += len(data)
        if eager:
            st.eager_msgs += 1
        else:
            st.rendezvous_msgs += 1
        return PostStatus.OK

    def _try_deliver(self, desc: _SendDesc) -> bool:
        target = self.fabric.device(desc.dst_rank, desc.dst_dev)
        with target._recv_lock:
            note_access("NetDevice._posted_recvs", id(target))
            if not target._posted_recvs:
                self.fabric.stats.rnr_events += 1
                return False
            recv_ctx = target._posted_recvs.popleft()
        with target._cq_lock:
            note_access("NetDevice._cq", id(target))
            target._cq.append(
                Completion(
                    kind="recv",
                    src_rank=self.rank,
                    src_dev=self.dev_index,
                    data=desc.data,
                    ctx=recv_ctx,
                )
            )
        with self._cq_lock:
            note_access("NetDevice._cq", id(self))
            self._cq.append(Completion(kind="send", ctx=desc.ctx, bounce=desc.bounce))
        st = self.fabric.stats
        st.messages += 1
        st.sends += 1
        st.bytes += len(desc.data)
        if desc.eager:
            st.eager_msgs += 1
        else:
            st.rendezvous_msgs += 1
        return True

    # -- completion / progress ---------------------------------------------
    def poll_cq(self, max_n: int = 16) -> List[Completion]:
        """Poll up to ``max_n`` completions (users must poll with sufficient
        frequency to avoid overflow — we never overflow but the contract
        stands).  Reaping a send completion frees its send-queue slot and
        recycles its bounce buffer."""
        out: List[Completion] = []
        with self._cq_lock:
            note_access("NetDevice._cq", id(self))
            for _ in range(max_n):
                if not self._cq:
                    break
                out.append(self._cq.popleft())
        freed = 0
        for c in out:
            if c.kind == "send":
                freed += 1
                if c.bounce is not None and self.bounce_pool is not None:
                    self.bounce_pool.release(c.bounce)
                    c.bounce = None
        if freed:
            with self._send_lock:
                note_access("NetDevice.send_ring", id(self))
                self._inflight_sends -= freed
        return out

    def hw_progress(self) -> bool:
        """Retry RNR'd sends.  Returns True if anything moved."""
        moved = False
        with self._send_lock:
            note_access("NetDevice.send_ring", id(self))
            pending = list(self._pending_sends)
            self._pending_sends.clear()
        for desc in pending:
            if self._try_deliver(desc):
                moved = True
            else:
                with self._send_lock:
                    note_access("NetDevice.send_ring", id(self))
                    self._pending_sends.append(desc)
        return moved

    def cq_depth(self) -> int:
        return len(self._cq)

    def inflight_sends(self) -> int:
        return self._inflight_sends


class Fabric:
    """The interconnect: a set of (rank, device) endpoints.

    Per-device injection bounds come from one shared
    :class:`~repro.core.comm.resources.ResourceLimits` — pass ``limits``
    directly (the variant registry does, e.g. for the ``lci_b{depth}``
    family), or use the legacy scalar kwargs, which assemble the same
    object.  0 buffers = no pool = eager sends need no registered buffer;
    depth 0 = unbounded ring."""

    def __init__(
        self,
        n_ranks: int,
        devices_per_rank: int = 1,
        recv_slots: int = 0,
        send_queue_depth: int = 0,
        bounce_buffers: int = 0,
        bounce_buffer_size: int = 64 * 1024,
        limits: Optional[ResourceLimits] = None,
    ):
        self.n_ranks = n_ranks
        self.devices_per_rank = devices_per_rank
        self.stats = FabricStats()
        if limits is None:
            limits = ResourceLimits(
                send_queue_depth=send_queue_depth,
                bounce_buffers=bounce_buffers,
                bounce_buffer_size=bounce_buffer_size,
                recv_slots=recv_slots,
            )
        elif recv_slots and not limits.recv_slots:
            limits = limits.variant(recv_slots=recv_slots)
        self.limits = limits
        self._devices: Dict[Tuple[int, int], NetDevice] = {}
        for r in range(n_ranks):
            for d in range(devices_per_rank):
                self._devices[(r, d)] = self._make_device(r, d)

    def _make_device(self, rank: int, dev_index: int) -> NetDevice:
        lim = self.limits
        pool = (
            RegisteredBufferPool(lim.bounce_buffers, lim.bounce_buffer_size)
            if lim.bounce_buffers > 0
            else None
        )
        return NetDevice(
            self,
            rank,
            dev_index,
            recv_slots=lim.recv_slots,
            send_queue_depth=lim.send_queue_depth,
            bounce_pool=pool,
        )

    def device(self, rank: int, dev: int = 0) -> NetDevice:
        return self._devices[(rank, dev)]

    def add_device(self, rank: int) -> NetDevice:
        """Open an extra device on ``rank`` (device replication)."""
        idx = sum(1 for (r, _d) in self._devices if r == rank)
        dev = self._make_device(rank, idx)
        self._devices[(rank, idx)] = dev
        return dev

    def devices_of(self, rank: int) -> List[NetDevice]:
        return [d for (r, _i), d in sorted(self._devices.items()) if r == rank]
