"""A faithful-behaviour MPI emulation over the in-process fabric.

Models the MPI properties the paper identifies as the source of the MPI
parcelport's inefficiencies (§3.3):

* a **single device** per process, wrapped in one coarse-grained blocking
  lock (the typical MPI+UCX structure, §3.3.3);
* the only completion mechanism is the per-operation request object,
  tested one at a time (``MPI_Test``), §3.3.2;
* **no explicit progress**: the progress engine runs only as a side effect
  of ``test`` calls (§3.3.4 — "Current MPICH and OpenMPI implementations
  only poll the progress engine during calls to MPI_Test");
* tag matching on every receive, including ``MPI_ANY_SOURCE``;
* concurrent testing of a *shared* request is disallowed (MPI 4.1 §12.6.2),
  so the client (the parcelport) must wrap its own try-lock around tests.

:class:`MPISim` speaks the same unified
:class:`repro.core.comm.interface.CommInterface` as the LCI device — the
classic ``isend``/``irecv``/``test`` surface is a thin veneer over it —
but its :class:`Capabilities` advertise what MPI *cannot* do: no one-sided
put-with-signal, no shared completion queues, no explicit progress, and no
EAGAIN to the caller (refused posts buffer MPI-internally, FIFO, invisible
to the client — the paper's point about MPI hiding resource exhaustion).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Optional, Tuple

from .comm.interface import Capabilities, PostStatus, UnsupportedCapabilityError
from .completion import Synchronizer
from .device import LCIDevice, LockMode
from .fabric import Fabric

__all__ = ["MPISim", "MPIRequest", "ANY_SOURCE"]

ANY_SOURCE = -1


class MPIRequest:
    __slots__ = ("sync", "kind", "done", "payload", "src")

    def __init__(self, kind: str):
        self.sync = Synchronizer()
        self.kind = kind  # 'send' | 'recv'
        self.done = False
        self.payload: Optional[bytes] = None
        self.src = -1


class MPISim:
    """Per-rank MPI library instance (a CommInterface backend)."""

    capabilities = Capabilities(
        one_sided_put=False,
        queue_completion=False,
        explicit_progress=False,
        bounded_injection=False,  # EAGAIN is swallowed, never surfaced
    )

    def __init__(self, fabric: Fabric, rank: int):
        # MPI internals: one device, coarse-grained *blocking* lock.
        self._dev = LCIDevice(fabric.device(rank, 0), lock_mode=LockMode.BLOCK)
        self.rank = rank
        # MPI's internal global lock (MPI_THREAD_MULTIPLE big lock).
        self._big_lock = threading.Lock()
        # Sends the fabric backpressured, queued MPI-internally and flushed
        # on progress (real MPI buffers nonblocking sends the NIC refuses).
        # FIFO preserves MPI's non-overtaking order guarantee.
        self._pending_posts: deque = deque()

    # -- unified CommInterface surface --------------------------------------
    def post_send(
        self, dst_rank: int, dst_dev: int, tag: int, data: bytes,
        comp: Any, ctx: Any = None, eager: bool = False,
    ) -> PostStatus:
        """Nonblocking tagged send completing into ``comp``.  Always OK:
        MPI never surfaces EAGAIN — a post the fabric refuses queues
        MPI-internally (FIFO) and flushes on progress, which is exactly the
        opacity the paper critiques (the client cannot throttle what it
        cannot see)."""
        with self._big_lock:
            if self._pending_posts or not self._dev.post_send(
                dst_rank, dst_dev, tag, data, comp, ctx=ctx, eager=eager
            ):
                self._pending_posts.append((dst_rank, dst_dev, tag, data, comp, ctx, eager))
        return PostStatus.OK

    def post_recv(self, src_rank: int, tag: int, comp: Any, ctx: Any = None) -> None:
        """Pre-post a tagged receive (``src_rank`` may be ANY_SOURCE)."""
        with self._big_lock:
            self._dev.post_recv(src_rank, tag, comp, ctx=ctx)

    def post_put_signal(
        self, dst_rank: int, dst_dev: int, data: bytes,
        comp: Any, ctx: Any = None, eager: bool = False,
    ) -> PostStatus:
        raise UnsupportedCapabilityError(
            "MPI has no one-sided put-with-signal (capabilities.one_sided_put=False)"
        )

    def progress(self, max_completions: int = 16) -> bool:
        """Drive the library: drain hardware completions, then flush the
        internally-buffered posts.  MPI offers no *explicit* progress verb
        to clients (``capabilities.explicit_progress=False``) — this runs
        only as a side effect of :meth:`test` / :meth:`poll`."""
        with self._big_lock:
            moved = self._dev.progress(max_completions)
            self._flush_pending()
        return moved

    def poll(self, max_completions: int = 16) -> bool:
        """Completion-test-driven (implicit) progress — all MPI ever has."""
        return self.progress(max_completions)

    def _flush_pending(self) -> None:
        """Retry backpressured sends in order; caller holds the big lock."""
        while self._pending_posts:
            dst_rank, dst_dev, tag, data, comp, ctx, eager = self._pending_posts[0]
            if not self._dev.post_send(dst_rank, dst_dev, tag, data, comp, ctx=ctx, eager=eager):
                return
            self._pending_posts.popleft()

    def pending_post_count(self) -> int:
        return len(self._pending_posts)

    # -- the classic MPI veneer over the interface --------------------------
    def isend(self, dest: int, tag: int, data: bytes) -> MPIRequest:
        req = MPIRequest("send")
        status = self.post_send(dest, 0, tag, data, req.sync)
        if not status:  # post_send's contract is Always-OK (queues internally)
            raise RuntimeError(
                f"MPISim.post_send returned {status!r} — the MPI veneer has no "
                "retry path; a refused post here would drop the send silently"
            )
        return req

    def irecv(self, source: int, tag: int) -> MPIRequest:
        req = MPIRequest("recv")
        self.post_recv(source, tag, req.sync)
        return req

    def test(self, req: MPIRequest) -> Tuple[bool, Optional[bytes]]:
        """MPI_Test: progress runs here and only here (implicit progress).

        The caller must guarantee no concurrent test of the same request —
        the MPI parcelport does this with try-locks around its request
        pools, which is exactly the structure the paper critiques.
        """
        if req.done:
            return True, req.payload
        # implicit progress as a side effect of testing
        self.poll()
        rec = req.sync.test()
        if rec is None:
            return False, None
        req.done = True
        if req.kind == "recv":
            req.payload = rec.data
            req.src = rec.src_rank
        return True, req.payload
