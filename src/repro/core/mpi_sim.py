"""A faithful-behaviour MPI emulation over the in-process fabric.

Models the MPI properties the paper identifies as the source of the MPI
parcelport's inefficiencies (§3.3):

* a **single device** per process, wrapped in one coarse-grained blocking
  lock (the typical MPI+UCX structure, §3.3.3);
* the only completion mechanism is the per-operation request object,
  tested one at a time (``MPI_Test``), §3.3.2;
* **no explicit progress**: the progress engine runs only as a side effect
  of ``test`` calls (§3.3.4 — "Current MPICH and OpenMPI implementations
  only poll the progress engine during calls to MPI_Test");
* tag matching on every receive, including ``MPI_ANY_SOURCE``;
* concurrent testing of a *shared* request is disallowed (MPI 4.1 §12.6.2),
  so the client (the parcelport) must wrap its own try-lock around tests.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Optional, Tuple

from .completion import Synchronizer
from .device import LCIDevice, LockMode
from .fabric import Fabric

__all__ = ["MPISim", "MPIRequest", "ANY_SOURCE"]

ANY_SOURCE = -1


class MPIRequest:
    __slots__ = ("sync", "kind", "done", "payload", "src")

    def __init__(self, kind: str):
        self.sync = Synchronizer()
        self.kind = kind  # 'send' | 'recv'
        self.done = False
        self.payload: Optional[bytes] = None
        self.src = -1


class MPISim:
    """Per-rank MPI library instance."""

    def __init__(self, fabric: Fabric, rank: int):
        # MPI internals: one device, coarse-grained *blocking* lock.
        self._dev = LCIDevice(fabric.device(rank, 0), lock_mode=LockMode.BLOCK)
        self.rank = rank
        # MPI's internal global lock (MPI_THREAD_MULTIPLE big lock).
        self._big_lock = threading.Lock()
        # Sends the fabric backpressured, queued MPI-internally and flushed
        # on progress (real MPI buffers nonblocking sends the NIC refuses).
        # FIFO preserves MPI's non-overtaking order guarantee.
        self._pending_posts: deque = deque()

    def isend(self, dest: int, tag: int, data: bytes) -> MPIRequest:
        req = MPIRequest("send")
        with self._big_lock:
            if self._pending_posts or not self._dev.post_send(dest, 0, tag, data, req.sync):
                self._pending_posts.append((dest, tag, data, req.sync))
        return req

    def _flush_pending(self) -> None:
        """Retry backpressured sends in order; caller holds the big lock."""
        while self._pending_posts:
            dest, tag, data, sync = self._pending_posts[0]
            if not self._dev.post_send(dest, 0, tag, data, sync):
                return
            self._pending_posts.popleft()

    def pending_post_count(self) -> int:
        return len(self._pending_posts)

    def irecv(self, source: int, tag: int) -> MPIRequest:
        req = MPIRequest("recv")
        with self._big_lock:
            self._dev.post_recv(source, tag, req.sync)
        return req

    def test(self, req: MPIRequest) -> Tuple[bool, Optional[bytes]]:
        """MPI_Test: progress runs here and only here (implicit progress).

        The caller must guarantee no concurrent test of the same request —
        the MPI parcelport does this with try-locks around its request
        pools, which is exactly the structure the paper critiques.
        """
        if req.done:
            return True, req.payload
        with self._big_lock:
            # implicit progress as a side effect of testing
            self._dev.progress()
            self._flush_pending()
        rec = req.sync.test()
        if rec is None:
            return False, None
        req.done = True
        if req.kind == "recv":
            req.payload = rec.data
            req.src = rec.src_rank
        return True, req.payload
