"""The LCI communication-library layer: devices, tag matching, progress.

An :class:`LCIDevice` wraps one :class:`~repro.core.fabric.NetDevice` (the
"complete set of network resources", paper §3.3.3) and adds what a
communication library adds on top of verbs:

* two-sided send/recv with (src, tag) matching and an unexpected-message
  queue (receives may be posted after the message arrives);
* one-sided ``put_dynamic`` whose remote completion lands directly in a
  client-visible completion object (LCI's ideal primitive, §3.3.1);
* **bounded injection**: ``post_send``/``put_dynamic`` return False when the
  underlying fabric refuses the post (full send queue / exhausted bounce
  pool, §3.3.4) — the client retries or throttles;
* an **explicit progress engine** (`progress()`), §3.3.4;
* a configurable **lock discipline** for the factor studies (§5.3):
  ``none``   — fine-grained: only the fabric's per-resource locks,
  ``try``    — one coarse try-lock; progress gives up if contended,
  ``block``  — one coarse blocking lock around every library call.

:class:`LCIDevice` is a full :class:`repro.core.comm.interface.
CommInterface` backend: the five-verb surface (``post_send`` /
``post_recv`` / ``post_put_signal`` / ``progress`` / ``poll``), typed
:class:`PostStatus` backpressure results passed through from the fabric,
and a :class:`Capabilities` descriptor the parcelport consults to select
protocol paths.  Completion objects are anything conforming to
:class:`~repro.core.comm.interface.CompletionTarget` — see
:mod:`repro.core.completion`.
"""
from __future__ import annotations

import struct
import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from .comm.interface import Capabilities, PostStatus, complete as _complete_target
from .fabric import Fabric, NetDevice

__all__ = ["LCIDevice", "LockMode", "CompletionRecord", "WIRE_OVERHEAD"]


class LockMode:
    NONE = "none"
    TRY = "try"
    BLOCK = "block"


# LCI wire header for two-sided messages: tag; puts carry the target CQ id
# in the immediate instead (no matching at all).
_WIRE_FMT = "<q"
_WIRE_LEN = struct.calcsize(_WIRE_FMT)
# Bytes post_send prepends to every two-sided payload — clients sizing a
# message against a bounce buffer must budget for it (puts add nothing).
WIRE_OVERHEAD = _WIRE_LEN


@dataclass
class CompletionRecord:
    """What the library hands back to its client."""

    op: str  # 'send' | 'recv' | 'put_recv'
    tag: int = -1
    src_rank: int = -1
    src_dev: int = -1
    data: Optional[bytes] = None
    ctx: Any = None


class _PostedRecv:
    __slots__ = ("comp", "ctx")

    def __init__(self, comp: Any, ctx: Any):
        self.comp = comp
        self.ctx = ctx


def _complete(comp: Any, record: CompletionRecord) -> None:
    """Dispatch through the unified CompletionTarget ``signal`` surface
    (queues, synchronizers, and legacy push-only objects alike)."""
    _complete_target(comp, record)


class LCIDevice:
    """Library-level device: matching + progress over one NetDevice."""

    PREPOST_DEPTH = 64

    def __init__(
        self,
        net: NetDevice,
        lock_mode: str = LockMode.NONE,
        put_target_comp: Any = None,
    ):
        self.net = net
        self.lock_mode = lock_mode
        self.put_target_comp = put_target_comp  # completion obj for dynamic puts
        self._coarse = threading.Lock()
        # matching structures (fine-grained lock of their own)
        self._match_lock = threading.Lock()
        self._posted: Dict[Tuple[int, int], deque] = {}  # (src, tag) -> _PostedRecv
        self._posted_any: Dict[int, deque] = {}  # tag -> _PostedRecv (any-source)
        self._unexpected: Dict[Tuple[int, int], deque] = {}
        self.progress_calls = 0
        self.lock_failures = 0
        self._prepost(self.PREPOST_DEPTH)

    @property
    def capabilities(self) -> Capabilities:
        """What this backend can do — the parcelport's selection surface
        (paper §2.3): dynamic put needs a registered target completion
        object, and EAGAIN is only surfaced when the fabric is bounded."""
        return Capabilities(
            one_sided_put=self.put_target_comp is not None,
            queue_completion=True,
            explicit_progress=True,
            bounded_injection=self.net.bounded,
        )

    # ------------------------------------------------------------------ util
    def _prepost(self, n: int) -> None:
        for _ in range(n):
            self.net.post_recv()

    def _acquire(self, try_only: bool = False) -> bool:
        if self.lock_mode == LockMode.NONE:
            return True
        if self.lock_mode == LockMode.TRY and try_only:
            ok = self._coarse.acquire(blocking=False)
            if not ok:
                self.lock_failures += 1
            return ok
        self._coarse.acquire()
        return True

    def _release(self) -> None:
        if self.lock_mode != LockMode.NONE:
            self._coarse.release()

    # ------------------------------------------------------------- two-sided
    def post_send(self, dst_rank: int, dst_dev: int, tag: int, data: bytes, comp: Any, ctx: Any = None, eager: bool = False) -> PostStatus:
        """Nonblocking tagged send; ``comp`` completes locally when sent.
        Returns a falsy :class:`PostStatus` (EAGAIN) when the fabric
        backpressures the post."""
        self._acquire()
        try:
            wire = struct.pack(_WIRE_FMT, tag) + data
            return self.net.post_send(dst_rank, dst_dev, wire, ctx=("send", tag, comp, ctx), eager=eager)
        finally:
            self._release()

    def post_recv(self, src_rank: int, tag: int, comp: Any, ctx: Any = None) -> None:
        """Nonblocking tagged receive; ``src_rank`` may be -1 (any source)."""
        self._acquire()
        try:
            pr = _PostedRecv(comp, ctx)
            with self._match_lock:
                # Check the unexpected queue first.
                if src_rank >= 0:
                    uq = self._unexpected.get((src_rank, tag))
                    if uq:
                        src, data = uq.popleft()
                        self._deliver_recv(pr, src, tag, data)
                        return
                else:
                    for (s, t), uq in self._unexpected.items():
                        if t == tag and uq:
                            src, data = uq.popleft()
                            self._deliver_recv(pr, src, tag, data)
                            return
                if src_rank >= 0:
                    self._posted.setdefault((src_rank, tag), deque()).append(pr)
                else:
                    self._posted_any.setdefault(tag, deque()).append(pr)
        finally:
            self._release()

    def _deliver_recv(self, pr: _PostedRecv, src: int, tag: int, data: bytes) -> None:
        _complete(pr.comp, CompletionRecord(op="recv", tag=tag, src_rank=src, data=data, ctx=pr.ctx))

    # -------------------------------------------------------------- one-sided
    def post_put_signal(self, dst_rank: int, dst_dev: int, data: bytes, comp: Any, ctx: Any = None, eager: bool = False) -> PostStatus:
        """One-sided put into the remote device's dynamic-put completion
        object.  No tag, no matching, no posted receive: the receiver learns
        about the message by reaping its completion target (paper §3.3.1).
        Returns a falsy :class:`PostStatus` (EAGAIN) when the fabric
        backpressures the post."""
        self._acquire()
        try:
            return self.net.post_put(dst_rank, dst_dev, data, imm=0, ctx=("send", -1, comp, ctx), eager=eager)
        finally:
            self._release()

    # historical LCI name for the same primitive
    put_dynamic = post_put_signal

    def eager_capacity(self) -> Any:
        """Largest eager message this device can inject (None = unlimited)."""
        return self.net.eager_capacity()

    # ---------------------------------------------------------------- progress
    def progress(self, max_completions: int = 16) -> bool:
        """Explicit progress (paper §3.3.4): poll the hardware CQ, run the
        matching logic, re-post receives, retry RNR'd sends.  Returns True
        iff any progress was made.  Under ``try`` lock mode a contended call
        returns False immediately — the HPX scheduler has other work."""
        if not self._acquire(try_only=True):
            return False
        try:
            self.progress_calls += 1
            moved = self.net.hw_progress()
            completions = self.net.poll_cq(max_completions)
            reposts = 0
            for c in completions:
                moved = True
                if c.kind == "send":
                    _op, tag, comp, ctx = c.ctx
                    _complete(comp, CompletionRecord(op="send", tag=tag, ctx=ctx))
                elif c.kind == "put":
                    if self.put_target_comp is None:
                        raise RuntimeError("dynamic put received but no target completion object")
                    _complete(
                        self.put_target_comp,
                        CompletionRecord(op="put_recv", src_rank=c.src_rank, src_dev=c.src_dev, data=c.data),
                    )
                elif c.kind == "recv":
                    reposts += 1
                    (tag,) = struct.unpack_from(_WIRE_FMT, c.data, 0)
                    payload = c.data[_WIRE_LEN:]
                    self._match_incoming(c.src_rank, tag, payload)
            # keep the pre-post depth (avoid RNR)
            self._prepost(reposts)
            return moved
        finally:
            self._release()

    def poll(self, max_completions: int = 16) -> bool:
        """Completion-test-driven progress — the implicit entry point of
        the unified interface.  At this layer completion delivery and the
        progress engine are fused (polling the hardware CQ *is* both), so
        ``poll`` and :meth:`progress` share one implementation; the
        parcelport's ``progress_mode`` decides which verb it calls when."""
        return self.progress(max_completions)

    def _match_incoming(self, src: int, tag: int, payload: bytes) -> None:
        with self._match_lock:
            q = self._posted.get((src, tag))
            if q:
                pr = q.popleft()
            else:
                qa = self._posted_any.get(tag)
                if qa:
                    pr = qa.popleft()
                else:
                    self._unexpected.setdefault((src, tag), deque()).append((src, payload))
                    return
        self._deliver_recv(pr, src, tag, payload)
