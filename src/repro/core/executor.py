"""The AMT worker-thread executor (the HPX runtime analogue, paper §2.2.2).

Worker threads execute tasks from per-worker deques (LIFO locally, FIFO
steals — standard work-stealing) and, when idle, pump the communication
runtime — exactly the integration contract of Listing 2.  The pump is the
repo's ONE :class:`~repro.core.comm.progress.ProgressEngine`: pass
``comm=`` any engine-driven endpoint (a parcelport, the serving channel
ops — anything with ``.engine`` and ``.execute(op)``) and each idle worker
runs one canonical engine step (``run_step``) under its own worker id, so
progress policies, completion routing, and backpressure retry apply to
host-side work the same way they do in the parcelport study.  The legacy
opaque ``background_work`` callable remains for callers without an engine.

The training/serving framework uses this executor for all host-side
asynchronous work (checkpoint shard writes, data prefetch, metric sinks),
making the framework itself an asynchronous many-task consumer of the
communication runtime, per the paper's model.  Work stealing doubles as the
host-level straggler mitigation: a slow worker's queue is drained by its
peers.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional

from .comm.membership import join_workers, spawn_worker
from .comm.progress import run_step
from .worker import set_worker_id

__all__ = ["AMTExecutor", "TaskFuture"]


class TaskFuture:
    """Minimal future: set once, readable from any thread."""

    __slots__ = ("_event", "_value", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def set(self, value: Any) -> None:
        self._value = value
        self._event.set()

    def set_error(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("task not finished")
        if self._error is not None:
            raise self._error
        return self._value


class _WorkerState:
    __slots__ = ("deque", "lock", "steals", "executed")

    def __init__(self):
        self.deque: deque = deque()
        self.lock = threading.Lock()
        self.steals = 0
        self.executed = 0


class AMTExecutor:
    """Work-stealing thread pool with parcelport background-work pumping."""

    def __init__(
        self,
        n_workers: int = 2,
        background_work: Optional[Callable[[], bool]] = None,
        comm: Any = None,
        idle_sleep: float = 50e-6,
        name: str = "amt",
    ):
        """``comm``: an engine-driven communication endpoint — anything
        with ``.engine`` (the shared ProgressEngine) and ``.execute(op)``,
        e.g. a parcelport.  Idle workers then run one engine step per pump
        instead of an opaque callable (the Listing 2 contract over the
        shared engine)."""
        self.n_workers = n_workers
        self.background_work = background_work
        self.comm = comm
        self.idle_sleep = idle_sleep
        self._states = [_WorkerState() for _ in range(n_workers)]
        self._stop = threading.Event()
        self._submit_rr = 0
        # worker threads are spawned through the membership layer's
        # ownership surface so lifecycle accounting (tools/check_api.py
        # gate 7) sees every live worker in one place
        self._threads: List[threading.Thread] = []
        for w in range(n_workers):
            self._threads.append(spawn_worker(self._run, name=f"{name}-w{w}", args=(w,)))

    # ------------------------------------------------------------------ API
    def submit(self, fn: Callable[..., Any], *args: Any, worker: Optional[int] = None) -> TaskFuture:
        fut = TaskFuture()
        w = worker if worker is not None else self._submit_rr % self.n_workers
        self._submit_rr += 1
        st = self._states[w]
        with st.lock:
            st.deque.append((fn, args, fut))
        return fut

    def progress(self) -> bool:
        """Explicit progress from the caller thread (paper §3.3.4 applied to
        host work: the train loop pumps this once per step)."""
        return self._pump(0)

    def _pump(self, wid: int) -> bool:
        """One communication pump: a canonical step of the shared engine
        when a comm endpoint is attached, else the legacy callable."""
        if self.comm is not None:
            return run_step(self.comm.engine, self.comm, wid)
        if self.background_work is not None:
            return self.background_work()
        return False

    def pending(self) -> int:
        return sum(len(s.deque) for s in self._states)

    def shutdown(self, wait: bool = True) -> None:
        self._stop.set()
        if wait:
            join_workers(self._threads)

    def stats(self) -> dict:
        return {
            "executed": [s.executed for s in self._states],
            "steals": [s.steals for s in self._states],
        }

    # ------------------------------------------------------------- internals
    def _pop_local(self, w: int):
        st = self._states[w]
        with st.lock:
            if st.deque:
                return st.deque.pop()  # LIFO: cache-warm own tasks
        return None

    def _steal(self, w: int):
        n = self.n_workers
        for k in range(1, n):
            victim = self._states[(w + k) % n]
            with victim.lock:
                if victim.deque:
                    self._states[w].steals += 1
                    return victim.deque.popleft()  # FIFO steal
        return None

    def _run(self, w: int) -> None:
        set_worker_id(w)
        st = self._states[w]
        while not self._stop.is_set():
            task = self._pop_local(w) or self._steal(w)
            if task is not None:
                fn, args, fut = task
                try:
                    fut.set(fn(*args))
                except BaseException as e:  # noqa: BLE001 - report via future
                    fut.set_error(e)
                st.executed += 1
                continue
            # Idle: pump the communication runtime (Listing 2 contract) —
            # one shared-engine step under this worker's id.
            try:
                progressed = self._pump(w)
            except BaseException:
                progressed = False
            if not progressed:
                time.sleep(self.idle_sleep)
