"""The MPI parcelport (the paper's baseline, §3.3).

Reproduces the structure the paper analyses:

* header messages received through a single pre-posted
  ``MPI_Irecv(MPI_ANY_SOURCE)`` that ``background_work`` polls under a
  try-lock — only one thread at a time can proceed down the header path
  (the sequential bottleneck of §3.3.1);
* pending sends and follow-up receives live in two shared request pools
  (deque + try-lock), and each ``background_work`` call tests **one**
  request per pool, round-robin (§3.3.2);
* progress happens only implicitly inside ``MPI_Test`` (§3.3.4);
* chunks of one parcel are transferred sequentially (§3.2);
* optional parcel aggregation (= the paper's ``mpi_a``).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, List, Optional, Tuple

from .comm.progress import (
    CompletionRouter,
    CompletionSource,
    ProgressEngine,
    ProgressPolicy,
    run_step,
)
from .fabric import Fabric
from .mpi_sim import ANY_SOURCE, MPIRequest, MPISim
from .parcel import (
    HEADER_PIGGYBACK_LIMIT,
    Chunk,
    Parcel,
    SendCallback,
    decode_header,
    encode_header,
)
from .parcelport import Locality, Parcelport

TAG_HEADER = 0

__all__ = ["MPIParcelport", "TAG_HEADER"]


class _SendOp:
    __slots__ = ("dest", "parcel", "cb", "msgs", "next_idx")

    def __init__(self, dest: int, parcel: Parcel, cb: Optional[SendCallback], msgs: List[Tuple[int, bytes]]):
        self.dest = dest
        self.parcel = parcel
        self.cb = cb
        self.msgs = msgs  # [(tag, data)] sent sequentially
        self.next_idx = 1  # msgs[0] already posted


class _RecvOp:
    __slots__ = ("src", "header", "nzc", "zc_bufs", "pending", "idx")

    def __init__(self, src: int, header: Any):
        self.src = src
        self.header = header
        self.nzc: Optional[bytes] = header.piggybacked_nzc
        self.zc_bufs: List[bytearray] = []
        self.pending: List[int] = []  # remaining message sizes (just for bookkeeping)
        self.idx = 0


class _RequestPool:
    """Shared pool of (request, op) pairs, one try-locked test per call."""

    def __init__(self) -> None:
        self._q: deque = deque()
        self._lock = threading.Lock()

    def add(self, req: MPIRequest, op: Any) -> None:
        with self._lock:
            self._q.append((req, op))

    def poll_one(self) -> Optional[Tuple[MPIRequest, Any]]:
        if not self._lock.acquire(blocking=False):
            return None
        try:
            if not self._q:
                return None
            return self._q.popleft()
        finally:
            self._lock.release()

    def __len__(self) -> int:
        return len(self._q)


class MPIParcelport(Parcelport):
    def __init__(self, locality: Locality, fabric: Fabric, aggregation: bool = False):
        super().__init__(locality, aggregation=aggregation)
        self.mpi = MPISim(fabric, locality.rank)
        # Capability-driven path selection (§2.3): the MPI backend
        # advertises neither one-sided put nor shared completion queues nor
        # explicit progress, which *forces* every structure the paper
        # critiques — two-sided headers, per-request synchronizers tested
        # round-robin in shared pools, and MPI_Test-only progress.  The
        # checks make the dependency explicit: a backend that offered more
        # would make this parcelport's structure a choice, not a necessity.
        caps = self.mpi.capabilities
        assert not caps.one_sided_put and not caps.queue_completion
        assert not caps.explicit_progress
        self._send_pool = _RequestPool()
        self._recv_pool = _RequestPool()
        self._header_lock = threading.Lock()
        self._header_req = self.mpi.irecv(ANY_SOURCE, TAG_HEADER)
        # The SAME progress engine the LCI parcelport and the DES run: the
        # MPI structure is just a different ProgressPolicy (whole step
        # behind the request-pool try-lock + the library big lock, progress
        # implicit inside MPI_Test) and a router over the request pools —
        # one test per pool per step, round-robin (§3.3.2).
        self.engine = ProgressEngine(
            ProgressPolicy.mpi_request_pool(),
            CompletionRouter(
                [
                    CompletionSource("mpi_header", batch=1),
                    CompletionSource("send_pool", batch=1),
                    CompletionSource("recv_pool", batch=1),
                ]
            ),
            ndevices=1,
        )

    # -- sending --------------------------------------------------------------
    def _send_impl(self, dest: int, parcel: Parcel, cb: Optional[SendCallback]) -> None:
        header = encode_header(parcel, device_index=0)
        msgs: List[Tuple[int, bytes]] = [(TAG_HEADER, header)]
        if parcel.nzc_chunk.size > HEADER_PIGGYBACK_LIMIT:
            msgs.append((parcel.parcel_id, parcel.nzc_chunk.data))
        for c in parcel.zc_chunks:
            msgs.append((parcel.parcel_id, c.data))
        self.engine.record("send", "rdv", len(msgs) - 1)
        op = _SendOp(dest, parcel, cb, msgs)
        req = self.mpi.isend(dest, TAG_HEADER, header)
        self.stats_sent += 1
        self._send_pool.add(req, op)

    def _advance_send(self, req: MPIRequest, op: _SendOp) -> bool:
        done, _ = self.mpi.test(req)
        if not done:
            self._send_pool.add(req, op)
            return False
        if op.next_idx < len(op.msgs):
            tag, data = op.msgs[op.next_idx]
            op.next_idx += 1
            nreq = self.mpi.isend(op.dest, tag, data)
            self._send_pool.add(nreq, op)
        else:
            if op.cb is not None:
                op.cb(op.parcel)
        return True

    # -- receiving --------------------------------------------------------------
    def _reap_header(self) -> Optional[bytes]:
        """Test the single any-source header receive (try-lock: only one
        thread proceeds; this is the paper's sequential bottleneck).  On
        completion the next any-source receive is pre-posted *before* the
        payload is handed back for dispatch."""
        if not self._header_lock.acquire(blocking=False):
            return None
        try:
            done, payload = self.mpi.test(self._header_req)
            if not done:
                return None
            self._header_req = self.mpi.irecv(ANY_SOURCE, TAG_HEADER)
            return payload
        finally:
            self._header_lock.release()

    def _process_header(self, payload: bytes) -> None:
        self.engine.record("header", "rdv")
        h = decode_header(payload)
        op = _RecvOp(h.source, h)
        if h.piggybacked_nzc is not None and not h.zc_sizes:
            self._finish_recv(op)
            return
        # Sequential follow-ups: first the nzc chunk if it did not piggyback,
        # then each zero-copy chunk.
        req = self.mpi.irecv(h.source, h.parcel_id)
        self._recv_pool.add(req, op)

    def _advance_recv(self, req: MPIRequest, op: _RecvOp) -> bool:
        done, payload = self.mpi.test(req)
        if not done:
            self._recv_pool.add(req, op)
            return False
        self.engine.record("chunk")
        h = op.header
        if op.nzc is None:
            op.nzc = payload
        else:
            # a zero-copy chunk: copy into the upper-layer allocated buffer
            if not op.zc_bufs:
                op.zc_bufs = self.locality.allocate_zc_chunks(op.nzc)
            buf = op.zc_bufs[op.idx]
            buf[:] = payload
            op.idx += 1
        if op.idx < len(h.zc_sizes):
            nreq = self.mpi.irecv(h.source, h.parcel_id)
            self._recv_pool.add(nreq, op)
        else:
            self._finish_recv(op)
        return True

    def _finish_recv(self, op: _RecvOp) -> None:
        h = op.header
        if h.zc_sizes and not op.zc_bufs:
            op.zc_bufs = self.locality.allocate_zc_chunks(op.nzc)
        parcel = Parcel(
            parcel_id=h.parcel_id,
            source=h.source,
            dest=h.dest,
            nzc_chunk=Chunk(bytes(op.nzc)),
            zc_chunks=[Chunk(bytes(b)) for b in op.zc_bufs],
            is_agg=h.is_agg,
        )
        self.deliver(parcel)

    def pending_work(self) -> bool:
        # MPI hides refused posts inside the library (no EAGAIN to us), so
        # the library's internal backlog counts as pending work too.
        return self.mpi.pending_post_count() > 0 or bool(self._retry_q)

    # ------------------------------------------- the progress-engine hookup
    def background_work(self) -> bool:
        """One step of the SHARED progress engine; this parcelport supplies
        only the op semantics (request-pool tests, header polling)."""
        return run_step(self.engine, self, 0)

    def execute(self, op: tuple) -> Any:
        """Execute one engine op against MPISim's request-pool structures.

        The engine's ``progress`` op maps to *nothing*: MPI advertises no
        explicit progress verb (``capabilities.explicit_progress=False``) —
        all progress rides inside the ``test`` calls the reaps perform,
        which is exactly the §3.3.4 structure the paper critiques."""
        kind = op[0]
        if kind == "reap":
            name = op[1].name
            if name == "mpi_header":
                return self._reap_header()
            if name == "send_pool":
                return self._send_pool.poll_one()
            return self._recv_pool.poll_one()
        if kind == "dispatch":
            name, item = op[1].name, op[3]
            if name == "mpi_header":
                self._process_header(item)
                return True
            if name == "send_pool":
                return self._advance_send(*item)
            return self._advance_recv(*item)
        if kind == "drain_retries":
            # MPISim buffers refused posts internally (no EAGAIN surfaces),
            # so the parcelport's retry queue is normally empty.
            return self._drain_retries()
        if kind == "step_trylock":
            # the pool try-locks live inside _RequestPool.poll_one / the
            # header lock — the step-level decision maps to "go ahead".
            return True
        # progress/poll/big_lock/implicit_tax/reap_*/flush: nothing to do
        # at this layer (see docstring); the DES charges their costs.
        return False
