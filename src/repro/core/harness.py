"""Shared delivery harness over the functional parcelport stack.

One helper used by the benchmark smoke gate, the protocol benchmarks, and
the test suite: build a world for a named variant, push payloads through
``async_action``, drain to quiescence, and hand back the world (for
``world.fabric.stats``) plus what arrived.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from .parcelport import World
from .variants import make_parcelport_factory, max_devices, variant_limits

__all__ = ["deliver_payloads", "transport_stats"]


def transport_stats(world: "World"):
    """The stats of whichever transport actually carried the bytes: the
    collective group's when the variant rode the JAX-collectives backend,
    the shmem group's on the shared-memory backend, the fabric's
    otherwise.  All share the ``FabricStats`` shape, so benchmark code
    reads any transport through this one accessor."""
    for attr in ("_collective_group", "_shmem_group"):
        group = getattr(world.fabric, attr, None)
        if group is not None:
            return group.stats
    return world.fabric.stats


def deliver_payloads(
    variant: str,
    payloads: Sequence[bytes],
    n_loc: int = 2,
    fabric_kwargs: Optional[Dict[str, Any]] = None,
    zero_copy_threshold: int = 1024,
    max_rounds: int = 100_000,
) -> Tuple[World, List[tuple]]:
    """Send each payload round-robin between localities on ``variant``,
    drain (raises on deadlock / parked posts), return ``(world, got)``."""
    if fabric_kwargs is None:
        # A variant may carry its own resource model (e.g. the lci_b{depth}
        # bounded-injection family): build the fabric from it so the limits
        # actually bind.  Explicit fabric_kwargs always win.
        limits = variant_limits(variant)
        if limits.bounded or limits.recv_slots:
            fabric_kwargs = {"limits": limits}
    world = World(
        n_loc,
        make_parcelport_factory(variant),
        devices_per_rank=max_devices(variant),
        fabric_kwargs=fabric_kwargs,
    )
    got: List[tuple] = []
    for loc in world.localities:
        loc.register_action("sink", lambda *a, _g=got: _g.append(a))
    for i, pl in enumerate(payloads):
        world.localities[i % n_loc].async_action(
            (i + 1) % n_loc, "sink", pl, zero_copy_threshold=zero_copy_threshold
        )
    world.drain(max_rounds=max_rounds)
    return world, got
