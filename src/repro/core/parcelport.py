"""The HPX parcelport abstraction (paper §2.3, Listing 2) and localities.

The contract a parcelport implements::

    send(locality, parcel, cb) -> None        # any worker thread may call
    background_work() -> bool                 # workers call when idle

and the upper layer provides::

    allocate_zc_chunks(nzc_chunk) -> buffers  # receiver-side zc allocation
    handle_parcel(parcel) -> None             # deliver to the runtime

The library-agnostic machinery — parcel aggregation (paper §2.2.2,
including the threshold-aware drain), backpressure retry parking, and the
send/receive stats — lives in :class:`repro.core.comm.base.ParcelportBase`
and is shared by every concrete parcelport; this module re-exports the
aggregation helpers under their historical names.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, List, Optional

from .comm.base import (  # noqa: F401  (re-exported public API)
    AGG_MAGIC,
    AGG_MAX_PARCELS,
    AGG_PER_PARCEL_BYTES,
    AGG_PREAMBLE_BYTES,
    AGG_SUB_SHIFT,
    ParcelportBase,
    aggregate_parcels,
    aggregate_projected_bytes,
    is_aggregate,
    split_aggregate,
)
from .fabric import Fabric
from .parcel import Parcel, SendCallback, deserialize_action, serialize_action, zc_sizes_from_nzc

__all__ = [
    "Parcelport",
    "Locality",
    "World",
    "aggregate_parcels",
    "aggregate_projected_bytes",
    "split_aggregate",
]


class Parcelport(ParcelportBase):
    """Abstract parcelport (one per communication library per locality).

    Subclasses implement ``_send_impl`` (per-parcel protocol selection
    against their :class:`~repro.core.comm.interface.CommInterface`
    backend) and ``background_work`` (their progress/completion loop)."""


class Locality:
    """One HPX process: action registry + the upper-layer callbacks."""

    def __init__(self, rank: int, world: "World"):
        self.rank = rank
        self.world = world
        self.actions: Dict[str, Callable[..., Any]] = {}
        self.parcelport: Optional[Parcelport] = None
        # Locality-unique parcel ids, also used as follow-up message tags.
        # Start at 1: tag 0 is reserved for header messages (TAG_HEADER).
        self._pid = itertools.count((rank << 40) + 1)
        self.handled = itertools.count()
        self.handled_count = 0

    def register_action(self, name: str, fn: Callable[..., Any]) -> None:
        self.actions[name] = fn

    # upper-layer callbacks (Listing 2) --------------------------------------
    def allocate_zc_chunks(self, nzc_data: bytes) -> List[bytearray]:
        """Allocate receive buffers for zero-copy chunks; the nzc chunk
        carries their sizes."""
        return [bytearray(sz) for sz in zc_sizes_from_nzc(nzc_data)]

    def handle_parcel(self, parcel: Parcel) -> None:
        action, args = deserialize_action(parcel)
        self.handled_count += 1
        fn = self.actions.get(action)
        if fn is None:
            raise KeyError(f"locality {self.rank}: unknown action {action!r}")
        fn(*args)

    # convenience: HPX async(locality, action, args...) ----------------------
    def async_action(
        self,
        dest: int,
        action: str,
        *args: Any,
        cb: Optional[SendCallback] = None,
        zero_copy_threshold: Optional[int] = None,
    ) -> None:
        kw = {}
        if zero_copy_threshold is not None:
            kw["zero_copy_threshold"] = zero_copy_threshold
        parcel = serialize_action(next(self._pid), self.rank, dest, action, args, **kw)
        assert self.parcelport is not None, "parcelport not attached"
        self.parcelport.send(dest, parcel, cb)


class World:
    """A set of in-process localities joined by one fabric."""

    def __init__(
        self,
        n_localities: int,
        parcelport_factory: Callable[["Locality", Fabric], Parcelport],
        devices_per_rank: int = 1,
        fabric_kwargs: Optional[Dict[str, Any]] = None,
    ):
        self.fabric = Fabric(n_localities, devices_per_rank=devices_per_rank, **(fabric_kwargs or {}))
        self.localities = [Locality(r, self) for r in range(n_localities)]
        for loc in self.localities:
            loc.parcelport = parcelport_factory(loc, self.fabric)
        # Optional lifecycle table (core.comm.membership): a consumer that
        # tracks workers against this world attaches its Membership here so
        # close() can run the abandoned-member liveness sweep BEFORE the
        # parcelports release their resources.
        self.membership: Optional[Any] = None

    def progress_all(self, rounds: int = 1) -> bool:
        """Drive every locality's background work (single-threaded pump,
        used by tests; the executor drives this from worker threads)."""
        any_progress = False
        for _ in range(rounds):
            for loc in self.localities:
                if loc.parcelport.background_work():
                    any_progress = True
        return any_progress

    def close(self) -> None:
        """Release per-parcelport resources — in particular, stop and join
        any dedicated progress threads (``lci_prg{n}``) so repeated world
        construction cannot accumulate live daemons.

        Teardown ordering matters (ISSUE 8): the membership sweep runs
        FIRST, so a tracked worker that died without ``leave()`` has its
        ``on_gone`` hook return ring/shmem slots while the transports are
        still alive; only then do the parcelports release resources."""
        if self.membership is not None:
            self.membership.sweep()
        for loc in self.localities:
            close = getattr(loc.parcelport, "close", None)
            if close is not None:
                close()

    def drain(self, max_rounds: int = 100_000) -> None:
        """Pump until quiescent (no progress for a few consecutive rounds).
        Raises if the world stops moving while a parcelport still holds
        parked (backpressured) posts — that is silent message loss, not
        quiescence."""
        idle = 0
        for _ in range(max_rounds):
            if self.progress_all():
                idle = 0
            else:
                idle += 1
                if idle > 8:
                    if any(loc.parcelport.pending_work() for loc in self.localities):
                        raise RuntimeError(
                            "world stalled with backpressured posts still parked "
                            "(undeliverable send: check bounce-buffer sizing / send-queue depth)"
                        )
                    return
        raise RuntimeError("world did not quiesce")
